"""repro — production-grade JAX reproduction of
"CADA: Communication-Adaptive Distributed Adam" (Chen, Guo, Sun, Yin, 2020).

Public API (stable entry points; everything else is internal):

    repro.CommRule, repro.CADAEngine        # paper Algorithm 1
    repro.TrainHParams, repro.jit_train_step  # pod-scale trainer
    repro.get_config, repro.list_archs      # the 10-arch registry
"""

__version__ = "0.1.0"


def __getattr__(name):  # lazy: importing repro must not touch jax devices
    if name in ("CommRule",):
        from repro.core.rules import CommRule
        return CommRule
    if name in ("CommStrategy", "strategy_for", "strategy_kinds",
                "register"):
        from repro.core import comm
        return getattr(comm, name)
    if name in ("CADAEngine",):
        from repro.core.engine import CADAEngine
        return CADAEngine
    if name in ("TrainHParams", "jit_train_step"):
        from repro.distributed import trainer
        return getattr(trainer, name)
    if name in ("get_config", "get_smoke_config", "list_archs"):
        import repro.configs as _c
        return getattr(_c, name)
    raise AttributeError(name)
