"""Pallas TPU kernels for the paper's compute hot spots.

  cada_update.py — fused AMSGrad/CADA optimizer step + ||Δθ||² (one HBM pass)
  ssm_scan.py    — fused selective scan (Mamba1/2) with VMEM state carry
  ops.py         — jit'd wrappers (interpret=True on CPU, Mosaic on TPU)
  ref.py         — pure-jnp oracles used by tests/test_kernels.py
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    diff_sq_norm, diff_sq_norm_flat, fused_amsgrad_flat, fused_cada_update,
    selective_scan,
)
