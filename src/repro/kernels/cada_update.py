"""Fused CADA/AMSGrad server update — Pallas TPU kernel.

The paper's per-iteration hot spot is elementwise streaming over the full
parameter vector: the Adam/AMSGrad update (eqs. 2a-2c) plus CADA's two norm
reductions (the rule's RHS needs ||θ^{k+1}-θ^k||², the LHS needs
||fresh-stale||²). A naive jnp implementation makes ~9 separate HBM passes
over {θ, h, v, v̂, ∇}; both kernels below make exactly ONE pass, with the
scalar reductions accumulated in fp32 VMEM.

TPU adaptation notes (DESIGN.md §6):
  * parameters are flattened and tiled into (BLOCK_ROWS, 128) VMEM blocks —
    lane dim 128, sublane a multiple of 8, so the VPU is fully utilized;
  * the reduction output is a (1, 1) fp32 block revisited by every grid step
    (TPU grid is sequential), initialized at step 0 — the standard Pallas
    accumulation pattern, no atomics needed (vs. the CUDA grid-reduce);
  * moments are carried in fp32 even when θ is bf16 (matches optim/adam.py).

Validated in ``interpret=True`` mode against ``ref.py`` (see
tests/test_kernels.py for the shape/dtype sweep).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 256          # (256, 128) fp32 blocks = 128 KiB/operand in VMEM
BLOCK = BLOCK_ROWS * LANES


def _amsgrad_kernel(theta_ref, h_ref, vhat_ref, grad_ref, lr_ref,
                    theta_out, h_out, vhat_out, sq_out,
                    *, b1: float, b2: float, eps: float):
    """One VMEM block of the fused AMSGrad/CADA update (paper eq. 2a-2c).

    Paper convention: v^{k+1} = β2·v̂^k + (1-β2)(∇^k)² (note v̂, not v), then
    v̂^{k+1} = max(v, v̂), and ε sits INSIDE the sqrt. Because (2b) reads v̂
    rather than v, the raw second moment v is a kernel-local temporary — the
    persistent optimizer state is only {h, v̂} (8P bytes, not 12P; bf16
    moment storage halves that again). Moments are dtype-parametric: math
    runs in fp32, the STORED (rounded) value drives the update — matching
    the per-leaf reference stream, so fp32 storage is bit-identical to the
    pre-parametric kernel and bf16 storage parity-matches the reference.
    """
    g = grad_ref[...].astype(jnp.float32)
    h32 = h_ref[...].astype(jnp.float32)
    vh32 = vhat_ref[...].astype(jnp.float32)
    h = (b1 * h32 + (1.0 - b1) * g).astype(h_out.dtype)
    v = b2 * vh32 + (1.0 - b2) * g * g
    vhat = jnp.maximum(v, vh32).astype(vhat_out.dtype)
    upd = (-lr_ref[0] * h.astype(jnp.float32)
           / jnp.sqrt(eps + vhat.astype(jnp.float32)))

    theta = theta_ref[...]
    theta_out[...] = (theta.astype(jnp.float32) + upd).astype(theta.dtype)
    h_out[...] = h
    vhat_out[...] = vhat

    # ||θ^{k+1} − θ^k||² partial sum, accumulated across the sequential grid.
    blk = jnp.sum(upd * upd)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sq_out[0, 0] = 0.0

    sq_out[0, 0] += blk


def fused_amsgrad_flat(theta, h, vhat, grad, lr, *, b1=0.9, b2=0.999,
                       eps=1e-8, interpret=False):
    """Fused update over pre-flattened (n_blocks*BLOCK,) buffers.

    Returns (theta', h', vhat', ||update||²). Moments keep their incoming
    storage dtype (fp32 or bf16 — see the kernel's dtype discipline).
    """
    n = theta.shape[0]
    assert n % BLOCK == 0, f"flat size {n} not a multiple of {BLOCK}"
    nb = n // BLOCK
    shape2d = (nb * BLOCK_ROWS, LANES)
    t2, h2, vh2, g2 = (a.reshape(shape2d) for a in (theta, h, vhat, grad))
    lr_arr = jnp.asarray([lr], jnp.float32)

    spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        partial(_amsgrad_kernel, b1=b1, b2=b2, eps=eps),
        grid=(nb,),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(spec, spec, spec,
                   pl.BlockSpec((1, 1), lambda i: (0, 0))),
        out_shape=(
            jax.ShapeDtypeStruct(shape2d, theta.dtype),
            jax.ShapeDtypeStruct(shape2d, h.dtype),
            jax.ShapeDtypeStruct(shape2d, vhat.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(t2, h2, vh2, g2, lr_arr)
    t_new, h_new, vh_new, sq = outs
    return (t_new.reshape(n), h_new.reshape(n), vh_new.reshape(n), sq[0, 0])


def _batched_diff_sq_kernel(a_ref, b_ref, out_ref):
    """Partial Σ_j (a_mj − b_mj)² for ONE worker row, accumulated across the
    inner (sequential) block grid axis — all M CADA rule LHS norms in a
    single pass over the two (M, n) planes."""
    d = a_ref[...].astype(jnp.float32) - b_ref[...].astype(jnp.float32)
    blk = jnp.sum(d * d)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += blk


def batched_diff_sq_norm_flat(a, b, *, interpret=False):
    """(M,) per-worker ||a_m − b_m||² over (M, n) pre-flattened planes.

    The grid is (M, n/BLOCK) with the block axis innermost: the TPU grid is
    sequential, so each worker's (1, 1) accumulator is initialized at its
    first block and revisited — the same pattern as the unbatched kernels,
    just with a second grid axis for the worker rows.
    """
    m, n = a.shape
    assert n % BLOCK == 0, f"flat width {n} not a multiple of {BLOCK}"
    nb = n // BLOCK
    shape3d = (m, nb * BLOCK_ROWS, LANES)
    spec = pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i, j: (i, j, 0))
    out = pl.pallas_call(
        _batched_diff_sq_kernel,
        grid=(m, nb),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(a.reshape(shape3d), b.reshape(shape3d))
    return out[:, 0]


def _batched_sq_kernel(a_ref, out_ref):
    """Partial Σ_j a_mj² for one worker row (single-operand variant)."""
    v = a_ref[...].astype(jnp.float32)
    blk = jnp.sum(v * v)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += blk


def batched_sq_norm_flat(a, *, interpret=False):
    """(M,) per-worker ||a_m||² over an (M, n) pre-flattened plane."""
    m, n = a.shape
    assert n % BLOCK == 0, f"flat width {n} not a multiple of {BLOCK}"
    nb = n // BLOCK
    shape3d = (m, nb * BLOCK_ROWS, LANES)
    spec = pl.BlockSpec((1, BLOCK_ROWS, LANES), lambda i, j: (i, j, 0))
    out = pl.pallas_call(
        _batched_sq_kernel,
        grid=(m, nb),
        in_specs=[spec],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(a.reshape(shape3d))
    return out[:, 0]


def _diff_sq_kernel(a_ref, b_ref, out_ref):
    """Partial Σ (a − b)² — the CADA rule LHS, one fused pass."""
    d = a_ref[...].astype(jnp.float32) - b_ref[...].astype(jnp.float32)
    blk = jnp.sum(d * d)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += blk


def diff_sq_norm_flat(a, b, *, interpret=False):
    """||a − b||² over pre-flattened buffers (rule LHS, eqs. 7/10)."""
    n = a.shape[0]
    assert n % BLOCK == 0, f"flat size {n} not a multiple of {BLOCK}"
    nb = n // BLOCK
    shape2d = (nb * BLOCK_ROWS, LANES)
    spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _diff_sq_kernel,
        grid=(nb,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(a.reshape(shape2d), b.reshape(shape2d))
    return out[0, 0]
