"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs in Python-on-CPU for bit-faithful validation); on a real TPU
``interpret=False`` compiles the same BlockSpec tiling to Mosaic. The flag
defaults from the backend so user code never branches.

``fused_cada_update`` is the pytree-level entry point used by the optimizer:
it flattens the parameter pytree into one padded fp32 stream, runs the fused
kernel, and scatters back — giving the one-HBM-pass optimizer step plus the
CADA rule's ||Δθ||² for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cada_update as _cu
from repro.kernels import ssm_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------------ flat ops

@partial(jax.jit, static_argnames=("b1", "b2", "eps", "interpret"))
def fused_amsgrad_flat(theta, h, vhat, grad, lr, *, b1=0.9, b2=0.999,
                       eps=1e-8, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _cu.fused_amsgrad_flat(theta, h, vhat, grad, lr, b1=b1, b2=b2,
                                  eps=eps, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def diff_sq_norm_flat(a, b, *, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _cu.diff_sq_norm_flat(a, b, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "dblk", "interpret"))
def selective_scan(dt, x, a, b, c, *, chunk=_ss.DEFAULT_CHUNK,
                   dblk=_ss.DEFAULT_DBLK, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ss.selective_scan(dt, x, a, b, c, chunk=chunk, dblk=dblk,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("window", "q_blk", "kv_blk",
                                   "interpret"))
def flash_attention(q, k, v, *, window=0, q_blk=None, kv_blk=None,
                    interpret=None):
    """GQA flash attention via the Pallas kernel.

    q (B, S, Hq, hd); k/v (B, S, Hkv, hd). Each Q head is paired with its
    KV head and flattened onto the kernel's G axis.
    """
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = _default_interpret()
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    qg = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kg = jnp.repeat(k.transpose(0, 2, 1, 3), grp, axis=1).reshape(
        b * hq, s, hd)
    vg = jnp.repeat(v.transpose(0, 2, 1, 3), grp, axis=1).reshape(
        b * hq, s, hd)
    kw = {}
    if q_blk:
        kw["q_blk"] = q_blk
    if kv_blk:
        kw["kv_blk"] = kv_blk
    o = _fa.flash_attention_kernel(qg, kg, vg, window=window,
                                   interpret=interpret, **kw)
    return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


# --------------------------------------------------------------- pytree ops

def _flatten_padded(tree, dtype, block=_cu.BLOCK):
    """Concat all leaves (as ``dtype``) into one flat buffer padded to a
    whole number of kernel blocks. Returns (flat, unflatten_fn)."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))

    def unflatten(buf, out_dtypes=None):
        out_dtypes = out_dtypes or dtypes
        outs, off = [], 0
        for sz, shp, dt in zip(sizes, shapes, out_dtypes):
            outs.append(buf[off:off + sz].reshape(shp).astype(dt))
            off += sz
        return jax.tree.unflatten(treedef, outs)

    return flat, unflatten


def fused_cada_update(params, h, vhat, grads, lr, *, b1=0.9, b2=0.999,
                      eps=1e-8, interpret=None):
    """Pytree-level fused CADA/AMSGrad step.

    Returns (params', h', vhat', ||θ'−θ||²). Padding lanes carry zero
    gradients, so their moments stay exactly zero and the update there is 0 —
    the norm is unaffected (eps > 0).
    """
    pf, unflat_p = _flatten_padded(params, jnp.float32)
    hf, unflat_m = _flatten_padded(h, jnp.float32)
    vhf, _ = _flatten_padded(vhat, jnp.float32)
    gf, _ = _flatten_padded(grads, jnp.float32)
    pt, ht, vht, sq = fused_amsgrad_flat(
        pf, hf, vhf, gf, lr, b1=b1, b2=b2, eps=eps, interpret=interpret)
    f32 = [jnp.float32] * len(jax.tree.leaves(h))
    p_dtypes = [l.dtype for l in jax.tree.leaves(params)]
    return (unflat_p(pt, p_dtypes), unflat_m(ht, f32),
            unflat_m(vht, f32), sq)


def diff_sq_norm(tree_a, tree_b, *, interpret=None):
    """||a − b||² over two same-structure pytrees (CADA rule LHS)."""
    af, _ = _flatten_padded(tree_a, jnp.float32)
    bf, _ = _flatten_padded(tree_b, jnp.float32)
    return diff_sq_norm_flat(af, bf, interpret=interpret)
