"""Jit'd public wrappers around the Pallas kernels.

Kernel-mode routing (the ``interpret`` flag on every flat op):

  * ``None`` (default) — on TPU, compile the Pallas kernel with Mosaic;
    elsewhere use the FUSED FLAT JNP fallback (same math on the same flat
    buffers, fused by XLA) so the hot paths and the test suite stay fast on
    CPU;
  * ``True``  — run the Pallas kernel in interpret mode (the kernel body
    executes as traced jnp, bit-faithful validation of the BlockSpec
    tiling);
  * ``False`` — force the compiled Pallas kernel.

The wrappers also own the BLOCK padding: arbitrary flat lengths are padded
with zeros up to whole kernel blocks and sliced back, so every pytree —
logreg through the LM path — takes the fused route (zero-padded gradients
leave zero moments and a zero update, so reductions are unaffected).

Sharded flat planes (the ``shard`` flag on the flat ops, a static
``distributed.sharding.FlatSharding``): the same kernels run SHARD-LOCAL
under a shard_map that is manual over the state-shard axes — each device
streams only its ``n_flat / shards`` slice (or its rows of the (M, n_flat)
planes) — and the scalar reductions (‖Δθ‖², the (M,) rule-LHS norms) are
completed with ONE psum of fp32 partials. The only cross-device bytes the
state math ever pays are those O(M) scalars; no plane is gathered.

``fused_cada_update`` is the pytree-level entry point used by the optimizer:
it flattens the parameter pytree into one padded fp32 stream, runs the fused
update, and scatters back — giving the one-HBM-pass optimizer step plus the
CADA rule's ||Δθ||² for free.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cada_update as _cu
from repro.kernels import ref as _ref
from repro.kernels import ssm_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_pallas(interpret) -> tuple[bool, bool]:
    """Resolve the 3-way ``interpret`` flag -> (use_pallas, interpret)."""
    if interpret is None:
        return jax.default_backend() == "tpu", False
    return True, bool(interpret)


def _pad_flat(arrs, block=_cu.BLOCK):
    """Zero-pad same-length flat buffers to a whole number of blocks."""
    n = arrs[0].shape[0]
    pad = (-n) % block
    if pad == 0:
        return arrs, n
    return [jnp.pad(a, ((0, pad),)) for a in arrs], n


def _pad_plane(a, block=_cu.BLOCK):
    """Zero-pad the flat axis of an (M, n) plane to whole blocks."""
    pad = (-a.shape[1]) % block
    return jnp.pad(a, ((0, 0), (0, pad))) if pad else a


def _shard_map(f, shard, in_specs, out_specs, manual):
    """shard_map manual over ``manual``, auto elsewhere (compat shim)."""
    from repro.launch.mesh import partial_auto_shard_map
    return partial_auto_shard_map(f, shard.mesh, in_specs, out_specs,
                                  manual)


# ------------------------------------------------------------------ flat ops

@partial(jax.jit, static_argnames=("b1", "b2", "eps", "interpret", "shard"))
def fused_amsgrad_flat(theta, h, vhat, grad, lr, *, b1=0.9, b2=0.999,
                       eps=1e-8, interpret=None, shard=None):
    """Fused AMSGrad/CADA step over arbitrary-length flat buffers.

    Returns (theta', h', vhat', ||update||²); moments keep their incoming
    storage dtype (fp32 or bf16 — see kernels/cada_update.py).

    ``shard`` (static FlatSharding, optional): run SHARD-LOCAL — manual
    shard_map over the state-shard axes, each device fusing its own
    ``n_flat / shards`` slice in one pass, with a single psum of the fp32
    ‖Δθ‖² partials. The global result is identical (the padding discipline
    makes every local slice self-contained).
    """
    if shard is not None and shard.axes:
        from jax.sharding import PartitionSpec as P
        spec = shard.server_spec()

        def local(t, hh, vh, g, lr_):
            t2, h2, vh2, sq = fused_amsgrad_flat(
                t, hh, vh, g, lr_, b1=b1, b2=b2, eps=eps,
                interpret=interpret)
            return t2, h2, vh2, jax.lax.psum(sq, shard.axes)

        return _shard_map(local, shard, (spec,) * 4 + (P(),),
                          (spec, spec, spec, P()), shard.axes)(
            theta, h, vhat, grad, jnp.asarray(lr, jnp.float32))
    pallas, interpret = _use_pallas(interpret)
    if not pallas:
        return _ref.amsgrad_ref(theta, h, vhat, grad, lr, b1=b1, b2=b2,
                                eps=eps)
    (t, hh, vh, g), n = _pad_flat([theta, h, vhat, grad])
    t2, h2, vh2, sq = _cu.fused_amsgrad_flat(t, hh, vh, g, lr, b1=b1, b2=b2,
                                             eps=eps, interpret=interpret)
    return t2[:n], h2[:n], vh2[:n], sq


@partial(jax.jit, static_argnames=("interpret",))
def diff_sq_norm_flat(a, b, *, interpret=None):
    pallas, interpret = _use_pallas(interpret)
    if not pallas:
        return _ref.diff_sq_norm_ref(a, b)
    (ap, bp), _ = _pad_flat([a, b])
    return _cu.diff_sq_norm_flat(ap, bp, interpret=interpret)


@partial(jax.jit, static_argnames=("m_total", "shard"))
def eq3_row_mean(plane, m_total, *, shard=None):
    """Eq. (3) server aggregate increment: Σ_rows(plane) / m_total.

    The row reduction is an ORDER-FIXED sequential accumulation over
    rows in DESCENDING row order (``fori_loop``), not XLA's tree
    reduction.  A fixed sequential order makes the result invariant to
    dropping all-zero rows: a masked dense ``(M, n)`` wire plane and the
    gathered ``(C, n)`` cohort plane holding only its nonzero rows (in
    ascending worker order) produce BIT-IDENTICAL fp32 aggregates, which
    is what lets the cohort-virtualized worker plane stay a drop-in for
    the dense plane.  (+0.0 addends are exact no-ops:
    the accumulator starts at +0.0 and IEEE-754 addition can only reach
    −0.0 from two −0.0 operands, so skipping zero rows never changes a
    bit.)  Pass ``m_total`` = the FULL worker count M even when ``plane``
    has only C cohort rows.

    ``shard``: under a sharded worker axis a cross-device sequential
    order is not expressible — fall back to the tree reduction (the
    sharded trainer plane is never the cohort parity oracle).
    """
    plane = plane.astype(jnp.float32)
    if shard is not None:
        return jnp.sum(plane, axis=0) / m_total

    rows = plane.shape[0]

    def body(i, acc):
        return acc + plane[rows - 1 - i]

    zero = jnp.zeros(plane.shape[1:], jnp.float32)
    return jax.lax.fori_loop(0, rows, body, zero) / m_total


@partial(jax.jit, static_argnames=("interpret", "shard"))
def batched_diff_sq_norm(a, b, *, interpret=None, shard=None):
    """(M,) per-worker ||a_m − b_m||² over (M, n) planes — the CADA rule
    LHS for all M workers in one pass (fp32 accumulate).

    The leading axis is polymorphic: a cohort-sized ``(C, n)`` plane (only
    the sampled workers' rows resident on device) takes the same kernel —
    per-row reductions never mix rows, so cohort rows are bit-identical
    to the same rows of the dense ``(M, n)`` pass.

    ``b`` is whatever second-gradient plane the eval dispatch produced —
    gathered per-worker rows, the stacked fused eval's second half, or
    the GROUPED plane scattered by stale-ring slot
    (``flat.grouped_second_plane``) — all land here as a dense (M, n)
    operand, so the LHS needs no re-gather and no grouping awareness.

    ``shard`` (static FlatSharding, optional): shard-local form — manual
    over the worker axis (each device sweeps only its own rows) and the
    plane's column axes, finishing the per-row partials with one psum over
    the column axes. Rows stay whole per device otherwise.
    """
    if shard is not None:
        from jax.sharding import PartitionSpec as P
        cols = shard.col_axes
        in_spec = shard.worker_spec()

        def local(al, bl):
            r = batched_diff_sq_norm(al, bl, interpret=interpret)
            return jax.lax.psum(r, cols) if cols else r

        return _shard_map(local, shard, (in_spec, in_spec),
                          P(shard.waxis), shard.plane_axes)(a, b)
    pallas, interpret = _use_pallas(interpret)
    if not pallas:
        d = a.astype(jnp.float32) - b.astype(jnp.float32)
        return jnp.sum(d * d, axis=1)
    ap, bp = (_pad_plane(x) for x in (a, b))
    return _cu.batched_diff_sq_norm_flat(ap, bp, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret", "shard"))
def batched_sq_norm(a, *, interpret=None, shard=None):
    """(M,) per-worker ||a_m||² over an (M, n) plane (``shard`` as in
    :func:`batched_diff_sq_norm`)."""
    if shard is not None:
        from jax.sharding import PartitionSpec as P
        cols = shard.col_axes

        def local(al):
            r = batched_sq_norm(al, interpret=interpret)
            return jax.lax.psum(r, cols) if cols else r

        return _shard_map(local, shard, (shard.worker_spec(),),
                          P(shard.waxis), shard.plane_axes)(a)
    pallas, interpret = _use_pallas(interpret)
    if not pallas:
        v = a.astype(jnp.float32)
        return jnp.sum(v * v, axis=1)
    return _cu.batched_sq_norm_flat(_pad_plane(a), interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "dblk", "interpret"))
def selective_scan(dt, x, a, b, c, *, chunk=_ss.DEFAULT_CHUNK,
                   dblk=_ss.DEFAULT_DBLK, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ss.selective_scan(dt, x, a, b, c, chunk=chunk, dblk=dblk,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("window", "q_blk", "kv_blk",
                                   "interpret"))
def flash_attention(q, k, v, *, window=0, q_blk=None, kv_blk=None,
                    interpret=None):
    """GQA flash attention via the Pallas kernel.

    q (B, S, Hq, hd); k/v (B, S, Hkv, hd). Each Q head is paired with its
    KV head and flattened onto the kernel's G axis.
    """
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = _default_interpret()
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    qg = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kg = jnp.repeat(k.transpose(0, 2, 1, 3), grp, axis=1).reshape(
        b * hq, s, hd)
    vg = jnp.repeat(v.transpose(0, 2, 1, 3), grp, axis=1).reshape(
        b * hq, s, hd)
    kw = {}
    if q_blk:
        kw["q_blk"] = q_blk
    if kv_blk:
        kw["kv_blk"] = kv_blk
    o = _fa.flash_attention_kernel(qg, kg, vg, window=window,
                                   interpret=interpret, **kw)
    return o.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)


# --------------------------------------------------------------- pytree ops

def _flatten_padded(tree, dtype, block=1024):
    """Concat all leaves (as ``dtype``) into one flat buffer padded to full
    VPU tiles. Returns (flat, unflatten_fn). Kernel-block padding happens
    inside the flat wrappers above, so small pytrees stay small here."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))

    def unflatten(buf, out_dtypes=None):
        out_dtypes = out_dtypes or dtypes
        outs, off = [], 0
        for sz, shp, dt in zip(sizes, shapes, out_dtypes):
            outs.append(buf[off:off + sz].reshape(shp).astype(dt))
            off += sz
        return jax.tree.unflatten(treedef, outs)

    return flat, unflatten


def fused_cada_update(params, h, vhat, grads, lr, *, b1=0.9, b2=0.999,
                      eps=1e-8, interpret=None):
    """Pytree-level fused CADA/AMSGrad step.

    Returns (params', h', vhat', ||θ'−θ||²). Padding lanes carry zero
    gradients, so their moments stay exactly zero and the update there is 0 —
    the norm is unaffected (eps > 0).
    """
    pf, unflat_p = _flatten_padded(params, jnp.float32)
    hf, unflat_m = _flatten_padded(h, jnp.float32)
    vhf, _ = _flatten_padded(vhat, jnp.float32)
    gf, _ = _flatten_padded(grads, jnp.float32)
    pt, ht, vht, sq = fused_amsgrad_flat(
        pf, hf, vhf, gf, lr, b1=b1, b2=b2, eps=eps, interpret=interpret)
    f32 = [jnp.float32] * len(jax.tree.leaves(h))
    p_dtypes = [l.dtype for l in jax.tree.leaves(params)]
    return (unflat_p(pt, p_dtypes), unflat_m(ht, f32),
            unflat_m(vht, f32), sq)


def diff_sq_norm(tree_a, tree_b, *, interpret=None):
    """||a − b||² over two same-structure pytrees (CADA rule LHS)."""
    af, _ = _flatten_padded(tree_a, jnp.float32)
    bf, _ = _flatten_padded(tree_b, jnp.float32)
    return diff_sq_norm_flat(af, bf, interpret=interpret)
