"""Pure-jnp oracles for every Pallas kernel (the tests' source of truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def amsgrad_ref(theta, h, vhat, grad, lr, *, b1=0.9, b2=0.999, eps=1e-8):
    """Reference fused AMSGrad/CADA update on flat fp32/bf16 buffers.

    Matches optim/adam.py (paper eqs. 2a-2c: v from v̂, ε inside the sqrt;
    v itself is a temporary — only {h, v̂} persist). Moments keep their
    incoming storage dtype; math runs in fp32 and the STORED (rounded)
    moment drives the update — the same dtype discipline as the Pallas
    kernel and the per-leaf reference stream (bit-identical for fp32).
    Returns (theta', h', vhat', ||update||²).
    """
    g = grad.astype(jnp.float32)
    h32 = h.astype(jnp.float32)
    vh32 = vhat.astype(jnp.float32)
    h_new = (b1 * h32 + (1.0 - b1) * g).astype(h.dtype)
    v_new = b2 * vh32 + (1.0 - b2) * g * g
    vhat_new = jnp.maximum(v_new, vh32).astype(vhat.dtype)
    upd = (-lr * h_new.astype(jnp.float32)
           / jnp.sqrt(eps + vhat_new.astype(jnp.float32)))
    theta_new = (theta.astype(jnp.float32) + upd).astype(theta.dtype)
    return theta_new, h_new, vhat_new, jnp.sum(upd * upd)


def diff_sq_norm_ref(a, b):
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d)


def selective_scan_ref(dt, x, a, b, c):
    """Reference selective scan (plain lax.scan over time).

    dt/x: (G, S, D); a: (G, D, N); b/c: (G, S, N).
    Returns y (G, S, D) fp32 (no D·x skip, no gating) and h_final (G, D, N).
    """
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    g, s, d = dt.shape
    n = a.shape[-1]

    def step(h, ins):
        dt_t, x_t, b_t, c_t = ins          # (G,D) (G,D) (G,N) (G,N)
        decay = jnp.exp(dt_t[..., None] * a)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("gdn,gn->gd", h, c_t)
        return h, y_t

    h0 = jnp.zeros((g, d, n), jnp.float32)
    swap = lambda t: jnp.swapaxes(t, 0, 1)   # noqa: E731 — time-major
    h_final, y = jax.lax.scan(step, h0, (swap(dt), swap(x), swap(b), swap(c)))
    return swap(y), h_final
