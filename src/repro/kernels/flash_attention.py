"""Flash (blockwise) causal attention — Pallas TPU kernel.

The TPU adaptation of the CUDA flash-attention idea: tile queries into
(q_blk, hd) VMEM blocks, stream KV blocks through VMEM, and carry the
running softmax state (m, l, acc) in fp32 scratch so the (S, S) score
matrix never touches HBM.

Grid = (B·H, S/q_blk, S/kv_blk) with the KV axis innermost (sequential on
TPU): scratch persists across KV steps, is initialized at kv==0 and the
normalized output is written at the LAST kv step. Causality is handled two
ways: fully-masked KV blocks (block_start > q_end) are skipped with
`pl.when` (no MXU work), diagonal blocks get an elementwise mask.

MXU alignment: q_blk/kv_blk default 128 and hd is padded by the wrapper to
a multiple of 128 if needed. GQA is handled by the wrapper mapping each Q
head to its KV head (the kernel sees one head pair per grid row).

Validated in interpret mode against models/attention.naive_attention.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK = 128
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, q_blk: int, kv_blk: int, n_kv: int, scale: float,
                  window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_blk
    k_start = ki * kv_blk
    # causal: the block is live unless it starts after the last query
    live = k_start <= q_start + q_blk - 1
    if window:
        live &= k_start + kv_blk - 1 >= q_start - window + 1

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale       # (q_blk, hd)
        k = k_ref[0].astype(jnp.float32)               # (kv_blk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                    # (q_blk, kv_blk)
        rel = (q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
               - (k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                     1)))
        mask = rel >= 0
        if window:
            mask &= rel < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, window: int = 0,
                           q_blk: int = DEFAULT_BLK,
                           kv_blk: int = DEFAULT_BLK,
                           interpret: bool = False):
    """q (G, S, hd), k/v (G, S, hd) — one KV head per G row (the ops.py
    wrapper expands GQA). Returns (G, S, hd) in q.dtype."""
    g, s, hd = q.shape
    q_blk = min(q_blk, s)
    kv_blk = min(kv_blk, s)
    assert s % q_blk == 0 and s % kv_blk == 0, (s, q_blk, kv_blk)
    n_kv = s // kv_blk
    grid = (g, s // q_blk, n_kv)
    scale = 1.0 / float(hd) ** 0.5

    qs = pl.BlockSpec((1, q_blk, hd), lambda gi, qi, ki: (gi, qi, 0))
    ks = pl.BlockSpec((1, kv_blk, hd), lambda gi, qi, ki: (gi, ki, 0))

    return pl.pallas_call(
        partial(_flash_kernel, q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv,
                scale=scale, window=window),
        grid=grid,
        in_specs=[qs, ks, ks],
        out_specs=qs,
        out_shape=jax.ShapeDtypeStruct((g, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((q_blk, 1), jnp.float32),
                        pltpu.VMEM((q_blk, 1), jnp.float32),
                        pltpu.VMEM((q_blk, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
