"""Selective-scan (Mamba1/Mamba2) — Pallas TPU kernel.

The CUDA selective-scan fuses the SSM recurrence
    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t x_t) ⊗ B_t ,   y_t = h_t · C_t
into one kernel so the (S, D, N) state trajectory never touches HBM. The TPU
adaptation keeps that insight but restructures for the VMEM hierarchy:

  * grid = (G, D/blk, S/chunk) with the CHUNK axis innermost — the TPU grid
    is executed sequentially, so a (blk, N) fp32 state tile lives in VMEM
    scratch and is carried across chunk steps (the Pallas equivalent of the
    CUDA per-threadblock register carry);
  * within a chunk the recurrence is a `fori_loop` over time; decay
    exp(Δ_t ⊙ A) and drive (Δ_t x_t) ⊗ B_t are computed IN the kernel from
    the (chunk, blk) Δ/x tiles and the (blk, N) A tile — the big (S, D, N)
    decay/drive tensors of the jnp reference are never materialized;
  * y_t = h_t · C_t is an N-contraction on the VPU (N = 16/64 ≪ 128 lanes:
    layout is state-minor; documented trade-off vs. transposing to put D on
    the lane axis, which the D-tiling already achieves for the heavy operand).

One kernel serves both variants via the group axis G:
  mamba1: G = batch,        D = d_inner,  A = per-(D, N) matrix
  mamba2: G = batch × heads, D = head_dim, A = a_h · 1 (broadcast per group)

Validated in ``interpret=True`` mode against ``ref.py``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
DEFAULT_DBLK = 128


def _scan_kernel(dt_ref, x_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, h_scr,
                 *, chunk: int):
    """One (group, D-block) tile; called sequentially over S/chunk chunks.

    Block shapes (leading group dim squeezed by the BlockSpec):
      dt/x: (chunk, blk)   a: (blk, N)   b/c: (chunk, N)
      y: (chunk, blk)      hfin: (blk, N)   h_scr: (blk, N) fp32 scratch
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]                              # (blk, N) fp32

    def step(t, h):
        dt_t = dt_ref[t, :].astype(jnp.float32)            # (blk,)
        x_t = x_ref[t, :].astype(jnp.float32)              # (blk,)
        b_t = b_ref[t, :].astype(jnp.float32)              # (N,)
        c_t = c_ref[t, :].astype(jnp.float32)              # (N,)
        decay = jnp.exp(dt_t[:, None] * a)                 # (blk, N)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h
    hfin_ref[...] = h                            # last chunk's write wins


def selective_scan(dt, x, a, b, c, *, chunk: int = DEFAULT_CHUNK,
                   dblk: int = DEFAULT_DBLK, interpret: bool = False):
    """Fused selective scan.

    Args:
      dt: (G, S, D) fp32 — softplus'd step sizes Δ.
      x:  (G, S, D)      — post-conv/silu inputs.
      a:  (G, D, N) fp32 — negative-definite state matrix (mamba2 passes the
          per-head scalar broadcast to (D, N)).
      b, c: (G, S, N)    — input/output projections B_t, C_t.
    Returns:
      y: (G, S, D) fp32 — WITHOUT the D·x skip / gating (done by the caller).
      h_final: (G, D, N) fp32.
    """
    g, s, d = dt.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    dblk = min(dblk, d)
    assert s % chunk == 0, (s, chunk)
    assert d % dblk == 0, (d, dblk)

    grid = (g, d // dblk, s // chunk)
    sd = pl.BlockSpec((1, chunk, dblk), lambda gi, di, ci: (gi, ci, di))
    sn = pl.BlockSpec((1, chunk, n), lambda gi, di, ci: (gi, ci, 0))
    sa = pl.BlockSpec((1, dblk, n), lambda gi, di, ci: (gi, di, 0))

    def squeeze_lead(kernel):
        # Block leading dims of size 1 arrive as real axes; index them away.
        def wrapped(dt_r, x_r, a_r, b_r, c_r, y_r, hf_r, h_scr):
            kernel(dt_r.at[0], x_r.at[0], a_r.at[0], b_r.at[0], c_r.at[0],
                   y_r.at[0], hf_r.at[0], h_scr)
        return wrapped

    y, hfin = pl.pallas_call(
        squeeze_lead(partial(_scan_kernel, chunk=chunk)),
        grid=grid,
        in_specs=[sd, sd, sa, sn, sn],
        out_specs=(sd, sa),
        out_shape=(
            jax.ShapeDtypeStruct((g, s, d), jnp.float32),
            jax.ShapeDtypeStruct((g, d, n), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((dblk, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, a, b, c)
    return y, hfin
