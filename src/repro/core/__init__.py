"""The paper's primary contribution: CADA rules, server/worker engine, and
the per-iteration / local-update baselines it is benchmarked against."""
from repro.core.engine import CADAEngine, EngineState, make_sampler
from repro.core.local_update import LocalState, LocalUpdateEngine
from repro.core.rules import RULES, CommRule

__all__ = [
    "CADAEngine", "EngineState", "make_sampler",
    "LocalUpdateEngine", "LocalState",
    "CommRule", "RULES",
]
