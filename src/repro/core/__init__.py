"""The paper's primary contribution: CADA rules, server/worker engine, and
the per-iteration / local-update baselines it is benchmarked against.

The per-rule behaviour lives in the strategy layer (``repro.core.comm``);
``CADAEngine`` and the pod trainer both run the same ``comm_round`` core.
"""
from repro.core.comm import (CommState, CommStrategy, comm_round,
                             init_comm_state, record_progress, register,
                             strategy_for, strategy_kinds)
from repro.core.engine import CADAEngine, EngineState, make_sampler
from repro.core.local_update import LocalState, LocalUpdateEngine
from repro.core.rules import RULES, CommRule

__all__ = [
    "CADAEngine", "EngineState", "make_sampler",
    "LocalUpdateEngine", "LocalState",
    "CommRule", "RULES",
    "CommState", "CommStrategy", "comm_round", "init_comm_state",
    "record_progress", "register", "strategy_for", "strategy_kinds",
]
