"""Pluggable communication-rule layer: ONE Algorithm-1 core for every engine.

This module owns everything about the paper's adaptive-communication round
that is independent of where it runs. Both the reference engine
(``core/engine.py``, vmap-simulated workers) and the pod trainer
(``distributed/trainer.py``, mesh runtime) consume :func:`comm_round`;
neither carries per-rule branches anymore.

Split of responsibility:

  * a :class:`CommStrategy` subclass owns what is SPECIFIC to one rule —
    its extra state slices (:meth:`init_extras` / :meth:`extras_specs`),
    its LHS given fresh gradients (:meth:`lhs`), its post-upload state
    transition (:meth:`post_upload`), its wire format
    (:meth:`transform_delta`), and its grad-evals/bytes accounting;
  * :func:`comm_round` owns what every rule shares — the RHS ring buffer
    of recent server progress, the max-staleness override, the eq. (3)
    innovation aggregation with the quantize hook, and the upload metrics.

Paper equation ↔ class mapping:

  ==========  =======================  ====================================
  eq. (5)     :class:`LAGStrategy`     naive stochastic LAG (§2.1 baseline)
  eq. (7)     :class:`CADA1Strategy`   SVRG-style snapshot innovation
  eq. (10)    :class:`CADA2Strategy`   same-sample two-iterate difference
  —           :class:`AlwaysStrategy`  threshold never satisfied ⇒ Adam
  beyond      :class:`CompressedInnovationStrategy`  quantized-innovation
  paper                                gating (arXiv 2111.00705 style)
  beyond      :class:`LAQStrategy`     full LAQ: error-feedback residual +
  paper                                quantized wire [Sun et al., 2019]
  beyond      :class:`TopKStrategy`    top-k sparsified innovation with
  paper                                error feedback (arXiv 2112.04088)
  beyond      :class:`AVPStrategy`     per-worker variance-adaptive upload
  paper                                period (arXiv 2007.06134 style)
  ==========  =======================  ====================================

Adding a rule is a one-class change: subclass :class:`CommStrategy`,
decorate with :func:`register`, and every engine, launcher, policy, and
benchmark picks it up through :func:`strategy_for` / :func:`strategy_kinds`.

All math here is dtype-polymorphic: computation happens in fp32, storage
follows the dtypes of the incoming state trees (the pod trainer stores
stale trees in bf16 — the cast point IS the wire format of the gated
cross-pod collective).
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.core.flat import (batch_has_local_axis, local_steps_vector,
                             per_worker_quantize_dequantize_flat,
                             per_worker_topk_extract_flat,
                             per_worker_topk_sparsify_flat, spec_dim)
from repro.core.quantize import (ef_correct, ef_residual,
                                 per_worker_quantize_dequantize,
                                 per_worker_topk_sparsify, topk_count)
from repro.core.rules import CommRule
from repro.kernels import ops as kops
from repro.utils.trees import tree_size


# ------------------------------------------------------------------ state

class CommState(NamedTuple):
    """The rule-agnostic communication state of Algorithm 1.

    ``extras`` is the strategy-owned slice dict (e.g. CADA1's snapshot θ̃
    and stored innovation δ̃; CADA2's per-worker θ^{k−τ_m}); engines treat
    it as an opaque pytree.
    """
    nabla: Any               # ∇^{k-1}: aggregated stale gradient (eq. 3)
    worker_grads: Any        # per-worker last contributed ∇ℓ(θ̂_m;ξ̂_m)
    staleness: jnp.ndarray   # τ_m, (M,) int32
    diff_hist: jnp.ndarray   # (d_max,) ring buffer of ||θ^{k+1-d}−θ^{k-d}||²
    extras: dict             # strategy-owned per-rule slices


class CommContext(NamedTuple):
    """Everything a strategy may consult when computing its LHS/transition.

    ``vgrad(params, batch) -> (losses, grads)`` evaluates per-worker
    gradients of broadcast params; ``vgrad_per`` takes an (M,)-leading
    params tree. Both are supplied by the engine (vmap or pod shard_map).
    """
    params: Any
    batch: Any
    fresh: Any               # per-worker fresh gradients at θ^k, fp32
    comm: CommState
    step: jnp.ndarray
    m: int
    vgrad: Callable
    vgrad_per: Callable
    participation: Any = None  # (M,) bool round-participation mask | None


class CommRoundResult(NamedTuple):
    losses: jnp.ndarray      # (M,) per-worker losses at θ^k
    comm: CommState          # post-round state (diff_hist NOT yet updated —
    #                          call record_progress with ||Δθ||² after the
    #                          server update)
    upload: jnp.ndarray      # (M,) bool upload mask
    metrics: dict


# ------------------------------------------------------------ tree helpers

def per_worker_sq_norm(tree) -> jnp.ndarray:
    """(M,) squared norms of an M-leading pytree, accumulated in fp32."""
    tot = 0.0
    for leaf in jax.tree.leaves(tree):
        axes = tuple(range(1, leaf.ndim))
        tot = tot + jnp.sum(jnp.square(leaf.astype(jnp.float32)), axis=axes)
    return tot


def select_rows(mask, new, old):
    """Per-worker select: rows of ``new`` where ``mask``, else ``old``
    (result keeps ``old``'s storage dtype)."""
    def leaf(n, o):
        mm = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(mm, n.astype(o.dtype), o)
    return jax.tree.map(leaf, new, old)


def broadcast_to_workers(tree, m: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


def _f32(tree):
    return jax.tree.map(lambda x: x.astype(jnp.float32), tree)


# ------------------------------------------------------- cadence adaptation

def adapt_period(period, grow, p_min, p_max):
    """The ONE home of integer cadence adaptation (±1, clipped to bounds).

    Shared by the two cadence axes of the layer:

      * avp's per-worker UPLOAD PERIODS (arXiv 2007.06134 style) — a
        period GROWS while the innovation energy stays under the shared
        recent-progress RHS (communication is not earning its bytes) and
        shrinks when it clears it;
      * the sim's per-worker LOCAL-STEP counts H for delta-payload rules
        (adaptive periodic averaging, Jiang & Agrawal) — H GROWS while a
        round's measured communication time exceeds its compute time
        (amortize the link over more local work) and shrinks when compute
        dominates.

    ``period``/``grow`` may be scalars or (M,) vectors; returns int32.
    """
    period = jnp.asarray(period, jnp.int32)
    nxt = jnp.where(grow, period + 1, period - 1)
    return jnp.clip(nxt, p_min, p_max)


# -------------------------------------------------------------- strategies

class CommStrategy:
    """Base class: one instance per (rule hyper-params, kind) pair.

    Subclasses override the four rule-specific concerns. The base class
    implements the pieces most rules share: no extra state, the LAQ-style
    optional quantization of the uploaded innovation, 32-bit uploads, and
    one gradient evaluation per iteration.
    """

    kind: str = "?"
    #: worker-side gradient evaluations per iteration (paper §2.2). For
    #: delta-payload rules this is per LOCAL iteration — a round of h
    #: local steps charges h evaluations.
    grad_evals_per_iter: int = 1
    #: PAYLOAD AXIS: False ⇒ the round ships one fresh gradient per
    #: iteration and gates it per worker (the 8 Algorithm-1 rules). True ⇒
    #: the worker runs H local optimizer steps between rounds and ships
    #: the accumulated MODEL DELTA θ^k − θ_m^(H) (local_momentum /
    #: fedadam): the round substitutes :meth:`local_payload` /
    #: :meth:`flat_local_payload` for the fresh eval, uploads always
    #: (lhs ≡ +inf — cadence lives in H, not in skipping), and the rule
    #: prescribes its server optimizer via :meth:`server_optimizer`.
    #: Because worker_grads then telescopes to the last shipped payload,
    #: ∇̄ ≡ mean_m(payload) exactly and eq. (3) becomes periodic
    #: averaging / FedAdam.
    delta_payload: bool = False
    #: True ⇒ the rule keeps NO innovation state (engines may drop the
    #: whole CommState and run the lean distributed-baseline path)
    stateless: bool = False
    #: flat-extras keys that are SHARED across workers (not (M,)-leading):
    #: the event-driven async runtime (repro.sim) slices every other extras
    #: entry to a single worker row when it gates one worker at a time, and
    #: passes these through whole (e.g. CADA1's snapshot θ̃).
    async_shared_extras: tuple = ()
    #: flat-extras keys that belong to the stale-iterate RING family
    #: (:meth:`second_eval_indexed`): neither shared nor per-worker-sliced.
    #: The async runtime SKIPS these on slice/merge and instead synthesizes
    #: a one-row ring per gate from the worker's own stale iterate via
    #: :meth:`async_indexed_row` — the host event loop tracks each worker's
    #: θ^{k−τ_m} exactly, so the bounded-slot ring (which assumes the sync
    #: engine's staleness cap) is never consulted asynchronously.
    async_indexed_extras: tuple = ()

    def __init__(self, rule: CommRule):
        self.rule = rule

    # ---- state slices
    def init_extras(self, params, m: int, make_grad_zeros, bcast) -> dict:
        """Strategy-owned state. ``make_grad_zeros()`` returns a gradient-
        shaped zero tree in the engine's comm storage dtype; ``bcast(t, m)``
        prepends the worker axis."""
        del params, m, make_grad_zeros, bcast
        return {}

    def extras_specs(self, param_spec, worker_param_spec,
                     worker_grad_spec) -> dict:
        """PartitionSpec tree matching :meth:`init_extras` (pod trainer)."""
        del param_spec, worker_param_spec, worker_grad_spec
        return {}

    # ---- per-round hooks
    def pre_step(self, extras: dict, params, k) -> dict:
        """Start-of-iteration transition (e.g. CADA1 snapshot refresh)."""
        del params, k
        return extras

    def lhs(self, ctx: CommContext, extras: dict):
        """Rule LHS given fresh gradients: returns ((M,) lhs, cache).

        ``cache`` is handed back to :meth:`post_upload` so work computed
        for the LHS (e.g. CADA1's fresh innovation) is not redone.
        """
        raise NotImplementedError

    def post_upload(self, extras: dict, cache, upload, ctx: CommContext
                    ) -> dict:
        """State transition after the upload mask is known."""
        del cache, upload, ctx
        return extras

    def transform_delta(self, delta):
        """Wire format of the uploaded innovation δ_m (quantize hook).

        Both sides apply the same round-trip so the server's stale worker
        copies stay exactly in sync with what each worker transmitted.
        """
        if self.rule.quantize_bits:
            return per_worker_quantize_dequantize(
                delta, self.rule.quantize_bits)
        return delta

    def wire_delta(self, ctx: CommContext, extras: dict, cache, delta):
        """The innovation that actually rides the wire.

        ``delta`` is the raw fp32 innovation fresh − stale; the default is
        the stateless :meth:`transform_delta`. Strategies whose wire
        consults per-worker state (error-feedback residuals) or whose LHS
        already computed the compressed plane (``cinn`` gates on
        ||Q_b(δ)||²) override this to reuse ``cache`` instead of
        compressing a second time.
        """
        del ctx, extras, cache
        return self.transform_delta(delta)

    # ---- payload/cadence hooks (delta_payload rules only)
    def server_optimizer(self):
        """The server optimizer a delta-payload rule PRESCRIBES (an
        optim protocol object), or None for gradient-payload rules (any
        server optimizer composes). Engines use this as the default when
        none is passed: sgd(1.0) turns the mean delta into periodic
        model averaging; a server Adam makes it FedAdam."""
        return None

    def local_payload(self, extras: dict, params, batch, m: int, vgrad_per,
                      h_steps):
        """Pytree local-step payload: run each worker's local optimizer
        from θ^k over the (H, M, b, ...) batch and return
        ``(losses, payload, cache)`` — (M,) mean loss over the worker's
        active steps, the (M,)-leading fp32 model-delta tree
        θ^k − θ_m^(h), and a cache for :meth:`post_upload` (e.g. the
        post-run local momenta). ``h_steps`` is the (M,) int32 active
        step count (rows beyond a worker's h_w are padding and must not
        change its state)."""
        raise NotImplementedError

    # ---- flat-plane hooks (core/flat.py)
    # The hot-path twin of the pytree hooks above: gradient-shaped
    # innovation state lives on packed (M, n_flat) planes and the LHS is a
    # batched one-pass norm, while PARAMETER-shaped state (snapshots,
    # stale iterates) stays in tree form — it feeds the model's gradient
    # evaluation, which needs the pytree anyway. Per-rule math lives ONCE
    # per concern on this class; the fused-vs-reference engine parity test
    # pins the flat and pytree forms against each other for every rule.

    def init_flat_extras(self, layout, params, params_flat, m: int,
                         grad_dtype) -> dict:
        """Strategy-owned state for the flat plane (twin of
        :meth:`init_extras`)."""
        del layout, params, params_flat, m, grad_dtype
        return {}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis: str,
                          P, col_axes: tuple = ()) -> dict:
        """PartitionSpec dict matching :meth:`init_flat_extras`.
        ``col_axes`` are the state-shard axes of the flat dim of
        (M, n_flat) planes (the server axes minus the worker axis)."""
        del param_spec, worker_param_spec, waxis, P, col_axes
        return {}

    def pooled_extras(self) -> tuple:
        """Flat-extras keys that are O(M·n) per-worker PLANES — the entries
        the cohort-virtualized plane (``flat.flat_cohort_round``) keeps in
        the host-resident :class:`~repro.core.flat.WorkerPool` and streams
        onto device C rows at a time (CADA1's ``worker_delta``, laq/topk's
        error-feedback ``residual``). Everything else stays device-resident
        in the cohort server state: shared pytrees (snapshots, rings) and
        (M,)-scalar vectors (slots, periods) are O(n) / O(M), not O(M·n).
        A pooled entry's flat hooks see a (C, n_flat) rows view; hooks that
        touch NON-pooled (M,)-length extras must index by ``ctx.cohort``
        when it is set (see CADA2/AVP).

        Writeback-ordering contract (the pipelined cohort driver): the
        host pool is written back LAZILY — under ``pipeline=True`` round
        i's rows land in the pool one round late, with overlapping
        consecutive-cohort rows forwarded on device instead
        (``flat.run_cohort_rounds``). Hooks therefore must treat the
        in-round ``rows`` / returned extras as the single source of truth
        for pooled state and must NEVER read the host pool mid-round; all
        current hooks are pure device functions of their inputs, which is
        exactly what makes the transfer reordering bit-exact."""
        return ()

    def flat_pre_step(self, extras: dict, params, params_flat, k) -> dict:
        del params, params_flat, k
        return extras

    def second_eval_shared(self, extras: dict):
        """Params PYTREE at which every worker evaluates its second
        gradient (CADA1's snapshot θ̃), or None. Shared points keep the
        broadcast-θ evaluation form XLA collapses best."""
        del extras
        return None

    def second_eval_per_worker(self, extras: dict):
        """(M,)-leading params PYTREE of per-worker evaluation points
        (CADA2's stale iterates θ^{k−τ_m}), or None.

        LEGACY dense form: a registered rule that needs per-worker points
        should prefer :meth:`second_eval_indexed` (the stale-iterate ring —
        O(R·n) instead of O(M·n) eval-point state); this hook remains for
        external strategies that carry a dense (M,)-leading plane."""
        del extras
        return None

    def second_eval_indexed(self, extras: dict):
        """The INDEXED second-evaluation family: ``(ring, slot)`` where
        ``ring`` is an (R,)-leading params pytree of DISTINCT evaluation
        points and ``slot`` is the (M,) int32 row index of each worker's
        point — or None when the rule has no second evaluation.

        ``slot=None`` means R == 1 and every worker shares row 0 (the
        degenerate ring: CADA1's snapshot) — the eval dispatch then keeps
        the collapsed broadcast-θ form XLA fuses best. The default adapts
        :meth:`second_eval_shared` into that degenerate ring, so every
        shared-point rule is ring-indexed for free with identical numerics.

        The staleness cap bounds R: at most ``min(M, max_delay) + 1``
        distinct global iterates can appear among M stale copies (see
        :class:`CADA2Strategy`), which is what drops CADA2's eval-point
        state O(M·n) → O(D·n) and the second eval's weight traffic M× → R×
        (``flat.grouped_second_plane``).
        """
        shared = self.second_eval_shared(extras)
        if shared is None:
            return None
        return jax.tree.map(lambda x: x[None], shared), None

    def async_indexed_row(self, stale_params) -> dict:
        """Synthesize the one-row ``async_indexed_extras`` entries for a
        single async gate call from the worker's own stale iterate
        ``stale_params`` (the exact θ the worker last uploaded against,
        tracked host-side by the event loop)."""
        del stale_params
        return {}

    def flat_lhs(self, ctx, extras: dict):
        """Rule LHS on the flat plane: ((M,) lhs, cache)."""
        raise NotImplementedError

    def flat_post_upload(self, extras: dict, cache, upload, ctx) -> dict:
        del cache, upload, ctx
        return extras

    def transform_delta_flat(self, layout, delta):
        """Wire format of the uploaded innovation on the (M, n_flat) plane
        (per-worker, per-leaf-segment scales — bit-identical to
        :meth:`transform_delta`)."""
        if self.rule.quantize_bits:
            return per_worker_quantize_dequantize_flat(
                layout, delta, self.rule.quantize_bits)
        return delta

    def flat_wire_delta(self, ctx, extras: dict, cache, delta):
        """Flat-plane twin of :meth:`wire_delta`."""
        del extras, cache
        return self.transform_delta_flat(ctx.layout, delta)

    def flat_sparse_wire(self, ctx, extras: dict, cache, delta):
        """Optional TRUE sparse wire: ((M, K) values, (M, K) int32 global
        indices) that replace the dense plane on the simulated collective,
        or None (the default — dense wire). Only rules whose compressor
        leaves a fixed-size support (topk) can ship one."""
        del ctx, extras, cache, delta
        return None

    def flat_local_payload(self, layout, extras: dict, params, params_flat,
                           batch, m: int, vgrad_per, h_steps):
        """Flat-plane twin of :meth:`local_payload`: returns
        ``(losses, payload, cache)`` with the payload a packed
        (M, n_flat) fp32 plane. ``batch`` leads with the H axis; the
        local run is a ``lax.scan`` over it with per-worker masking at
        ``h_steps``."""
        raise NotImplementedError

    # ---- accounting
    @property
    def bits_per_entry(self) -> int:
        return self.rule.quantize_bits or 32

    def bytes_per_upload(self, n_params: int) -> float:
        return n_params * self.bits_per_entry / 8.0

    @property
    def wire_format(self) -> str:
        """Which ledger bucket this rule's wire fills — ``dense``,
        ``quantized``, or ``sparse`` (``obs.metrics.CommLedger`` splits
        bytes-up by this)."""
        return "quantized" if self.bits_per_entry < 32 else "dense"


STRATEGIES: dict[str, type[CommStrategy]] = {}


def register(cls: type[CommStrategy]) -> type[CommStrategy]:
    STRATEGIES[cls.kind] = cls
    return cls


def strategy_kinds() -> tuple[str, ...]:
    return tuple(STRATEGIES)


def strategy_for(rule: CommRule) -> CommStrategy:
    try:
        return STRATEGIES[rule.kind](rule)
    except KeyError:
        raise ValueError(
            f"no communication strategy registered for kind={rule.kind!r}; "
            f"known: {strategy_kinds()}") from None


@register
class AlwaysStrategy(CommStrategy):
    """Threshold never satisfied ⇒ plain distributed Adam/AMSGrad."""
    kind = "always"
    stateless = True

    def lhs(self, ctx, extras):
        return jnp.full((ctx.m,), jnp.inf, jnp.float32), None

    def flat_lhs(self, ctx, extras):
        return jnp.full((ctx.m,), jnp.inf, jnp.float32), None


@register
class LAGStrategy(CommStrategy):
    """Eq. (5): naive stochastic LAG — LHS compares gradients drawn at
    DIFFERENT samples, so its variance never vanishes (§2.1 shows it stops
    skipping late in training; reproduced as a baseline)."""
    kind = "lag"

    def lhs(self, ctx, extras):
        diff = jax.tree.map(
            lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
            ctx.fresh, ctx.comm.worker_grads)
        return per_worker_sq_norm(diff), None

    def flat_lhs(self, ctx, extras):
        return kops.batched_diff_sq_norm(
            ctx.fresh, ctx.comm.worker_grads.astype(jnp.float32),
            interpret=ctx.interpret, shard=ctx.shard), None


@register
class CADA1Strategy(CommStrategy):
    """Eq. (7): SVRG-style innovation vs. a snapshot θ̃ refreshed every D
    iterations — LHS is ||δ̃_m^k − δ̃_m^{k−τ}||² with
    δ̃_m = ∇ℓ(θ^k;ξ) − ∇ℓ(θ̃;ξ) evaluated at the SAME sample."""
    kind = "cada1"
    grad_evals_per_iter = 2
    async_shared_extras = ("snapshot",)

    def init_extras(self, params, m, make_grad_zeros, bcast):
        return {"snapshot": params,
                "worker_delta": bcast(make_grad_zeros(), m)}

    def extras_specs(self, param_spec, worker_param_spec, worker_grad_spec):
        return {"snapshot": param_spec, "worker_delta": worker_grad_spec}

    def pre_step(self, extras, params, k):
        refresh = (k % self.rule.max_delay) == 0
        snapshot = jax.tree.map(
            lambda s, p: jnp.where(refresh, p, s),
            extras["snapshot"], params)
        return {**extras, "snapshot": snapshot}

    def lhs(self, ctx, extras):
        _, snap_grads = ctx.vgrad(extras["snapshot"], ctx.batch)
        delta_fresh = jax.tree.map(
            lambda f, g: f.astype(jnp.float32) - g.astype(jnp.float32),
            ctx.fresh, snap_grads)
        diff = jax.tree.map(
            lambda a, b: a - b.astype(jnp.float32),
            delta_fresh, extras["worker_delta"])
        return per_worker_sq_norm(diff), delta_fresh

    def post_upload(self, extras, delta_fresh, upload, ctx):
        return {**extras,
                "worker_delta": select_rows(upload, delta_fresh,
                                            extras["worker_delta"])}

    # ---- flat plane: θ̃ stays a pytree (it feeds vgrad; the shared-point
    # evaluation keeps the broadcast form XLA collapses); the innovation
    # state δ̃ is a packed (M, n_flat) plane.
    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        # copy: θ̃ must not alias the caller's params (donation)
        return {"snapshot": jax.tree.map(jnp.copy, params),
                "worker_delta": jnp.zeros((m, layout.n_flat), grad_dtype)}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        return {"snapshot": param_spec,
                "worker_delta": P(waxis, spec_dim(col_axes))}

    def flat_pre_step(self, extras, params, params_flat, k):
        return self.pre_step(extras, params, k)

    def pooled_extras(self):
        # δ̃ is the one O(M·n) plane; θ̃ is shared and stays on device
        return ("worker_delta",)

    def second_eval_shared(self, extras):
        return extras["snapshot"]

    def flat_lhs(self, ctx, extras):
        delta_fresh = ctx.fresh - ctx.second
        lhs = kops.batched_diff_sq_norm(
            delta_fresh, extras["worker_delta"].astype(jnp.float32),
            interpret=ctx.interpret, shard=ctx.shard)
        return lhs, delta_fresh

    def flat_post_upload(self, extras, delta_fresh, upload, ctx):
        wd = extras["worker_delta"]
        return {**extras,
                "worker_delta": jnp.where(upload[:, None],
                                          delta_fresh.astype(wd.dtype), wd)}


@register
class CADA2Strategy(CommStrategy):
    """Eq. (10): same-sample two-iterate difference — LHS is
    ||∇ℓ(θ^k;ξ_m^k) − ∇ℓ(θ^{k−τ_m};ξ_m^k)||², each worker re-evaluating
    its CURRENT sample at its last-communicated iterate."""
    kind = "cada2"
    grad_evals_per_iter = 2

    def init_extras(self, params, m, make_grad_zeros, bcast):
        return {"worker_params": bcast(params, m)}

    def extras_specs(self, param_spec, worker_param_spec, worker_grad_spec):
        return {"worker_params": worker_param_spec}

    def lhs(self, ctx, extras):
        _, stale_now = ctx.vgrad_per(extras["worker_params"], ctx.batch)
        diff = jax.tree.map(
            lambda f, g: f.astype(jnp.float32) - g.astype(jnp.float32),
            ctx.fresh, stale_now)
        return per_worker_sq_norm(diff), None

    def post_upload(self, extras, cache, upload, ctx):
        return {**extras,
                "worker_params": select_rows(
                    upload, broadcast_to_workers(ctx.params, ctx.m),
                    extras["worker_params"])}

    # ---- flat plane: the STALE-ITERATE RING. The staleness cap means at
    # most min(M, D)+1 distinct global iterates can ever appear among the M
    # stale copies θ^{k−τ_m} (an un-capped worker has τ ≤ D−1 when it
    # skips, so keepers reference ≤ min(M−1, D−1) distinct iterates and the
    # uploaders add one more) — so instead of the dense (M,)-leading
    # ``worker_params`` pytree (O(M·n) eval-point state, the reference
    # ``init_extras`` above keeps it as the oracle) the flat plane stores:
    #
    #   * ``ring``         — (R,)-leading params pytree of distinct iterates
    #   * ``slot``         — (M,) int32: each worker's ring row
    #   * ``ring_version`` — (R,) int32: 1 + the step each row was written
    #                        (0 = the shared init row), the eviction order
    #
    # ``ring[slot]`` reproduces the dense plane BIT-EXACTLY (pinned by
    # tests/test_stale_ring.py), so masks/staleness/params cannot move.
    def ring_rows(self, m: int) -> int:
        """R = min(M, max_delay) + 1 — the occupancy bound above."""
        return min(m, self.rule.max_delay) + 1

    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        del layout, params_flat, grad_dtype
        rr = self.ring_rows(m)
        return {
            "ring": jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (rr,) + p.shape), params),
            "slot": jnp.zeros((m,), jnp.int32),
            "ring_version": jnp.zeros((rr,), jnp.int32),
        }

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        del worker_param_spec, waxis, col_axes
        # ring rows shard like params (leading R axis replicated — R is
        # small); the index vectors ride with the other (M,) scalars
        return {"ring": jax.tree.map(lambda s: P(None, *s), param_spec,
                                     is_leaf=lambda x: isinstance(x, P)),
                "slot": P(None),
                "ring_version": P(None)}

    def second_eval_indexed(self, extras):
        return extras["ring"], extras["slot"]

    def flat_lhs(self, ctx, extras):
        return kops.batched_diff_sq_norm(ctx.fresh, ctx.second,
                                         interpret=ctx.interpret,
                                         shard=ctx.shard), None

    def flat_post_upload(self, extras, cache, upload, ctx):
        ring, slot = extras["ring"], extras["slot"]
        version = extras["ring_version"]
        rr = version.shape[0]
        # Refcount the rows still held by NON-uploading workers; write θ^k
        # into the oldest unreferenced row. Full participation always has
        # one free (see the bound above). Under partial participation an
        # offline worker's ancient row can be evicted — but the version
        # ordering guarantees the evicted row is ≥ D versions old, so that
        # worker's next upload is already staleness-cap-forced and the
        # garbage LHS it reads never decides anything (masks stay exact;
        # only the unpinned mean_lhs metric can move).
        #
        # Cohort rounds (ctx.cohort set): ``upload`` covers only the C
        # sampled rows, but the refcount must span ALL M workers — an
        # offline worker keeps its row exactly like a dense-plane
        # non-participant (keep=1), so the two planes pick the same
        # eviction slot and stay bit-identical.
        if ctx.cohort is not None:
            keep = jnp.ones_like(slot).at[ctx.cohort].set(
                jnp.where(upload, 0, 1).astype(jnp.int32))
            new_slot = lambda s: slot.at[ctx.cohort].set(
                jnp.where(upload, s, slot[ctx.cohort]))
        else:
            keep = jnp.where(upload, 0, 1).astype(jnp.int32)
            new_slot = lambda s: jnp.where(upload, s, slot)
        refs = jnp.zeros((rr,), jnp.int32).at[slot].add(keep)
        s = jnp.argmin(version + jnp.where(refs > 0, jnp.int32(2 ** 30), 0))

        def write(rv):
            rg, ver = rv
            rg = jax.tree.map(
                lambda row, p: row.at[s].set(p.astype(row.dtype)),
                rg, ctx.params)
            return rg, ver.at[s].set(ctx.step.astype(jnp.int32) + 1)

        ring, version = jax.lax.cond(jnp.any(upload), write, lambda rv: rv,
                                     (ring, version))
        return {**extras,
                "ring": ring,
                "slot": new_slot(s),
                "ring_version": version}

    # ---- async (repro.sim): the ring's occupancy bound assumes the sync
    # engine's round-global staleness cap; free-running workers break it.
    # The event loop instead tracks each worker's exact stale iterate
    # host-side (a Python reference — GC keeps at most τ-bounded distinct
    # server pytrees alive) and the gate sees a one-row ring.
    async_indexed_extras = ("ring", "slot", "ring_version")

    def async_indexed_row(self, stale_params):
        return {"ring": jax.tree.map(lambda x: x[None], stale_params),
                "slot": jnp.zeros((1,), jnp.int32),
                "ring_version": jnp.zeros((1,), jnp.int32)}


@register
class CompressedInnovationStrategy(CommStrategy):
    """Beyond-paper: compressed-innovation gating (the rule family of LAQ
    [Sun et al., 2019] and *Communication-Compressed Adaptive Gradient
    Method* (arXiv 2111.00705)).

    The worker forms its innovation δ_m = ∇ℓ(θ^k;ξ_m^k) − θ̂-contribution,
    quantizes it to ``quantize_bits`` (default 8) — the b-bit code IS what
    would ride the wire — and uploads only when the quantized innovation
    carries enough energy relative to recent server progress:
    ||Q_b(δ_m)||² > RHS. One gradient evaluation per iteration (the stale
    term is the stored contribution, no re-evaluation), and uploads are
    accounted at b bits per entry.

    The quantized plane computed for the gate IS the wire: ``lhs`` hands
    it back as the strategy cache and :meth:`wire_delta` reuses it, so the
    round quantizes exactly once (it used to re-quantize the same δ via
    ``transform_delta`` — bit-identical output, twice the work).
    """
    kind = "cinn"

    @property
    def bits_per_entry(self) -> int:
        return self.rule.quantize_bits or 8

    def transform_delta(self, delta):
        return per_worker_quantize_dequantize(delta, self.bits_per_entry)

    def lhs(self, ctx, extras):
        innovation = jax.tree.map(
            lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
            ctx.fresh, ctx.comm.worker_grads)
        q = per_worker_quantize_dequantize(innovation, self.bits_per_entry)
        return per_worker_sq_norm(q), q

    def wire_delta(self, ctx, extras, cache, delta):
        del delta  # cache IS Q_b(δ) of this round's innovation
        return cache

    def transform_delta_flat(self, layout, delta):
        return per_worker_quantize_dequantize_flat(layout, delta,
                                                   self.bits_per_entry)

    def flat_lhs(self, ctx, extras):
        innovation = ctx.fresh - ctx.comm.worker_grads.astype(jnp.float32)
        q = per_worker_quantize_dequantize_flat(ctx.layout, innovation,
                                                self.bits_per_entry)
        return kops.batched_sq_norm(q, interpret=ctx.interpret,
                                    shard=ctx.shard), q

    def flat_wire_delta(self, ctx, extras, cache, delta):
        del delta
        return cache


class ErrorFeedbackStrategy(CommStrategy):
    """Shared scaffolding of the explicit-residual compressed-upload rules:
    wire = C(δ_m + e_m), gate = ||wire||², residual transition on upload —
    ONCE per concern per plane, so a change to the residual semantics
    cannot silently diverge between rules or planes. Subclasses supply
    only the compressor pair (:meth:`_compress` / :meth:`_compress_flat`)
    and their accounting."""

    def _compress(self, corrected):
        """Pytree compressor over the fp32 corrected innovation."""
        raise NotImplementedError

    def _compress_flat(self, layout, corrected):
        """(M, n_flat)-plane twin — must be bit-identical."""
        raise NotImplementedError

    def init_extras(self, params, m, make_grad_zeros, bcast):
        # error_feedback=False is genuinely memory-free: no residual plane
        # is allocated (it would be worker-grads-sized), not just unused
        if not self.rule.error_feedback:
            return {}
        return {"residual": bcast(make_grad_zeros(), m)}

    def extras_specs(self, param_spec, worker_param_spec, worker_grad_spec):
        if not self.rule.error_feedback:
            return {}
        return {"residual": worker_grad_spec}

    def lhs(self, ctx, extras):
        delta = jax.tree.map(
            lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
            ctx.fresh, ctx.comm.worker_grads)
        corrected = (ef_correct(delta, extras["residual"])
                     if self.rule.error_feedback else delta)
        wire = self._compress(corrected)
        return per_worker_sq_norm(wire), (wire, corrected)

    def wire_delta(self, ctx, extras, cache, delta):
        del delta
        return cache[0]

    def post_upload(self, extras, cache, upload, ctx):
        if not self.rule.error_feedback:
            return extras
        wire, corrected = cache
        return {**extras,
                "residual": ef_residual(corrected, wire, upload,
                                        extras["residual"])}

    # ---- flat plane: e_m is one (M, n_flat) plane.
    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        if not self.rule.error_feedback:
            return {}
        return {"residual": jnp.zeros((m, layout.n_flat), grad_dtype)}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        if not self.rule.error_feedback:
            return {}
        return {"residual": P(waxis, spec_dim(col_axes))}

    def pooled_extras(self):
        # e_m is worker-grads-sized — pooled iff it exists at all
        return ("residual",) if self.rule.error_feedback else ()

    def flat_lhs(self, ctx, extras):
        delta = ctx.fresh - ctx.comm.worker_grads.astype(jnp.float32)
        corrected = (ef_correct(delta, extras["residual"])
                     if self.rule.error_feedback else delta)
        wire = self._compress_flat(ctx.layout, corrected)
        return kops.batched_sq_norm(wire, interpret=ctx.interpret,
                                    shard=ctx.shard), \
            (wire, corrected)

    def flat_wire_delta(self, ctx, extras, cache, delta):
        del delta
        return cache[0]

    def flat_post_upload(self, extras, cache, upload, ctx):
        return self.post_upload(extras, cache, upload, ctx)


@register
class LAQStrategy(ErrorFeedbackStrategy):
    """Beyond-paper: full LAQ [Sun et al., 2019] — lazy uploads composed
    with b-bit quantization AND an error-feedback residual.

    Each worker carries e_m, the quantization error its past uploads left
    behind. The wire is Q_b(δ_m + e_m): the corrected innovation; the gate
    is its energy, ||Q_b(δ_m + e_m)||² > RHS — the worker uploads exactly
    when what it WOULD transmit still carries information relative to
    recent server progress. On upload e_m ← (δ_m + e_m) − Q_b(δ_m + e_m);
    on skip e_m is carried unchanged (the unsent innovation re-enters the
    next δ_m via the stale copy, not via e_m).

    Error-retention semantics, precisely: because δ_m is an INNOVATION
    against the synced stale copy (which absorbs only the quantized wire),
    the architecture already re-injects each round's compression error
    once — it reappears inside the next δ_m for free. The textbook
    residual therefore injects it a SECOND time: on a stationary gradient
    the stale copies oscillate inside the quantization band (EF-SGD-grade
    bounded noise, vanishing as 2^{−b}) instead of locking on exactly,
    which ``error_feedback=False`` (e_m ≡ 0, the memory-free variant —
    what Sun et al.'s LAQ actually does) achieves. Keep the default for
    studying the textbook composition; prefer ``error_feedback=False`` at
    coarse widths (b ≤ 4), where the doubled band is material. Both
    behaviours are pinned by a regression test. One gradient evaluation
    per iteration; uploads are accounted at b (default 8) bits per entry.
    """
    kind = "laq"

    @property
    def bits_per_entry(self) -> int:
        return self.rule.quantize_bits or 8

    def _compress(self, corrected):
        return per_worker_quantize_dequantize(corrected, self.bits_per_entry)

    def _compress_flat(self, layout, corrected):
        # rides the segment-vectorized flat quantizer (bit-identical scales)
        return per_worker_quantize_dequantize_flat(layout, corrected,
                                                   self.bits_per_entry)


@register
class TopKStrategy(ErrorFeedbackStrategy):
    """Beyond-paper: top-k sparsified innovation with error feedback (the
    sparse-upload family of arXiv 2112.04088).

    The wire keeps only the ⌈topk_frac·size⌉ largest-magnitude entries of
    δ_m + e_m per (worker, leaf); the dropped mass lands in the
    error-feedback residual e_m (same transition as :class:`LAQStrategy`,
    with sparsification as the compressor; ``quantize_bits`` additionally
    quantizes the kept values). The gate is the energy of the sparse wire.
    The :class:`LAQStrategy` error-retention caveat applies here too: the
    innovation-vs-stale-copy mechanism re-injects dropped mass once on its
    own, so the textbook residual doubles it — bounded, and
    ``error_feedback=False`` is the memory-free alternative.

    Accounting is SPARSE: an upload costs k·(value_bits + index_bits)
    bits with k = ⌈topk_frac·n⌉ over the whole parameter vector,
    value_bits = ``quantize_bits`` or 32, index_bits = ⌈log₂ n⌉ — not
    n·32. (The per-leaf masks keep ⌈frac·size⌉ per leaf, so the true kept
    count can exceed k by at most one per leaf — the flat and pytree
    planes report identical bytes either way.)
    """
    kind = "topk"

    def _compress(self, corrected):
        sparse = per_worker_topk_sparsify(corrected, self.rule.topk_frac)
        return (per_worker_quantize_dequantize(sparse,
                                               self.rule.quantize_bits)
                if self.rule.quantize_bits else sparse)

    def _compress_flat(self, layout, corrected):
        sparse = per_worker_topk_sparsify_flat(layout, corrected,
                                               self.rule.topk_frac)
        return (per_worker_quantize_dequantize_flat(
                    layout, sparse, self.rule.quantize_bits)
                if self.rule.quantize_bits else sparse)

    # ---- true sparse wire (flat plane): when ``sparse_wire`` is set the
    # simulated collective ships (values, indices) pairs sized k extracted
    # from the compressed plane — the payload the sparse ACCOUNTING below
    # already charges for — instead of the dense masked plane. The
    # residual transition still reads the dense cache, so error feedback
    # is untouched; reconstruction is bit-equal (the exact-k mask and
    # the extraction select the same support).
    def flat_sparse_wire(self, ctx, extras, cache, delta):
        del extras, delta
        if not self.rule.sparse_wire or self.rule.topk_frac >= 1.0:
            return None
        return per_worker_topk_extract_flat(ctx.layout, cache[0],
                                            self.rule.topk_frac)

    # ---- sparse accounting
    def bytes_per_upload(self, n_params: int) -> float:
        k = topk_count(n_params, self.rule.topk_frac)
        index_bits = max(1, math.ceil(math.log2(n_params))) \
            if n_params > 1 else 1
        return k * (self.bits_per_entry + index_bits) / 8.0

    @property
    def wire_format(self) -> str:
        return "sparse"


@register
class AVPStrategy(CommStrategy):
    """Beyond-paper: variance-adaptive upload period (arXiv 2007.06134
    style, re-expressed on the CADA state).

    Each worker keeps its own integer period p_m ∈ [period_min,
    resolved_period_max] and uploads exactly when its staleness reaches
    p_m (the shared max-staleness cap still applies above it). After every
    iteration p_m adapts against the SHARED recent-progress RHS the CADA
    rules use: while the worker's innovation energy ||δ_m||² exceeds the
    RHS its period shrinks by one (communicate more while informative),
    otherwise it grows by one. One gradient evaluation per iteration —
    the adaptation reads the progress ring, never a second evaluation.

    ``avp_compose`` composes the period gate with the CADA LHS check: the
    LHS becomes the innovation energy where the worker is due (−∞
    otherwise), so a worker uploads only when due AND ||δ_m||² > RHS —
    the period is then a FLOOR on upload spacing (an informativeness
    check rides on top) instead of a schedule; the shared max-staleness
    cap still forces an upload eventually.
    """
    kind = "avp"

    def _init_periods(self, m: int):
        return jnp.full((m,), self.rule.period_min, jnp.int32)

    def _adapt(self, period, energy, diff_hist):
        # shared cadence adaptation: GROW (upload less) while the
        # innovation energy stays under the RHS, shrink when it clears it
        r = self.rule
        return adapt_period(period, ~(energy > r.rhs(diff_hist)),
                            r.period_min, r.resolved_period_max)

    def _gate(self, staleness, period, energy):
        due = staleness >= period
        if self.rule.avp_compose:
            return jnp.where(due, energy,
                             -jnp.inf).astype(jnp.float32)
        return jnp.where(due, jnp.inf, -jnp.inf).astype(jnp.float32)

    def init_extras(self, params, m, make_grad_zeros, bcast):
        return {"period": self._init_periods(m)}

    def extras_specs(self, param_spec, worker_param_spec, worker_grad_spec):
        return {"period": PartitionSpec(None)}

    def lhs(self, ctx, extras):
        delta = jax.tree.map(
            lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
            ctx.fresh, ctx.comm.worker_grads)
        energy = per_worker_sq_norm(delta)
        return self._gate(ctx.comm.staleness, extras["period"],
                          energy), energy

    def post_upload(self, extras, energy, upload, ctx):
        period = self._adapt(extras["period"], energy, ctx.comm.diff_hist)
        if ctx.participation is not None:
            # an OFFLINE worker evaluated nothing this round — its period
            # cannot adapt to a gradient it never computed
            period = jnp.where(ctx.participation, period, extras["period"])
        return {**extras, "period": period}

    # ---- flat plane: only the energy norm changes form.
    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        return {"period": self._init_periods(m)}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        return {"period": P(None)}

    def flat_lhs(self, ctx, extras):
        energy = kops.batched_diff_sq_norm(
            ctx.fresh, ctx.comm.worker_grads.astype(jnp.float32),
            interpret=ctx.interpret, shard=ctx.shard)
        # cohort round: the (M,) period vector is server-resident; gate
        # the C sampled rows against their own periods
        period = extras["period"]
        if ctx.cohort is not None:
            period = period[ctx.cohort]
        return self._gate(ctx.comm.staleness, period, energy), energy

    def flat_post_upload(self, extras, energy, upload, ctx):
        if ctx.cohort is None:
            return self.post_upload(extras, energy, upload, ctx)
        # cohort twin of the participation freeze: only the sampled rows
        # evaluated a gradient, so only their periods adapt — identical
        # integers to the dense plane's where(participation, ...) form
        p_c = self._adapt(extras["period"][ctx.cohort], energy,
                          ctx.comm.diff_hist)
        return {**extras,
                "period": extras["period"].at[ctx.cohort].set(p_c)}


# ----------------------------------------------------------- shared round

def init_comm_state(strategy: CommStrategy, params, m: int,
                    grad_dtype=None) -> CommState:
    """Fresh CommState: τ_m starts at D so iteration 0 uploads everywhere.

    ``grad_dtype`` is the storage dtype of gradient-shaped comm state
    (None ⇒ follow the param dtypes; the pod trainer passes bf16 here for
    the 314B/405B memory policy).
    """
    r = strategy.rule
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, grad_dtype or p.dtype), params)
    extras = strategy.init_extras(params, m, lambda: zeros,
                                  broadcast_to_workers)
    return CommState(
        nabla=zeros,
        worker_grads=broadcast_to_workers(zeros, m),
        staleness=jnp.full((m,), r.max_delay, jnp.int32),
        diff_hist=jnp.zeros((r.d_max,), jnp.float32),
        extras=extras,
    )


def comm_state_specs(strategy: CommStrategy, param_spec, worker_param_spec,
                     grad_spec, worker_grad_spec, scalar_spec) -> CommState:
    """CommState-shaped PartitionSpec tree (pod trainer)."""
    return CommState(
        nabla=grad_spec,
        worker_grads=worker_grad_spec,
        staleness=scalar_spec,
        diff_hist=scalar_spec,
        extras=strategy.extras_specs(param_spec, worker_param_spec,
                                     worker_grad_spec),
    )


def comm_round(strategy: CommStrategy, comm: CommState, params, batch, k,
               *, vgrad, vgrad_per=None, participation=None,
               local_steps=None) -> CommRoundResult:
    """One rule-agnostic communication round of Algorithm 1 (lines 4-15).

    The caller supplies the gradient evaluators and afterwards applies the
    server update (lines 16-17) to ``result.comm.nabla``, then records the
    progress scalar via :func:`record_progress`.

    ``participation`` ((M,) bool or None) masks the upload decision for
    partial-participation rounds (see ``flat.flat_comm_round`` — the sim
    runtime's knob); ``None`` leaves the graph unchanged.

    ``local_steps`` is the PAYLOAD/CADENCE axis — only legal for
    delta-payload rules (``strategy.delta_payload``), whose batch leads
    with the local-steps axis H and whose payload is the accumulated
    local-optimizer model delta instead of one fresh gradient (see
    ``flat.flat_comm_round`` for the full contract). For the 8
    gradient-payload rules it must stay None and this round's graph is
    byte-identical to the pre-axis form.
    """
    r = strategy.rule
    m = comm.staleness.shape[0]
    if local_steps is not None and not strategy.delta_payload:
        raise ValueError(
            f"rule kind {r.kind!r} ships per-iteration gradients; "
            "local_steps is only meaningful for delta-payload rules "
            "(local_momentum, fedadam)")

    # Line 4 (rule-owned): e.g. CADA1 snapshot refresh every D iterations.
    extras = strategy.pre_step(comm.extras, params, k)

    if strategy.delta_payload:
        # Payload/cadence branch: h_w local optimizer steps per worker,
        # payload = θ^k − θ_m^(h) substituted for ``fresh``. worker_grads
        # then telescopes to the last payload, so ∇̄ ≡ mean_m(payload)
        # and the rule's server optimizer closes the periodic-averaging /
        # FedAdam loop. Always-upload cadence (lhs ≡ +inf).
        batch_h = (batch if batch_has_local_axis(r, local_steps)
                   else jax.tree.map(lambda x: x[None], batch))
        h_steps = local_steps_vector(r, m, batch_h, local_steps)
        losses, fresh, cache = strategy.local_payload(
            extras, params, batch_h, m, vgrad_per, h_steps)
        ctx = CommContext(params=params, batch=batch, fresh=fresh,
                          comm=comm._replace(extras=extras), step=k, m=m,
                          vgrad=vgrad, vgrad_per=vgrad_per,
                          participation=participation)
        lhs = jnp.full((m,), jnp.inf, jnp.float32)
    else:
        h_steps = None
        # Lines 6/8: fresh stochastic gradients at θ^k (all rules).
        losses, fresh = vgrad(params, batch)
        ctx = CommContext(params=params, batch=batch, fresh=fresh,
                          comm=comm._replace(extras=extras), step=k, m=m,
                          vgrad=vgrad, vgrad_per=vgrad_per,
                          participation=participation)

        # Lines 7/9: rule LHS vs the shared recent-progress RHS.
        lhs, cache = strategy.lhs(ctx, extras)
    rhs = r.rhs(comm.diff_hist)
    # Line 10: upload if the condition is VIOLATED or staleness capped.
    upload = (lhs > rhs) | (comm.staleness >= r.max_delay)
    if participation is not None:
        upload = upload & participation

    # Eq. (3): server refines ∇ with the uploaded innovations δ_m. The
    # strategy's wire format (quantize/sparsify/error-feedback hook) is
    # applied to δ BEFORE both the server aggregate and the worker stale
    # copy, so the two sides stay exactly in sync; the cast to the
    # stale-tree storage dtype is the cross-worker wire dtype (bf16 halves
    # DCN bytes on the pod mesh).
    delta = jax.tree.map(
        lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
        fresh, comm.worker_grads)
    delta = strategy.wire_delta(ctx, extras, cache, delta)
    zeros = jax.tree.map(jnp.zeros_like, delta)
    wire = jax.tree.map(
        lambda d, s: d.astype(s.dtype),
        select_rows(upload, delta, zeros), comm.worker_grads)
    nabla = jax.tree.map(
        lambda n, d: (n.astype(jnp.float32)
                      + jnp.mean(d.astype(jnp.float32), axis=0)
                      ).astype(n.dtype),
        comm.nabla, wire)
    worker_grads = jax.tree.map(
        lambda s, d: (s.astype(jnp.float32) + d.astype(jnp.float32)
                      ).astype(s.dtype),
        comm.worker_grads, wire)

    staleness = jnp.where(upload, 1, comm.staleness + 1)
    extras = strategy.post_upload(extras, cache, upload, ctx)

    uploads = jnp.sum(upload.astype(jnp.int32))
    n_active = (jnp.asarray(m, jnp.int32) if participation is None
                else jnp.sum(participation.astype(jnp.int32)))
    if strategy.delta_payload:
        # one eval per LOCAL step: Σ_active h_w
        grad_evals = jnp.sum(h_steps if participation is None
                             else jnp.where(participation, h_steps, 0))
    else:
        grad_evals = n_active * strategy.grad_evals_per_iter
    metrics = {
        "uploads": uploads,
        # fraction of ACTIVE workers that skipped (an offline worker does
        # not "skip" — it was never asked)
        "skip_rate": 1.0 - uploads.astype(jnp.float32) / n_active,
        "upload_mask": upload,
        "staleness": staleness,
        "rhs": rhs,
        # full per-worker gate LHS (inf for threshold-free rules) — the
        # obs.metrics.CommLedger derives LHS−RHS gate margins from this
        "lhs": lhs,
        "mean_lhs": jnp.mean(jnp.where(jnp.isfinite(lhs), lhs, 0.0)),
        "max_staleness": jnp.max(staleness),
        "grad_evals": grad_evals,
        "bytes_up": (uploads.astype(jnp.float32)
                     * strategy.bytes_per_upload(tree_size(params))),
    }
    new_comm = CommState(nabla=nabla, worker_grads=worker_grads,
                         staleness=staleness, diff_hist=comm.diff_hist,
                         extras=extras)
    return CommRoundResult(losses=losses, comm=new_comm, upload=upload,
                           metrics=metrics)


def record_progress(comm: CommState, dtheta_sq, k) -> CommState:
    """Push ||θ^{k+1} − θ^k||² into the RHS ring buffer (line 17's tail)."""
    d_max = comm.diff_hist.shape[0]
    diff_hist = jax.lax.dynamic_update_index_in_dim(
        comm.diff_hist, dtheta_sq.astype(jnp.float32), k % d_max, axis=0)
    return comm._replace(diff_hist=diff_hist)


def nabla_f32(comm: CommState):
    """The server-update driver ∇^k in fp32 (line 16's input)."""
    return _f32(comm.nabla)


# The delta-payload strategies (local_momentum / fedadam) live in
# core/local_update.py next to the seed engine they reproduce; importing
# them here registers them so every consumer of the registry — engines,
# launcher choices, sweeps — sees the full kind set without knowing about
# the payload axis.
from repro.core import local_update as _local_update  # noqa: E402,F401
