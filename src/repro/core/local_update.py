"""Local-update baselines: local momentum SGD [Yu et al., 2019] and
FedAdam [Reddi et al., 2020] — the paper's strongest baselines (§4).

Both run H local iterations per communication round:
  * local momentum — every worker does heavy-ball SGD; every H steps the
    params AND momentum buffers are averaged across workers.
  * FedAdam — workers run H plain-SGD steps from the server iterate; the
    server treats the negative mean model delta as a pseudo-gradient and
    applies an Adam step with server stepsize α_s.

Communication accounting matches the paper: one upload per worker per round,
i.e. M uploads per H iterations; one gradient evaluation per worker per local
iteration.

TWO implementations live here:

  * :class:`LocalMomentumStrategy` / :class:`FedAdamStrategy` — the
    baselines REBUILT on the strategy layer as registered DELTA-PAYLOAD
    rules (``kind="local_momentum"`` / ``"fedadam"``): the shared
    ``comm_round`` / ``flat_comm_round`` / ``flat_cohort_round`` carry
    them on every engine, the payload is the accumulated model delta
    θ^k − θ_m^(H) shipped through the ordinary wire hooks (so
    ``quantize_bits`` compression of local updates composes for free),
    and the prescribed server optimizer (``server_optimizer()``) closes
    the averaging / FedAdam loop. The telescoping identity makes this
    exact: with every worker uploading every round, ``worker_grads``
    always equals the last shipped payload, so ∇̄ ≡ mean_m(payload) and
    the server's sgd(1.0) / Adam step IS the seed engine's round tail.
  * :class:`LocalUpdateEngine` — the SEED standalone engine, kept as the
    PARITY ORACLE for the strategy-layer rules (the ``fused=False``
    precedent: tests pin the registered rules' trajectories against it
    at the same H and seeds, then everything routes through the rule
    layer).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.comm import (CommStrategy, broadcast_to_workers, register,
                             select_rows)
from repro.core.flat import spec_dim
from repro.kernels import ops as kops
from repro.optim.adam import adam
from repro.optim.base import apply_updates
from repro.optim.sgd import sgd
from repro.utils.trees import tree_size


class LocalState(NamedTuple):
    step: jnp.ndarray        # global iteration counter (local steps count!)
    params: Any              # server params θ (replicated start of round)
    momenta: Any             # per-worker momentum buffers (M-leading)
    server_opt: Any          # FedAdam server Adam state (None for local-mom)


class LocalUpdateEngine:
    """One engine for both baselines; ``algo`` in {"local_momentum",
    "fedadam"}."""

    def __init__(self, loss_fn: Callable, n_workers: int, h_period: int,
                 algo: str = "local_momentum", lr: float = 0.1,
                 beta: float = 0.9, server_lr: float = 0.01,
                 server_betas=(0.9, 0.999), server_eps: float = 1e-3):
        # server_eps follows Reddi et al.'s recommended adaptivity τ=1e-3:
        # with τ→0 the Adam-normalized server step never decays and FedAdam
        # orbits the optimum instead of converging.
        if algo not in ("local_momentum", "fedadam"):
            raise ValueError(algo)
        self.loss_fn = loss_fn
        self.m = n_workers
        self.h = h_period
        self.algo = algo
        self.lr = lr
        self.beta = beta
        self._vgrad = jax.vmap(jax.value_and_grad(loss_fn),
                               in_axes=(0, 0))
        self._server_opt = (adam(lr=server_lr, b1=server_betas[0],
                                 b2=server_betas[1], eps=server_eps,
                                 amsgrad=False, eps_inside_sqrt=False)
                            if algo == "fedadam" else None)

    def init(self, params) -> LocalState:
        zeros = jax.tree.map(
            lambda x: jnp.zeros((self.m,) + x.shape, x.dtype), params)
        return LocalState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            momenta=zeros,
            server_opt=(self._server_opt.init(params)
                        if self._server_opt else None),
        )

    def round(self, state: LocalState, batches) -> tuple[LocalState, dict]:
        """One communication round = H local steps + 1 averaging.

        ``batches`` has leading axes (H, M, b, ...).
        """
        # Broadcast server params to every worker.
        wparams = broadcast_to_workers(state.params, self.m)
        momenta = state.momenta
        if self.algo == "fedadam":
            momenta = jax.tree.map(jnp.zeros_like, momenta)  # plain local SGD

        def local_step(carry, batch):
            wp, mom = carry
            losses, grads = self._vgrad(wp, batch)
            if self.algo == "local_momentum":
                mom = jax.tree.map(lambda m_, g: self.beta * m_ + g,
                                   mom, grads)
                wp = jax.tree.map(lambda p, m_: p - self.lr * m_, wp, mom)
            else:
                wp = jax.tree.map(lambda p, g: p - self.lr * g, wp, grads)
            return (wp, mom), jnp.mean(losses)

        (wparams, momenta), losses = jax.lax.scan(
            local_step, (wparams, momenta), batches)

        mean_params = jax.tree.map(lambda x: jnp.mean(x, axis=0), wparams)
        if self.algo == "local_momentum":
            params = mean_params
            momenta = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.mean(x, axis=0, keepdims=True), x.shape), momenta)
            server_opt = state.server_opt
        else:  # FedAdam: pseudo-gradient = −(mean Δ) = θ_server − mean θ_m
            pseudo = jax.tree.map(jnp.subtract, state.params, mean_params)
            updates, server_opt = self._server_opt.update(
                pseudo, state.server_opt, state.params)
            params = apply_updates(state.params, updates)

        p = tree_size(state.params)
        metrics = {
            "loss": losses,                             # (H,) per-iteration
            "uploads": jnp.asarray(self.m, jnp.int32),  # per round
            "grad_evals": jnp.asarray(self.m * self.h, jnp.int32),
            "bytes_up": jnp.asarray(float(self.m) * 4.0 * p, jnp.float32),
        }
        return LocalState(step=state.step + self.h, params=params,
                          momenta=momenta, server_opt=server_opt), metrics

    def run(self, state: LocalState, batches):
        """Scan over rounds: batches (rounds, H, M, b, ...)."""
        return jax.lax.scan(self.round, state, batches)


# --------------------------------------------------- strategy-layer rules
#
# The same two baselines as registered delta-payload CommStrategy rules.
# The local run is a lax.scan over the batch's H axis with PER-WORKER
# masking at ``h_steps`` (rows beyond a worker's h_w are padding: its
# params/momenta freeze, its losses stop counting) — that is what lets
# the sim hand every worker its own adapted H inside one padded scan.

def _masked_mean_losses(step_losses, h_steps):
    """(H, M) per-step losses -> (M,) mean over each worker's ACTIVE steps
    (padded rows arrive already zeroed)."""
    return jnp.sum(step_losses, axis=0) / h_steps.astype(step_losses.dtype)


class LocalUpdateStrategy(CommStrategy):
    """Shared base of the delta-payload family: the local-step scan, the
    payload θ^k − θ_m^(h), and the flat twin. Subclasses supply the local
    optimizer step and (optionally) per-worker local state."""

    delta_payload = True

    # ---- the local optimizer step (pytree and flat forms)
    def _local_step(self, wp, grads, mom):
        """(new_wp, new_mom) from one local step; ``mom`` may be None."""
        raise NotImplementedError

    def _local_step_flat(self, wp, g, mom):
        raise NotImplementedError

    # ---- pytree payload
    def local_payload(self, extras, params, batch, m, vgrad_per, h_steps):
        wp0 = broadcast_to_workers(params, m)
        mom0 = self._initial_momenta(extras, params, m)
        h_max = jax.tree.leaves(batch)[0].shape[0]

        def body(carry, inp):
            wp, mom = carry
            j, b_j = inp
            losses, grads = vgrad_per(wp, b_j)
            new_wp, new_mom = self._local_step(wp, grads, mom)
            active = j < h_steps
            wp = select_rows(active, new_wp, wp)
            if mom is not None:
                mom = select_rows(active, new_mom, mom)
            return (wp, mom), jnp.where(active, losses, 0.0)

        (wp, mom), step_losses = jax.lax.scan(
            body, (wp0, mom0), (jnp.arange(h_max), batch))
        payload = jax.tree.map(
            lambda p, w: p.astype(jnp.float32) - w.astype(jnp.float32),
            params, wp)
        return _masked_mean_losses(step_losses, h_steps), payload, mom

    def _initial_momenta(self, extras, params, m):
        """(M,)-leading momentum tree carried into the round, or None."""
        del extras, params, m
        return None

    # ---- flat payload
    def flat_local_payload(self, layout, extras, params, params_flat, batch,
                           m, vgrad_per, h_steps):
        del params
        wp0 = jnp.broadcast_to(params_flat[None], (m, layout.n_flat)
                               ).astype(jnp.float32)
        mom0 = self._initial_momenta_flat(extras)
        h_max = jax.tree.leaves(batch)[0].shape[0]

        def body(carry, inp):
            wp, mom = carry
            j, b_j = inp
            losses, grads = vgrad_per(layout.unpack_worker(wp), b_j)
            g = layout.pack_worker(grads).astype(jnp.float32)
            new_wp, new_mom = self._local_step_flat(wp, g, mom)
            active = (j < h_steps)
            wp = jnp.where(active[:, None], new_wp, wp)
            if mom is not None:
                mom = jnp.where(active[:, None], new_mom, mom)
            return (wp, mom), jnp.where(active, losses, 0.0)

        (wp, mom), step_losses = jax.lax.scan(
            body, (wp0, mom0), (jnp.arange(h_max), batch))
        payload = params_flat.astype(jnp.float32)[None] - wp
        return _masked_mean_losses(step_losses, h_steps), payload, mom

    def _initial_momenta_flat(self, extras):
        del extras
        return None


@register
class LocalMomentumStrategy(LocalUpdateStrategy):
    """Local heavy-ball SGD with periodic model averaging, as a rule.

    Local step: mom ← β·mom + g; θ_m ← θ_m − lr·mom. Payload = the model
    delta; prescribed server optimizer sgd(1.0), so the server update
    θ ← θ − mean_m(Δ_m) ≡ mean_m(θ_m) — exactly the seed engine's
    averaging round. Momenta are per-worker n-vectors that PERSIST across
    rounds and are averaged across the round's uploaders after every
    round (the seed's all-worker average, generalized to partial
    participation: offline workers took no local steps, so they keep
    their old momenta) — hence an O(M·n) plane, POOLED on the cohort
    plane like laq/topk's residual.
    """

    kind = "local_momentum"

    def server_optimizer(self):
        return sgd(1.0)

    def _local_step(self, wp, grads, mom):
        r = self.rule
        new_mom = jax.tree.map(
            lambda mo, g: (r.local_beta * mo.astype(jnp.float32)
                           + g.astype(jnp.float32)).astype(mo.dtype),
            mom, grads)
        new_wp = jax.tree.map(
            lambda p, mo: (p.astype(jnp.float32)
                           - r.local_lr * mo.astype(jnp.float32)
                           ).astype(p.dtype),
            wp, new_mom)
        return new_wp, new_mom

    def _local_step_flat(self, wp, g, mom):
        r = self.rule
        new_mom = r.local_beta * mom + g
        return wp - r.local_lr * new_mom, new_mom

    def _initial_momenta(self, extras, params, m):
        del params, m
        return extras["momenta"]

    def _initial_momenta_flat(self, extras):
        return extras["momenta"].astype(jnp.float32)

    # ---- state slices
    def init_extras(self, params, m, make_grad_zeros, bcast):
        return {"momenta": bcast(make_grad_zeros(), m)}

    def extras_specs(self, param_spec, worker_param_spec, worker_grad_spec):
        return {"momenta": worker_grad_spec}

    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        return {"momenta": jnp.zeros((m, layout.n_flat), grad_dtype)}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        return {"momenta": P(waxis, spec_dim(col_axes))}

    def pooled_extras(self):
        return ("momenta",)

    # ---- post-round momentum averaging over the uploaders
    def post_upload(self, extras, cache, upload, ctx):
        mom_run = cache  # post-local-run momenta from local_payload
        cnt = jnp.maximum(jnp.sum(upload.astype(jnp.int32)),
                          1).astype(jnp.float32)

        def leaf(mn, mo):
            mask = upload.reshape((-1,) + (1,) * (mn.ndim - 1))
            avg = jnp.sum(jnp.where(mask, mn.astype(jnp.float32), 0.0),
                          axis=0) / cnt
            return jnp.where(mask, avg[None].astype(mo.dtype), mo)

        return {**extras,
                "momenta": jax.tree.map(leaf, mom_run, extras["momenta"])}

    def flat_post_upload(self, extras, cache, upload, ctx):
        mom_run = cache
        cnt = jnp.maximum(jnp.sum(upload.astype(jnp.int32)),
                          1).astype(jnp.float32)
        masked = jnp.where(upload[:, None], mom_run, 0.0)
        # order-fixed raw row sum (denominator 1): the dense masked plane
        # and the cohort's C rows produce BIT-identical averages — the
        # same argument as eq. (3)'s aggregate
        avg = kops.eq3_row_mean(masked, 1, shard=ctx.shard) / cnt
        mom = extras["momenta"]
        new = jnp.where(upload[:, None], avg[None].astype(mom.dtype), mom)
        return {**extras, "momenta": new}


@register
class FedAdamStrategy(LocalUpdateStrategy):
    """FedAdam (Reddi et al., arXiv 2003.00295) as a rule: plain local
    SGD steps, delta payload, server Adam.

    The prescribed server optimizer is the seed engine's exact server:
    Adam(lr=``server_lr``, β=(0.9, 0.999), ε=1e-3, no amsgrad, ε outside
    the sqrt) — Reddi et al.'s recommended adaptivity τ=1e-3 (τ→0 makes
    the normalized server step orbit instead of converge). ∇̄ ≡
    mean_m(Δ_m) is the pseudo-gradient. No per-worker state beyond the
    gradient row.
    """

    kind = "fedadam"

    def server_optimizer(self):
        return adam(lr=self.rule.server_lr, b1=0.9, b2=0.999, eps=1e-3,
                    amsgrad=False, eps_inside_sqrt=False)

    def _local_step(self, wp, grads, mom):
        r = self.rule
        new_wp = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - r.local_lr * g.astype(jnp.float32)
                          ).astype(p.dtype),
            wp, grads)
        return new_wp, mom

    def _local_step_flat(self, wp, g, mom):
        return wp - self.rule.local_lr * g, mom
