"""Local-update baselines: local momentum SGD [Yu et al., 2019] and
FedAdam [Reddi et al., 2020] — the paper's strongest baselines (§4).

Both run H local iterations per communication round:
  * local momentum — every worker does heavy-ball SGD; every H steps the
    params AND momentum buffers are averaged across workers.
  * FedAdam — workers run H plain-SGD steps from the server iterate; the
    server treats the negative mean model delta as a pseudo-gradient and
    applies an Adam step with server stepsize α_s.

Communication accounting matches the paper: one upload per worker per round,
i.e. M uploads per H iterations; one gradient evaluation per worker per local
iteration.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.comm import broadcast_to_workers
from repro.optim.adam import adam
from repro.optim.base import apply_updates
from repro.utils.trees import tree_size


class LocalState(NamedTuple):
    step: jnp.ndarray        # global iteration counter (local steps count!)
    params: Any              # server params θ (replicated start of round)
    momenta: Any             # per-worker momentum buffers (M-leading)
    server_opt: Any          # FedAdam server Adam state (None for local-mom)


class LocalUpdateEngine:
    """One engine for both baselines; ``algo`` in {"local_momentum",
    "fedadam"}."""

    def __init__(self, loss_fn: Callable, n_workers: int, h_period: int,
                 algo: str = "local_momentum", lr: float = 0.1,
                 beta: float = 0.9, server_lr: float = 0.01,
                 server_betas=(0.9, 0.999), server_eps: float = 1e-3):
        # server_eps follows Reddi et al.'s recommended adaptivity τ=1e-3:
        # with τ→0 the Adam-normalized server step never decays and FedAdam
        # orbits the optimum instead of converging.
        if algo not in ("local_momentum", "fedadam"):
            raise ValueError(algo)
        self.loss_fn = loss_fn
        self.m = n_workers
        self.h = h_period
        self.algo = algo
        self.lr = lr
        self.beta = beta
        self._vgrad = jax.vmap(jax.value_and_grad(loss_fn),
                               in_axes=(0, 0))
        self._server_opt = (adam(lr=server_lr, b1=server_betas[0],
                                 b2=server_betas[1], eps=server_eps,
                                 amsgrad=False, eps_inside_sqrt=False)
                            if algo == "fedadam" else None)

    def init(self, params) -> LocalState:
        zeros = jax.tree.map(
            lambda x: jnp.zeros((self.m,) + x.shape, x.dtype), params)
        return LocalState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            momenta=zeros,
            server_opt=(self._server_opt.init(params)
                        if self._server_opt else None),
        )

    def round(self, state: LocalState, batches) -> tuple[LocalState, dict]:
        """One communication round = H local steps + 1 averaging.

        ``batches`` has leading axes (H, M, b, ...).
        """
        # Broadcast server params to every worker.
        wparams = broadcast_to_workers(state.params, self.m)
        momenta = state.momenta
        if self.algo == "fedadam":
            momenta = jax.tree.map(jnp.zeros_like, momenta)  # plain local SGD

        def local_step(carry, batch):
            wp, mom = carry
            losses, grads = self._vgrad(wp, batch)
            if self.algo == "local_momentum":
                mom = jax.tree.map(lambda m_, g: self.beta * m_ + g,
                                   mom, grads)
                wp = jax.tree.map(lambda p, m_: p - self.lr * m_, wp, mom)
            else:
                wp = jax.tree.map(lambda p, g: p - self.lr * g, wp, grads)
            return (wp, mom), jnp.mean(losses)

        (wparams, momenta), losses = jax.lax.scan(
            local_step, (wparams, momenta), batches)

        mean_params = jax.tree.map(lambda x: jnp.mean(x, axis=0), wparams)
        if self.algo == "local_momentum":
            params = mean_params
            momenta = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.mean(x, axis=0, keepdims=True), x.shape), momenta)
            server_opt = state.server_opt
        else:  # FedAdam: pseudo-gradient = −(mean Δ) = θ_server − mean θ_m
            pseudo = jax.tree.map(jnp.subtract, state.params, mean_params)
            updates, server_opt = self._server_opt.update(
                pseudo, state.server_opt, state.params)
            params = apply_updates(state.params, updates)

        p = tree_size(state.params)
        metrics = {
            "loss": losses,                             # (H,) per-iteration
            "uploads": jnp.asarray(self.m, jnp.int32),  # per round
            "grad_evals": jnp.asarray(self.m * self.h, jnp.int32),
            "bytes_up": jnp.asarray(float(self.m) * 4.0 * p, jnp.float32),
        }
        return LocalState(step=state.step + self.h, params=params,
                          momenta=momenta, server_opt=server_opt), metrics

    def run(self, state: LocalState, batches):
        """Scan over rounds: batches (rounds, H, M, b, ...)."""
        return jax.lax.scan(self.round, state, batches)
