"""Paper-faithful server/worker engine for CADA and per-iteration baselines.

This is the reference implementation of Algorithm 1: a (virtual) server and M
workers, simulated SPMD-style on however many devices are present — worker
gradients are a ``vmap`` over the worker axis, so the same code runs on one
CPU (paper experiments) or sharded (see `repro.distributed` for the
mesh/pod-level runtime).

The engine is a pure ``(state, batch) -> (state, metrics)`` step, jittable and
scannable. Communication is *accounted* exactly as the paper counts it: one
"upload" per worker per iteration in which the rule fires (|M^k| uploads at
iteration k), and two gradient evaluations per iteration per worker for
CADA1/2, one otherwise.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import per_worker_quantize_dequantize
from repro.core.rules import CommRule
from repro.optim.base import Optimizer, apply_updates
from repro.utils.trees import tree_size, tree_sq_norm


class EngineState(NamedTuple):
    step: jnp.ndarray            # k
    params: Any                  # θ^k (server copy)
    opt_state: Any               # Adam/AMSGrad moments {h, v, v̂}
    nabla: Any                   # ∇^{k-1}: aggregated stale gradient (eq. 3)
    worker_grads: Any            # per-worker last contributed ∇ℓ(θ̂_m;ξ̂_m)
    staleness: jnp.ndarray       # τ_m, (M,)
    diff_hist: jnp.ndarray       # ring buffer of ||θ^{k+1-d}−θ^{k-d}||²
    snapshot: Any                # θ̃ (CADA1) else None
    worker_delta: Any            # stored δ̃_m^{k−τ} (CADA1) else None
    worker_params: Any           # θ^{k−τ_m} per worker (CADA2) else None


def _per_worker_sq_norm(tree) -> jnp.ndarray:
    """(M,) squared norms of an M-leading pytree."""
    leaves = jax.tree.leaves(tree)
    tot = 0.0
    for leaf in leaves:
        axes = tuple(range(1, leaf.ndim))
        tot = tot + jnp.sum(jnp.square(leaf.astype(jnp.float32)), axis=axes)
    return tot


def _select_rows(mask, new, old):
    def leaf(n, o):
        m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(leaf, new, old)


def _broadcast_to_workers(tree, m):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


class CADAEngine:
    """Server + M workers running Algorithm 1 (or a per-iteration baseline).

    Args:
      loss_fn: scalar loss ``loss_fn(params, (x, y))`` for ONE worker batch.
      optimizer: the server optimizer (paper: AMSGrad-form Adam). The LAG
        baseline is usually paired with plain SGD, as in the paper.
      rule: the communication rule (cada1 / cada2 / lag / always).
      n_workers: M.
    """

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 rule: CommRule, n_workers: int):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.rule = rule
        self.m = n_workers
        self._vgrad = jax.vmap(jax.value_and_grad(loss_fn),
                               in_axes=(None, 0))
        self._vgrad_per_params = jax.vmap(jax.grad(loss_fn),
                                          in_axes=(0, 0))

    # ------------------------------------------------------------- state
    def init(self, params) -> EngineState:
        r = self.rule
        zeros_like_params = jax.tree.map(jnp.zeros_like, params)
        wzeros = _broadcast_to_workers(zeros_like_params, self.m)
        return EngineState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            opt_state=self.optimizer.init(params),
            nabla=zeros_like_params,
            worker_grads=wzeros,
            # τ_m initialized to D so that iteration 0 uploads everywhere.
            staleness=jnp.full((self.m,), r.max_delay, jnp.int32),
            diff_hist=jnp.zeros((r.d_max,), jnp.float32),
            snapshot=params if r.kind == "cada1" else None,
            worker_delta=wzeros if r.kind == "cada1" else None,
            worker_params=(_broadcast_to_workers(params, self.m)
                           if r.kind == "cada2" else None),
        )

    # -------------------------------------------------------------- step
    def step(self, state: EngineState, batch) -> tuple[EngineState, dict]:
        """One iteration of Algorithm 1. ``batch`` has leading axis M."""
        r = self.rule
        k = state.step

        # Line 4: refresh the CADA1 snapshot every D iterations.
        snapshot = state.snapshot
        if r.kind == "cada1":
            refresh = (k % r.max_delay) == 0
            snapshot = jax.tree.map(
                lambda s, p: jnp.where(refresh, p, s), snapshot, state.params)

        # Lines 6/8: fresh stochastic gradients at θ^k (all rules).
        losses, fresh = self._vgrad(state.params, batch)

        # Rule LHS (lines 7/9).
        worker_delta_fresh = None
        if r.kind == "cada1":
            snap_grads = jax.vmap(jax.grad(self.loss_fn), in_axes=(None, 0))(
                snapshot, batch)
            worker_delta_fresh = jax.tree.map(
                jnp.subtract, fresh, snap_grads)
            lhs = _per_worker_sq_norm(jax.tree.map(
                jnp.subtract, worker_delta_fresh, state.worker_delta))
        elif r.kind == "cada2":
            stale_grads = self._vgrad_per_params(state.worker_params, batch)
            lhs = _per_worker_sq_norm(jax.tree.map(
                jnp.subtract, fresh, stale_grads))
        elif r.kind == "lag":
            lhs = _per_worker_sq_norm(jax.tree.map(
                jnp.subtract, fresh, state.worker_grads))
        else:  # always — distributed Adam: force the rule to fire.
            lhs = jnp.full((self.m,), jnp.inf, jnp.float32)

        rhs = (r.c / r.d_max) * jnp.sum(state.diff_hist)
        # Line 10: upload if the condition is VIOLATED or staleness capped.
        upload = (lhs > rhs) | (state.staleness >= r.max_delay)

        # Eq. (3): server refines the aggregated stale gradient with the
        # uploaded innovations δ_m. With quantize_bits set, δ_m is the
        # b-bit LAQ-style round trip and BOTH sides apply the same value,
        # so the server's worker copies stay exactly in sync.
        delta = jax.tree.map(jnp.subtract, fresh, state.worker_grads)
        if r.quantize_bits:
            delta = per_worker_quantize_dequantize(delta, r.quantize_bits)
        zeros = jax.tree.map(jnp.zeros_like, delta)
        masked_delta = _select_rows(upload, delta, zeros)
        nabla = jax.tree.map(
            lambda n, d: n + jnp.mean(d, axis=0), state.nabla,
            masked_delta)

        worker_grads = jax.tree.map(jnp.add, state.worker_grads,
                                    masked_delta)
        staleness = jnp.where(upload, 1, state.staleness + 1)
        worker_delta = state.worker_delta
        if r.kind == "cada1":
            worker_delta = _select_rows(upload, worker_delta_fresh,
                                        state.worker_delta)
        worker_params = state.worker_params
        if r.kind == "cada2":
            worker_params = _select_rows(
                upload, _broadcast_to_workers(state.params, self.m),
                state.worker_params)

        # Lines 16-17: server Adam update driven by ∇^k (eqs. 2a-2c).
        updates, opt_state = self.optimizer.update(
            nabla, state.opt_state, state.params)
        params = apply_updates(state.params, updates)

        diff = tree_sq_norm(updates).astype(jnp.float32)
        diff_hist = jax.lax.dynamic_update_index_in_dim(
            state.diff_hist, diff, k % r.d_max, axis=0)

        new_state = EngineState(
            step=k + 1, params=params, opt_state=opt_state, nabla=nabla,
            worker_grads=worker_grads, staleness=staleness,
            diff_hist=diff_hist, snapshot=snapshot,
            worker_delta=worker_delta, worker_params=worker_params)

        p = tree_size(state.params)
        bytes_per_param = (r.quantize_bits or 32) / 8.0
        uploads = jnp.sum(upload.astype(jnp.int32))
        metrics = {
            "loss": jnp.mean(losses),
            "uploads": uploads,
            "skip_rate": 1.0 - uploads.astype(jnp.float32) / self.m,
            "grad_evals": jnp.asarray(self.m * r.grad_evals_per_iter,
                                      jnp.int32),
            "bytes_up": uploads.astype(jnp.float32) * bytes_per_param * p,
            "rhs": rhs,
            "mean_lhs": jnp.mean(jnp.where(jnp.isfinite(lhs), lhs, 0.0)),
            "max_staleness": jnp.max(staleness),
        }
        return new_state, metrics

    # --------------------------------------------------------------- run
    def run(self, state: EngineState, batches) -> tuple[EngineState, dict]:
        """Scan over pre-sampled batches with leading axis (steps, M, ...)."""
        def body(s, b):
            return self.step(s, b)
        return jax.lax.scan(body, state, batches)


def make_sampler(x: np.ndarray, y: np.ndarray, shard_index: np.ndarray,
                 batch_size: int):
    """Per-worker minibatch sampler over a (M, n_pad) shard-index matrix.

    Returns ``sample(rng) -> (xb, yb)`` with shapes (M, b, ...), (M, b);
    device-resident and jittable.
    """
    xd = jnp.asarray(x)
    yd = jnp.asarray(y)
    idx = jnp.asarray(shard_index)
    m, n_pad = idx.shape

    def sample(rng):
        pos = jax.random.randint(rng, (m, batch_size), 0, n_pad)
        flat = jnp.take_along_axis(idx, pos, axis=1)      # (M, b) global ids
        return xd[flat], yd[flat]

    return sample
