"""Paper-faithful server/worker engine for CADA and per-iteration baselines.

This is the reference implementation of Algorithm 1: a (virtual) server and M
workers, simulated SPMD-style on however many devices are present — worker
gradients are a ``vmap`` over the worker axis, so the same code runs on one
CPU (paper experiments) or sharded (see `repro.distributed` for the
mesh/pod-level runtime).

The engine keeps ONLY the vmap/scan harness and the server optimizer; the
entire communication round (rule LHS/RHS, staleness cap, eq. 3 innovation
aggregation, quantize hook, accounting) is the shared Algorithm-1 core —
the SAME core the pod trainer consumes, so the two cannot drift. Per-rule
behaviour lives in the :mod:`repro.core.comm` strategy objects; this module
contains no rule dispatch.

Two state planes implement that core (both per-iteration identical; the
fused-vs-reference parity test pins them):

  * ``fused=True`` (default) — the flat-buffer hot path
    (:mod:`repro.core.flat`): comm state lives in contiguous (M, n_flat)
    planes, the rule LHS rides the batched one-pass kernel, and the server
    update is the fused AMSGrad/CADA kernel (Pallas on TPU, fused flat jnp
    elsewhere) whose free ||Δθ||² feeds the RHS ring buffer directly;
  * ``fused=False`` — the per-leaf pytree reference
    (:func:`repro.core.comm.comm_round`), kept as the readable oracle.

The default server optimizer is :class:`repro.optim.fused.FusedAMSGrad`
(paper eqs. 2a-2c); any protocol :class:`repro.optim.base.Optimizer` still
drops in (the flat plane then bridges ∇ back to a pytree for it).

The engine is a pure ``(state, batch) -> (state, metrics)`` step, jittable
and scannable. Communication is *accounted* exactly as the paper counts it:
one "upload" per worker per iteration in which the rule fires (|M^k| uploads
at iteration k), and per-rule gradient evaluations (2 for CADA1/2, 1
otherwise) as reported by the strategy.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.core.comm import (CommState, comm_round, init_comm_state,
                             nabla_f32, record_progress, strategy_for)
from repro.core.rules import CommRule
from repro.optim.base import Optimizer, apply_updates
from repro.optim.fused import FusedAMSGrad
from repro.utils.trees import tree_sq_norm


class EngineState(NamedTuple):
    step: jnp.ndarray            # k
    params: Any                  # θ^k (server copy, model pytree form)
    opt_state: Any               # server-optimizer state
    comm: Any                    # CommState | FlatCommState
    params_flat: Any = None      # θ^k packed fp32 (fused plane only)


class CohortEngineState(NamedTuple):
    """Device-resident engine state under the cohort-virtualized plane
    (the O(M·n) per-worker planes live in the host WorkerPool)."""
    step: jnp.ndarray
    params: Any
    opt_state: Any
    server: Any                  # flat.CohortServerState
    params_flat: jnp.ndarray


class CADAEngine:
    """Server + M workers running Algorithm 1 (or a per-iteration baseline).

    Args:
      loss_fn: scalar loss ``loss_fn(params, (x, y))`` for ONE worker batch.
      optimizer: the server optimizer. Default: the fused AMSGrad/CADA
        kernel (paper: AMSGrad-form Adam). Protocol optimizers (e.g. plain
        SGD for the LAG baseline, as in the paper) drop in unchanged.
      rule: the communication rule (any kind registered in core/comm.py).
      n_workers: M.
      fused: run the flat-buffer hot path (default) or the per-leaf pytree
        reference implementation.
      fuse_evals: stack the rule's second gradient evaluation onto the
        fresh one in a single vmapped call with a broadcast 2-way eval
        axis — the batch is NOT copied (no ``concatenate([x, x])``), the
        stacked axis broadcasts it. Default ON: re-measured after the
        broadcast-axis rewrite (logreg m=10, the BENCH_cada problem) the
        stacked form cut cada2's gating overhead from ~38% to ~16% of a
        step ON CPU too — the old doubled-batch form lost ~10-15% there,
        which is why the default used to be TPU-only. Upload masks,
        staleness, and params stay bit-exact vs the two-call dispatch and
        the per-leaf reference on every pinned parity gate
        (tests/test_flat_plane.py, test_parity_engine_trainer.py,
        test_stale_ring.py, single-device and forced-8-device mesh);
        ``fuse_evals=False`` restores the two-call dispatch.
      group_evals: evaluate the second gradient with ≤R broadcast-point
        evaluations grouped by stale-iterate ring slot instead of
        gathering M per-worker rows (flat plane, indexed rules only).
        Weight traffic M× → R×, arithmetic × occupancy — a win only when
        the eval is weight-bandwidth-bound and R ≪ M; see
        ``flat.grouped_second_plane``. Opt-in (float-level differences vs
        the per-row vmap are possible).
      interpret: kernel-mode override for the flat ops (see kernels/ops.py:
        None = auto, True = Pallas interpret, False = compiled Pallas).
      resum_every: cohort-plane drift guard — every K cohort rounds,
        recompute ∇̄ from the host pool (fp64 accumulate) instead of
        trusting the incremental aggregate. 0 (default) = off; the
        incremental form is exact in real arithmetic and bit-pinned vs the
        dense plane, so the guard is belt-and-braces for very long runs.
    """

    def __init__(self, loss_fn: Callable, optimizer: Optimizer | None = None,
                 rule: CommRule | None = None, n_workers: int = 1, *,
                 fused: bool | None = None, fuse_evals: bool | None = None,
                 group_evals: bool = False, interpret=None,
                 resum_every: int = 0,
                 allow_adaptive_local_steps: bool = False):
        self.loss_fn = loss_fn
        self.rule = CommRule() if rule is None else rule
        self.strategy = strategy_for(self.rule)
        if self.rule.adapt_local_steps and not allow_adaptive_local_steps:
            raise ValueError(
                "adapt_local_steps adapts H against MEASURED communication "
                "time — the bare engine has no clock. Run it through the "
                "sim runtime (repro.sim, --runtime sim), which prices every "
                "round and passes the adapted schedule back in.")
        if optimizer is None:
            # delta-payload rules PRESCRIBE their server optimizer
            # (sgd(1.0) = periodic averaging, server Adam = FedAdam);
            # gradient rules default to the paper's fused AMSGrad.
            optimizer = (self.strategy.server_optimizer()
                         or FusedAMSGrad(lr=1e-3))
        self.optimizer = optimizer
        self.m = n_workers
        self.fused = True if fused is None else fused
        self._fuse_evals = (True if fuse_evals is None else fuse_evals)
        self._group_evals = group_evals
        self._interpret = interpret
        self.resum_every = resum_every
        self._fused_opt = isinstance(self.optimizer, FusedAMSGrad)
        self._layout: F.FlatLayout | None = None
        self._cohort_step = None
        self._vgrad = jax.vmap(jax.value_and_grad(loss_fn),
                               in_axes=(None, 0))
        self._vgrad_per = jax.vmap(jax.value_and_grad(loss_fn),
                                   in_axes=(0, 0))

    # ------------------------------------------------------------- state
    def init(self, params) -> EngineState:
        if not self.fused:
            return EngineState(
                step=jnp.zeros([], jnp.int32),
                params=params,
                opt_state=self.optimizer.init(params),
                comm=init_comm_state(self.strategy, params, self.m),
            )
        layout = F.layout_of(params)
        self._layout = layout
        params_flat = layout.pack(params)
        # comm storage follows the param dtype (as the reference plane
        # does) when it is uniform; mixed-dtype trees store fp32.
        grad_dtype = (layout.dtypes[0] if len(set(layout.dtypes)) == 1
                      else jnp.float32)
        opt_state = (self.optimizer.init_flat(layout.n_flat)
                     if self._fused_opt else self.optimizer.init(params))
        return EngineState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            opt_state=opt_state,
            comm=F.init_flat_comm_state(self.strategy, layout, params,
                                        self.m, grad_dtype=grad_dtype,
                                        params_flat=params_flat),
            params_flat=params_flat,
        )

    # -------------------------------------------------------------- step
    def step(self, state: EngineState, batch, participation=None,
             local_steps=None) -> tuple[EngineState, dict]:
        """One iteration of Algorithm 1. ``batch`` has leading axis M —
        or (H, M, ...) for a delta-payload rule running H local steps
        (see ``flat.batch_has_local_axis`` for the exact contract).

        ``participation`` ((M,) bool or None) masks uploads for
        partial-participation rounds (the sim runtime's knob); None keeps
        the compiled graph exactly as before. ``local_steps`` (None |
        scalar | (M,)) is the per-worker local-step count of a
        delta-payload round — the sim's adaptive schedule.
        """
        if self.fused:
            return self._step_flat(state, batch, participation, local_steps)
        k = state.step

        # Lines 4-15: the shared communication round.
        out = comm_round(self.strategy, state.comm, state.params, batch, k,
                         vgrad=self._vgrad, vgrad_per=self._vgrad_per,
                         participation=participation,
                         local_steps=local_steps)

        # Lines 16-17: server Adam update driven by ∇^k (eqs. 2a-2c).
        opt = (self.optimizer if not self._fused_opt
               else _as_protocol(self.optimizer))
        updates, opt_state = opt.update(
            nabla_f32(out.comm), state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        comm = record_progress(out.comm, tree_sq_norm(updates), k)

        new_state = EngineState(step=k + 1, params=params,
                                opt_state=opt_state, comm=comm)
        metrics = {"loss": jnp.mean(out.losses), **out.metrics}
        return new_state, metrics

    def _step_flat(self, state: EngineState, batch, participation=None,
                   local_steps=None):
        """The flat-plane hot path: one packed gradient plane per round,
        single-op comm math, fused server update with ||Δθ||² for free."""
        k = state.step
        layout = self._layout
        out = F.flat_comm_round(
            self.strategy, layout, state.comm, state.params,
            state.params_flat, batch, k, vgrad=self._vgrad,
            vgrad_per=self._vgrad_per, fuse_evals=self._fuse_evals,
            group_evals=self._group_evals,
            interpret=self._interpret, participation=participation,
            local_steps=local_steps)

        nabla = F.nabla_f32(out.comm)
        if self._fused_opt:
            theta, opt_state, dsq = self.optimizer.apply_flat(
                state.params_flat, state.opt_state, nabla,
                interpret=self._interpret)
            theta = layout.cast_roundtrip(theta)
            params = layout.unpack(theta)
        else:
            grad_tree = layout.unpack(
                nabla, dtypes=(np.dtype(np.float32),) * len(layout.dtypes))
            updates, opt_state = self.optimizer.update(
                grad_tree, state.opt_state, state.params)
            params = apply_updates(state.params, updates)
            dsq = tree_sq_norm(updates)
            theta = layout.pack(params)

        comm = F.record_progress(out.comm, dsq, k)
        new_state = EngineState(step=k + 1, params=params,
                                opt_state=opt_state, comm=comm,
                                params_flat=theta)
        metrics = {"loss": jnp.mean(out.losses), **out.metrics}
        return new_state, metrics

    # ------------------------------------------------------ cohort plane
    def init_cohort(self, params, *, pool_storage: str = "ram",
                    pool_path: str | None = None):
        """Cohort-virtualized state: (CohortEngineState, flat.WorkerPool).

        Device state is O(C·n) per round + O(n) server buffers + O(M)
        scalar vectors; the O(M·n) per-worker planes live in the returned
        host pool (``pool_storage="memmap"`` + ``pool_path`` spill them
        past RAM). Requires the fused plane; the server optimizer is the
        fused AMSGrad kernel or any protocol optimizer (delta-payload
        rules prescribe protocol servers — sgd(1.0) / server Adam — and
        run cohort-virtualized through the same round). The jitted cohort
        step is built here, once — not lazily per round.
        """
        if not self.fused:
            raise ValueError("the cohort plane requires fused=True")
        layout = F.layout_of(params)
        self._layout = layout
        # own the param buffers: the cohort step donates its state, and
        # the caller's arrays must survive the first round
        params = jax.tree.map(jnp.array, params)
        params_flat = layout.pack(params)
        grad_dtype = (layout.dtypes[0] if len(set(layout.dtypes)) == 1
                      else jnp.float32)
        server, pool = F.init_cohort_state(
            self.strategy, layout, params, self.m, grad_dtype=grad_dtype,
            params_flat=params_flat, pool_storage=pool_storage,
            pool_path=pool_path)
        if self._fused_opt:
            opt_state = self.optimizer.init_flat(layout.n_flat)
        else:
            # own the buffers: protocol-optimizer inits are zeros trees
            # XLA dedupes into ONE buffer, and the donating cohort step
            # must never see the same buffer twice
            opt_state = jax.tree.map(jnp.array, self.optimizer.init(params))
        state = CohortEngineState(
            step=jnp.zeros([], jnp.int32), params=params,
            opt_state=opt_state, server=server, params_flat=params_flat)
        self._adopt_pool(pool)
        return state, pool

    def _adopt_pool(self, pool) -> None:
        """Bind the cohort step to a pool's fused-plane layout (stacking
        order + storage dtype) and build the jitted step once."""
        if pool.plane_dtype is None:
            raise ValueError("the cohort step needs a uniform-dtype pool "
                             "(the fused staging block stacks the planes)")
        key = (pool.plane_order, np.dtype(pool.plane_dtype).str)
        if getattr(self, "_cohort_plane_key", None) != key:
            self._cohort_plane_key = key
            self._plane_order = pool.plane_order
            self._plane_dtype = pool.plane_dtype
            self._cohort_step = self._build_cohort_step()

    def _build_cohort_step(self):
        """The fused-block cohort step:
        ``step(state, fused, batch, cohort)`` with ``fused`` the
        (P, C, n_flat) gather block. The pipelined driver forwards
        overlapping rows into ``fused`` in a SEPARATE jitted patch before
        this runs (see flat.run_cohort_rounds) — serial and pipelined
        drive this one executable, which is what pins bit-exact parity."""
        layout = self._layout
        order, dtype = self._plane_order, self._plane_dtype

        def step(state, fused, batch, cohort):
            k = state.step
            rows = F.split_fused_rows(fused, order)
            out = F.flat_cohort_round(
                self.strategy, layout, state.server, rows, state.params,
                state.params_flat, batch, k, cohort, m_total=self.m,
                vgrad=self._vgrad, vgrad_per=self._vgrad_per,
                fuse_evals=self._fuse_evals, interpret=self._interpret)
            nabla = out.server.nabla.astype(jnp.float32)
            if self._fused_opt:
                theta, opt_state, dsq = self.optimizer.apply_flat(
                    state.params_flat, state.opt_state, nabla,
                    interpret=self._interpret)
                theta = layout.cast_roundtrip(theta)
                params = layout.unpack(theta)
            else:
                # protocol server (delta-payload rules): mirror _step_flat
                grad_tree = layout.unpack(
                    nabla,
                    dtypes=(np.dtype(np.float32),) * len(layout.dtypes))
                updates, opt_state = self.optimizer.update(
                    grad_tree, state.opt_state, state.params)
                params = apply_updates(state.params, updates)
                dsq = tree_sq_norm(updates)
                theta = layout.pack(params)
            server = F.record_progress(out.server, dsq, k)
            new_state = CohortEngineState(
                step=k + 1, params=params,
                opt_state=opt_state, server=server, params_flat=theta)
            metrics = {"loss": jnp.mean(out.losses), **out.metrics}
            return new_state, F.stack_fused_rows(out.rows, order,
                                                 dtype), metrics

        # the gathered block and the previous state are both dead after
        # the round — donate them, so the device never holds two copies
        # of the cohort plane
        return jax.jit(step, donate_argnums=(0, 1))

    def step_cohort(self, state: CohortEngineState, pool, batch, cohort):
        """One eager cohort round: gather the C sampled rows from the host
        pool (one fused H2D), run the jitted round + fused server update,
        scatter the block back (one D2H). ``batch`` holds ONLY the cohort
        rows ((C, b, ...) leaves); ``cohort`` is sorted ascending (the
        gather enforces it). Multi-round callers should prefer
        :meth:`run_cohort`, which pipelines the transfers."""
        cohort = np.sort(np.asarray(cohort).astype(np.int32))
        self._adopt_pool(pool)
        fused = pool.gather_fused(cohort)
        state, out, metrics = self._cohort_step(
            state, fused, batch, jnp.asarray(cohort))
        pool.scatter_fused(cohort, out)
        return state, metrics

    def run_cohort(self, state: CohortEngineState, pool, batches, cohorts,
                   *, pipeline: bool = True, metrics_every: int = 8,
                   trace=None, metrics_out: list | None = None):
        """Multi-round cohort driver over a precomputed (T, C) schedule.

        ``batches`` is a list/tuple of per-round cohort batches, a stacked
        tree with a leading rounds axis, or a callable
        ``batches(i, cohort) -> batch``. ``pipeline=True`` (default) runs
        the double-buffered transfer pipeline — bit-exact to
        ``pipeline=False``, the serial oracle (flat.run_cohort_rounds
        documents the mechanism). Metrics are fetched every
        ``metrics_every`` rounds; the returned list holds HOST-side metric
        dicts. Applies the ``resum_every`` drift guard (the driver drains
        the pipeline before each re-sum). ``trace`` (an
        ``obs.trace.Tracer`` or None) records per-round
        gather/patch/step/scatter spans on the ``"pipeline"`` track;
        ``metrics_out`` (a list) receives fetched metrics incrementally,
        surviving mid-run exceptions. Returns (state, metrics).
        """
        cohorts = np.asarray(cohorts, np.int32)
        self._adopt_pool(pool)
        if callable(batches):
            batch_fn = batches
        elif isinstance(batches, (list, tuple)):
            batch_fn = lambda i, _c: batches[i]             # noqa: E731
        else:
            batch_fn = lambda i, _c: jax.tree.map(          # noqa: E731
                lambda b: b[i], batches)
        on_round = None
        if self.resum_every:
            def on_round(_i, st):
                nabla = jnp.asarray(pool.resum_nabla()).astype(
                    st.server.nabla.dtype)
                return st._replace(server=st.server._replace(nabla=nabla))
        return F.run_cohort_rounds(
            self._cohort_step, state, pool, batch_fn, cohorts,
            pipeline=pipeline, metrics_every=metrics_every,
            on_round=on_round, on_round_every=self.resum_every,
            trace=trace, metrics_out=metrics_out)

    # --------------------------------------------------------------- run
    def run(self, state: EngineState, batches, participation=None,
            local_steps=None) -> tuple[EngineState, dict]:
        """Scan over pre-sampled batches with leading axis (steps, M, ...)
        — (steps, H, M, ...) for a delta-payload rule running H local
        steps per round.

        ``participation`` ((steps, M) bool or None) feeds per-round
        partial-participation masks into the scan; None compiles the exact
        pre-existing graph (the sim's degenerate-parity anchor).
        ``local_steps`` ((steps, M) int32 or None) is the sim's adapted
        per-round local-step schedule for delta-payload rules.
        """
        if participation is None and local_steps is None:
            def body(s, b):
                return self.step(s, b)
            return jax.lax.scan(body, state, batches)

        if local_steps is None:
            def body_p(s, xs):
                b, p = xs
                return self.step(s, b, p)
            return jax.lax.scan(body_p, state, (batches, participation))

        if participation is None:
            def body_h(s, xs):
                b, h = xs
                return self.step(s, b, local_steps=h)
            return jax.lax.scan(body_h, state, (batches, local_steps))

        def body_ph(s, xs):
            b, p, h = xs
            return self.step(s, b, p, local_steps=h)
        return jax.lax.scan(body_ph, state,
                            (batches, participation, local_steps))


def _as_protocol(fused: FusedAMSGrad) -> Optimizer:
    from repro.optim.fused import as_optimizer
    return as_optimizer(fused)


def sample_cohorts(m: int, c: int, steps: int, seed: int = 0) -> np.ndarray:
    """(steps, C) int32 SORTED cohort ids, one independent draw per round,
    seeded per (seed, round) exactly like ``sim.events.ParticipationModel``
    so a cohort schedule and a participation-mask schedule with the same
    seed describe the same runs."""
    out = np.empty((steps, c), np.int32)
    for k in range(steps):
        rng = np.random.default_rng((seed, k))
        out[k] = np.sort(rng.choice(m, c, replace=False))
    return out


def cohorts_to_participation(cohorts: np.ndarray, m: int) -> np.ndarray:
    """(steps, M) bool participation masks equivalent to a (steps, C)
    cohort schedule — the dense-plane oracle's input for cohort parity."""
    steps = cohorts.shape[0]
    masks = np.zeros((steps, m), bool)
    masks[np.arange(steps)[:, None], cohorts] = True
    return masks


def make_sampler(x: np.ndarray, y: np.ndarray, shard_index: np.ndarray,
                 batch_size: int):
    """Per-worker minibatch sampler over a (M, n_pad) shard-index matrix.

    Returns ``sample(rng) -> (xb, yb)`` with shapes (M, b, ...), (M, b);
    device-resident and jittable.
    """
    xd = jnp.asarray(x)
    yd = jnp.asarray(y)
    idx = jnp.asarray(shard_index)
    m, n_pad = idx.shape

    def sample(rng):
        pos = jax.random.randint(rng, (m, batch_size), 0, n_pad)
        flat = jnp.take_along_axis(idx, pos, axis=1)      # (M, b) global ids
        return xd[flat], yd[flat]

    return sample


def make_cohort_sampler(x: np.ndarray, y: np.ndarray,
                        shard_index: np.ndarray, batch_size: int):
    """Cohort twin of :func:`make_sampler`: draws batches ONLY for the C
    sampled workers — ``sample(rng, cohort) -> (xb, yb)`` with (C, b, ...)
    leaves. This is what makes federated M ≥ 10⁴ runs fit: batch storage
    is O(C·b), not O(M·b). The draws are NOT row-matched to
    :func:`make_sampler` (a (C, b) randint is a different stream than
    slicing a (M, b) one) — cohort-vs-dense parity tests slice full
    batches instead.
    """
    xd = jnp.asarray(x)
    yd = jnp.asarray(y)
    idx = jnp.asarray(shard_index)
    n_pad = idx.shape[1]

    def sample(rng, cohort):
        c = cohort.shape[0]
        pos = jax.random.randint(rng, (c, batch_size), 0, n_pad)
        flat = jnp.take_along_axis(idx[cohort], pos, axis=1)
        return xd[flat], yd[flat]

    return sample
