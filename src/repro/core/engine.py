"""Paper-faithful server/worker engine for CADA and per-iteration baselines.

This is the reference implementation of Algorithm 1: a (virtual) server and M
workers, simulated SPMD-style on however many devices are present — worker
gradients are a ``vmap`` over the worker axis, so the same code runs on one
CPU (paper experiments) or sharded (see `repro.distributed` for the
mesh/pod-level runtime).

The engine keeps ONLY the vmap/scan harness and the server optimizer; the
entire communication round (rule LHS/RHS, staleness cap, eq. 3 innovation
aggregation, quantize hook, accounting) is :func:`repro.core.comm.comm_round`
— the SAME core the pod trainer consumes, so the two cannot drift. Per-rule
behaviour lives in the :mod:`repro.core.comm` strategy objects; this module
contains no rule dispatch.

The engine is a pure ``(state, batch) -> (state, metrics)`` step, jittable
and scannable. Communication is *accounted* exactly as the paper counts it:
one "upload" per worker per iteration in which the rule fires (|M^k| uploads
at iteration k), and per-rule gradient evaluations (2 for CADA1/2, 1
otherwise) as reported by the strategy.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import (CommState, comm_round, init_comm_state,
                             nabla_f32, record_progress, strategy_for)
from repro.core.rules import CommRule
from repro.optim.base import Optimizer, apply_updates
from repro.utils.trees import tree_sq_norm


class EngineState(NamedTuple):
    step: jnp.ndarray            # k
    params: Any                  # θ^k (server copy)
    opt_state: Any               # Adam/AMSGrad moments {h, v, v̂}
    comm: CommState              # Algorithm-1 communication state


class CADAEngine:
    """Server + M workers running Algorithm 1 (or a per-iteration baseline).

    Args:
      loss_fn: scalar loss ``loss_fn(params, (x, y))`` for ONE worker batch.
      optimizer: the server optimizer (paper: AMSGrad-form Adam). The LAG
        baseline is usually paired with plain SGD, as in the paper.
      rule: the communication rule (any kind registered in core/comm.py).
      n_workers: M.
    """

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 rule: CommRule, n_workers: int):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.rule = rule
        self.strategy = strategy_for(rule)
        self.m = n_workers
        self._vgrad = jax.vmap(jax.value_and_grad(loss_fn),
                               in_axes=(None, 0))
        self._vgrad_per = jax.vmap(jax.value_and_grad(loss_fn),
                                   in_axes=(0, 0))

    # ------------------------------------------------------------- state
    def init(self, params) -> EngineState:
        return EngineState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            opt_state=self.optimizer.init(params),
            comm=init_comm_state(self.strategy, params, self.m),
        )

    # -------------------------------------------------------------- step
    def step(self, state: EngineState, batch) -> tuple[EngineState, dict]:
        """One iteration of Algorithm 1. ``batch`` has leading axis M."""
        k = state.step

        # Lines 4-15: the shared communication round.
        out = comm_round(self.strategy, state.comm, state.params, batch, k,
                         vgrad=self._vgrad, vgrad_per=self._vgrad_per)

        # Lines 16-17: server Adam update driven by ∇^k (eqs. 2a-2c).
        updates, opt_state = self.optimizer.update(
            nabla_f32(out.comm), state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        comm = record_progress(out.comm, tree_sq_norm(updates), k)

        new_state = EngineState(step=k + 1, params=params,
                                opt_state=opt_state, comm=comm)
        metrics = {"loss": jnp.mean(out.losses), **out.metrics}
        return new_state, metrics

    # --------------------------------------------------------------- run
    def run(self, state: EngineState, batches) -> tuple[EngineState, dict]:
        """Scan over pre-sampled batches with leading axis (steps, M, ...)."""
        def body(s, b):
            return self.step(s, b)
        return jax.lax.scan(body, state, batches)


def make_sampler(x: np.ndarray, y: np.ndarray, shard_index: np.ndarray,
                 batch_size: int):
    """Per-worker minibatch sampler over a (M, n_pad) shard-index matrix.

    Returns ``sample(rng) -> (xb, yb)`` with shapes (M, b, ...), (M, b);
    device-resident and jittable.
    """
    xd = jnp.asarray(x)
    yd = jnp.asarray(y)
    idx = jnp.asarray(shard_index)
    m, n_pad = idx.shape

    def sample(rng):
        pos = jax.random.randint(rng, (m, batch_size), 0, n_pad)
        flat = jnp.take_along_axis(idx, pos, axis=1)      # (M, b) global ids
        return xd[flat], yd[flat]

    return sample
