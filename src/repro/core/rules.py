"""CADA communication-rule hyper-parameters (paper eqs. 5, 7, 10).

A rule decides, per worker and per iteration, whether the fresh stochastic
gradient is informative enough to upload. All rules share the RHS
    (c/d_max) * Σ_{d=1..d_max} ||θ^{k+1-d} − θ^{k-d}||²
(the recent-progress measure, tracked as a ring buffer of d_max scalars) and
the max-staleness override τ_m ≥ D.

This module holds only the HYPER-PARAMETERS. The per-rule behaviour — LHS
computation, extra state slices, post-upload transitions, accounting —
lives in first-class strategy objects in :mod:`repro.core.comm`; the
``kind`` string selects one via ``comm.strategy_for(rule)``.

Observability: every rule's decisions are ledgered per run by
:class:`repro.obs.metrics.CommLedger` — uploads and bytes split by the
strategy's ``wire_format`` (dense/quantized/sparse), the LHS−RHS gate
margins (how decisively each rule fires), and the staleness histogram
its ``max_delay`` cap produced. Ledger byte totals reuse the strategy's
property-pinned ``bytes_per_upload`` accounting bit-for-bit; see
``src/repro/obs/README.md``. The rules:

  * ``cada1``  (eq. 7)  — SVRG-style innovation vs. a snapshot θ̃ refreshed
    every D iterations:  ||δ̃_m^k − δ̃_m^{k−τ}||² ≤ RHS.
  * ``cada2``  (eq. 10) — same-sample two-iterate difference:
    ||∇ℓ(θ^k;ξ_m^k) − ∇ℓ(θ^{k−τ_m};ξ_m^k)||² ≤ RHS.
    The flat plane stores the stale iterates θ^{k−τ_m} as a STALE-ITERATE
    RING, not per-worker copies: staleness ≤ ``max_delay`` = D bounds the
    number of distinct global iterates among the M stale points at D+1,
    so R = min(M, D)+1 ring rows plus an (M,) slot index represent them
    exactly — O(D·n) eval-point state instead of O(M·n), bit-exact vs the
    dense plane (the pytree reference keeps the dense form as the
    oracle). The second evaluation then runs STACKED onto the fresh eval
    by default (``fuse_evals``: one vmapped call, the 2-way eval axis
    broadcasts the batch instead of copying it — measured ~38% → ~16%
    cada2 gating overhead on the CPU logreg bench, bit-exact on every
    pinned parity gate), or GROUPED (``group_evals``: ≤R broadcast-point
    evals, weight traffic R× instead of M× — opt-in; wins only when the
    eval is weight-bandwidth-bound and R ≪ M, loses at bench scale where
    R > M), or as the plain gathered per-worker vmap
    (``fuse_evals=False``), the reference form.
  * ``lag``    (eq. 5)  — naive stochastic LAG (different samples — shown
    ineffective in §2.1; reproduced as a baseline).
  * ``always``          — threshold never satisfied ⇒ distributed Adam.

Beyond-paper rules (the compressed-upload family — these both SKIP uploads
like the paper's rules AND shrink the uploads that do happen):

  * ``cinn`` — compressed-innovation gating: upload iff the b-bit quantized
    innovation ||Q_b(δ_m)||² exceeds the RHS (arXiv 2111.00705 family);
    ``quantize_bits`` (default 8) sets the wire width.
  * ``laq``  — full LAQ [Sun et al., 2019]: each worker carries an
    error-feedback residual e_m across rounds; the wire is Q_b(δ_m + e_m)
    and e_m accumulates the quantization error after every upload.
    ``error_feedback=False`` is the memory-free variant — see
    ``comm.LAQStrategy`` for the error-retention semantics (the lazy
    innovation already re-injects compression error once, so the textbook
    residual doubles the band; prefer False at b ≤ 4). Uploads are
    accounted at ``quantize_bits`` (default 8) bits per entry.
  * ``topk`` — top-k sparsified innovation with error feedback
    (arXiv 2112.04088 style): only the ``topk_frac`` largest-magnitude
    entries of δ_m + e_m ride the wire (per worker, per leaf); the dropped
    mass lands in e_m. Uploads are accounted SPARSELY as
    k·(value_bits + index_bits) with k = ⌈topk_frac·n⌉,
    value_bits = ``quantize_bits`` or 32, and index_bits = ⌈log₂ n⌉ —
    NOT as n·32. With ``sparse_wire=True`` the flat plane also SHIPS the
    sparse form: (values, indices) pairs sized k cross the simulated
    collective instead of the dense masked plane (bit-equal reconstruction
    — see ``flat.per_worker_topk_extract_flat``).
  * ``avp``  — variance-adaptive upload period (arXiv 2007.06134 style):
    each worker keeps its own integer period p_m ∈ [period_min,
    period_max] and uploads when its staleness reaches p_m; p_m shrinks
    while the innovation energy exceeds the shared recent-progress RHS and
    grows when it does not. One gradient evaluation per iteration — the
    adaptation reads the RHS ring, never a second evaluation.
    ``avp_compose=True`` composes the period gate with the CADA LHS check:
    a worker uploads only when it is due AND its innovation energy clears
    the RHS (the period becomes a floor on upload spacing instead of a
    schedule; the max-staleness cap still forces eventually).

The PAYLOAD/CADENCE axis (beyond-paper — the federated baselines of the
paper's experiments, rebuilt on the strategy layer):

  * ``local_momentum`` — local SGD-with-momentum: each worker runs H =
    ``local_steps`` local steps (lr ``local_lr``, momentum ``local_beta``)
    between rounds and ships the accumulated MODEL DELTA θ^k − θ_m^(H);
    every round uploads (cadence lives in H, not in skipping), the
    prescribed server optimizer is sgd(1.0), so the server update is
    exactly periodic model averaging. Worker momenta are per-worker
    n-vectors, averaged across the round's uploaders after every round
    → POOLED on the cohort plane. H=1 is per-iteration momentum SGD.
  * ``fedadam`` — FedAdam (Reddi et al., arXiv 2003.00295): plain local
    SGD steps (no momentum), same delta payload, with the prescribed
    server optimizer Adam(lr=``server_lr``) driving θ from the mean
    delta. No per-worker state beyond the gradient row.

  Both compose with ``quantize_bits`` (the delta wire rides the same
  ``wire_delta`` round-trip as the gradient rules — compressed local
  updates for free). ``adapt_local_steps`` adapts H per worker against
  the COMM TIME the sim's link model observes (adaptive periodic
  averaging, Jiang & Agrawal): H grows while a round's communication
  time exceeds its compute time, shrinks otherwise, clipped to
  [``local_steps_min``, ``local_steps_max``] — the same ±1 adaptation
  avp applies to upload periods (``comm.adapt_period``), driven by
  wall-clock instead of innovation energy. Adaptation therefore REQUIRES
  the sim runtime (``--runtime sim``); the bare engines have no clock.

The RUNTIME axis is orthogonal to the rule axis: every rule above runs
under (a) the synchronous engines (``core/engine.py`` /
``distributed/trainer.py`` — rounds, no clock), and (b) the discrete-event
heterogeneous-cluster runtime (:mod:`repro.sim` — simulated wall-clock
with per-worker compute/link models, stragglers, partial participation,
and a bounded-staleness ASYNC mode where the server applies the fused
Adam update per arriving upload and workers gate with these same
strategies against their stale copy, staleness capped at τ_max). The
rules' ``bytes_per_upload`` accounting is what the sim's link model
prices, so the compressed-upload family's savings become wall-clock
savings under expensive links (``--runtime sim --network wan``); see
``src/repro/sim/README.md``.

The WORKER-PLANE axis is orthogonal to both: every rule also runs
cohort-virtualized (``engine.init_cohort`` / ``sim`` ``cohort_size=``),
where per round only the C sampled workers' rows exist on device and the
O(M·n) per-worker planes live in a host ``flat.WorkerPool``. What each
rule keeps per worker decides what gets pooled
(``comm.Strategy.pooled_extras``):

  * ``cada1`` — the snapshot innovation δ̃_m is a per-worker n-vector →
    POOLED (gathered/scattered with the gradient row); the snapshot θ̃
    itself is one shared n-vector and stays on device.
  * ``cada2`` — the stale-iterate ring is R shared iterates + an (M,)
    slot index, all server-side; nothing per-worker beyond the gradient
    row. The ring's slot refcounting updates through a cohort scatter
    over the full (M,) slot vector.
  * ``laq`` / ``topk`` — the error-feedback residual e_m is a per-worker
    n-vector → POOLED iff ``error_feedback`` (the memory-free variants
    pool only the gradient row).
  * ``avp`` — per-worker periods p_m are one (M,) integer vector →
    stays on device (O(M) scalars, not O(M·n) planes), updated at
    cohort indices.
  * ``lag`` / ``always`` / ``cinn`` — the gradient row only.

Staleness is always an (M,) device vector (non-sampled workers age by
one per round). Every cohort round is bit-exact to the dense plane run
with the cohort's indicator mask as participation
(``tests/test_cohort_plane.py``, all 8 rules).
"""
from __future__ import annotations

from dataclasses import dataclass

RULES = ("cada1", "cada2", "lag", "always", "cinn", "laq", "topk", "avp")
#: the delta-payload family (ships local-step model deltas, not gradients)
LOCAL_RULES = ("local_momentum", "fedadam")


@dataclass(frozen=True)
class CommRule:
    """Hyper-parameters of the adaptive-communication condition."""
    kind: str = "cada2"
    c: float = 0.6          # threshold constant (paper grid {0.05..1.8})
    d_max: int = 10         # averaging window of the RHS (paper: 10 / 2)
    max_delay: int = 50     # D — forces an upload and snapshot period
    quantize_bits: int = 0  # 0 = rule default; b-bit uniform innovation
    #                         upload (LAQ-style composition — beyond-paper;
    #                         the ``cinn``/``laq`` rules default to 8 bits)
    error_feedback: bool = True  # laq/topk: carry the per-worker residual
    #                              e_m across rounds (False = drop the
    #                              compression error instead)
    topk_frac: float = 0.1  # topk: fraction of innovation entries uploaded
    sparse_wire: bool = False  # topk: ship (values, indices) pairs sized k
    #                            through the flat-plane collective instead
    #                            of the dense masked plane
    period_min: int = 1     # avp: per-worker upload-period lower bound
    period_max: int = 0     # avp: upper bound (0 = max_delay)
    avp_compose: bool = False  # avp: upload only when due AND the
    #                            innovation energy clears the CADA RHS
    local_steps: int = 1    # delta-payload rules: local optimizer steps H
    #                         per comm round (1 = per-iteration payload)
    local_lr: float = 0.1   # delta-payload rules: local SGD learning rate
    local_beta: float = 0.9  # local_momentum: local momentum coefficient
    server_lr: float = 0.01  # fedadam: server Adam learning rate
    adapt_local_steps: bool = False  # adapt H per worker from measured
    #                                  comm vs compute time (sim runtime
    #                                  only — the engines have no clock)
    local_steps_min: int = 1  # adaptive-H lower bound
    local_steps_max: int = 0  # adaptive-H upper bound (0 = max_delay,
    #                           mirroring avp's period bound)

    def __post_init__(self):
        # validate against the live strategy registry (late import — comm.py
        # depends on this module), so a newly registered strategy is
        # constructible without editing this file; RULES documents the
        # built-in set.
        from repro.core.comm import strategy_kinds
        if self.kind not in strategy_kinds():
            raise ValueError(
                f"rule kind must be one of {strategy_kinds()}")
        if self.d_max < 1 or self.max_delay < 1:
            raise ValueError("d_max and max_delay must be >= 1")
        if self.c < 0:
            raise ValueError("threshold c must be >= 0")
        if self.quantize_bits and not 2 <= self.quantize_bits < 32:
            raise ValueError("quantize_bits must be 0 or in [2, 32)")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError("topk_frac must be in (0, 1]")
        if self.period_min < 1 or self.period_max < 0:
            raise ValueError("period_min must be >= 1 and period_max >= 0")
        if self.resolved_period_max < self.period_min:
            raise ValueError(
                f"period_max ({self.resolved_period_max}) must be >= "
                f"period_min ({self.period_min})")
        if self.local_steps < 1:
            raise ValueError("local_steps must be >= 1")
        if self.local_lr <= 0:
            raise ValueError("local_lr must be > 0")
        if not 0.0 <= self.local_beta < 1.0:
            raise ValueError("local_beta must be in [0, 1)")
        if self.server_lr <= 0:
            raise ValueError("server_lr must be > 0")
        if self.local_steps_min < 1 or self.local_steps_max < 0:
            raise ValueError(
                "local_steps_min must be >= 1 and local_steps_max >= 0")
        if self.resolved_local_steps_max < self.local_steps_min:
            raise ValueError(
                f"local_steps_max ({self.resolved_local_steps_max}) must "
                f"be >= local_steps_min ({self.local_steps_min})")
        if self.local_steps > 1 or self.adapt_local_steps:
            from repro.core.comm import STRATEGIES
            if not STRATEGIES[self.kind].delta_payload:
                raise ValueError(
                    f"kind={self.kind!r} ships per-iteration gradients; "
                    "local_steps > 1 / adapt_local_steps need a "
                    f"delta-payload rule ({LOCAL_RULES})")

    @property
    def resolved_period_max(self) -> int:
        """avp upper period bound: explicit, or the staleness cap D."""
        return self.period_max or self.max_delay

    @property
    def resolved_local_steps_max(self) -> int:
        """Adaptive-H upper bound: explicit, or the staleness cap D
        (the same default cap avp applies to its upload periods)."""
        return self.local_steps_max or self.max_delay

    def rhs(self, diff_hist):
        """The shared recent-progress RHS, (c/d_max)·Σ_d ||θ^{k+1-d}−θ^{k-d}||².

        The ONE home of the formula: both Algorithm-1 rounds gate against
        it and avp adapts its periods against it — they cannot drift.
        """
        import jax.numpy as jnp
        return (self.c / self.d_max) * jnp.sum(diff_hist)

    @property
    def grad_evals_per_iter(self) -> int:
        """Worker-side gradient evaluations per iteration (paper §2.2).

        Delegates to the rule's strategy object (late import: comm.py
        depends on this module for the hyper-parameter container).
        """
        from repro.core.comm import strategy_for
        return strategy_for(self).grad_evals_per_iter
