"""CADA communication rules (paper eqs. 5, 7, 10).

A rule decides, per worker and per iteration, whether the fresh stochastic
gradient is informative enough to upload. All rules share the RHS
    (c/d_max) * Σ_{d=1..d_max} ||θ^{k+1-d} − θ^{k-d}||²
(the recent-progress measure, tracked as a ring buffer of d_max scalars) and
the max-staleness override τ_m ≥ D.

Rules:
  * ``cada1`` (eq. 7)  — SVRG-style innovation vs. a snapshot θ̃ refreshed
    every D iterations:  ||δ̃_m^k − δ̃_m^{k−τ}||² ≤ RHS.
  * ``cada2`` (eq. 10) — same-sample two-iterate difference:
    ||∇ℓ(θ^k;ξ_m^k) − ∇ℓ(θ^{k−τ_m};ξ_m^k)||² ≤ RHS.
  * ``lag``   (eq. 5)  — naive stochastic LAG (different samples — shown
    ineffective in §2.1; reproduced as a baseline).
  * ``always``          — threshold never satisfied ⇒ distributed Adam.
"""
from __future__ import annotations

from dataclasses import dataclass

RULES = ("cada1", "cada2", "lag", "always")


@dataclass(frozen=True)
class CommRule:
    """Hyper-parameters of the adaptive-communication condition."""
    kind: str = "cada2"
    c: float = 0.6          # threshold constant (paper grid {0.05..1.8})
    d_max: int = 10         # averaging window of the RHS (paper: 10 / 2)
    max_delay: int = 50     # D — forces an upload and snapshot period
    quantize_bits: int = 0  # 0 = off; b-bit uniform innovation upload
    #                         (LAQ-style composition — beyond-paper)

    def __post_init__(self):
        if self.kind not in RULES:
            raise ValueError(f"rule kind must be one of {RULES}")
        if self.d_max < 1 or self.max_delay < 1:
            raise ValueError("d_max and max_delay must be >= 1")
        if self.c < 0:
            raise ValueError("threshold c must be >= 0")
        if self.quantize_bits and not 2 <= self.quantize_bits < 32:
            raise ValueError("quantize_bits must be 0 or in [2, 32)")

    @property
    def grad_evals_per_iter(self) -> int:
        """Worker-side gradient evaluations per iteration (paper §2.2)."""
        return 2 if self.kind in ("cada1", "cada2") else 1
