"""CADA communication-rule hyper-parameters (paper eqs. 5, 7, 10).

A rule decides, per worker and per iteration, whether the fresh stochastic
gradient is informative enough to upload. All rules share the RHS
    (c/d_max) * Σ_{d=1..d_max} ||θ^{k+1-d} − θ^{k-d}||²
(the recent-progress measure, tracked as a ring buffer of d_max scalars) and
the max-staleness override τ_m ≥ D.

This module holds only the HYPER-PARAMETERS. The per-rule behaviour — LHS
computation, extra state slices, post-upload transitions, accounting —
lives in first-class strategy objects in :mod:`repro.core.comm`; the
``kind`` string selects one via ``comm.strategy_for(rule)``:

  * ``cada1``  (eq. 7)  — SVRG-style innovation vs. a snapshot θ̃ refreshed
    every D iterations:  ||δ̃_m^k − δ̃_m^{k−τ}||² ≤ RHS.
  * ``cada2``  (eq. 10) — same-sample two-iterate difference:
    ||∇ℓ(θ^k;ξ_m^k) − ∇ℓ(θ^{k−τ_m};ξ_m^k)||² ≤ RHS.
  * ``lag``    (eq. 5)  — naive stochastic LAG (different samples — shown
    ineffective in §2.1; reproduced as a baseline).
  * ``always``          — threshold never satisfied ⇒ distributed Adam.
  * ``cinn``  (beyond-paper) — compressed-innovation gating: upload iff the
    b-bit quantized innovation ||Q_b(δ_m)||² exceeds the RHS (LAQ /
    arXiv 2111.00705 family); proves the strategy layer's extensibility.
"""
from __future__ import annotations

from dataclasses import dataclass

RULES = ("cada1", "cada2", "lag", "always", "cinn")


@dataclass(frozen=True)
class CommRule:
    """Hyper-parameters of the adaptive-communication condition."""
    kind: str = "cada2"
    c: float = 0.6          # threshold constant (paper grid {0.05..1.8})
    d_max: int = 10         # averaging window of the RHS (paper: 10 / 2)
    max_delay: int = 50     # D — forces an upload and snapshot period
    quantize_bits: int = 0  # 0 = rule default; b-bit uniform innovation
    #                         upload (LAQ-style composition — beyond-paper;
    #                         the ``cinn`` rule defaults to 8 bits)

    def __post_init__(self):
        # validate against the live strategy registry (late import — comm.py
        # depends on this module), so a newly registered strategy is
        # constructible without editing this file; RULES documents the
        # built-in set.
        from repro.core.comm import strategy_kinds
        if self.kind not in strategy_kinds():
            raise ValueError(
                f"rule kind must be one of {strategy_kinds()}")
        if self.d_max < 1 or self.max_delay < 1:
            raise ValueError("d_max and max_delay must be >= 1")
        if self.c < 0:
            raise ValueError("threshold c must be >= 0")
        if self.quantize_bits and not 2 <= self.quantize_bits < 32:
            raise ValueError("quantize_bits must be 0 or in [2, 32)")

    @property
    def grad_evals_per_iter(self) -> int:
        """Worker-side gradient evaluations per iteration (paper §2.2).

        Delegates to the rule's strategy object (late import: comm.py
        depends on this module for the hyper-parameter container).
        """
        from repro.core.comm import strategy_for
        return strategy_for(self).grad_evals_per_iter
