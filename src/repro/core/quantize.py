"""Lossy innovation compression: uniform quantization, top-k
sparsification, and error-feedback residuals (LAQ-style compositions).

The CADA paper's closest sibling, LAQ [Sun et al., 2019], combines the
lazy-upload rule with QUANTIZED innovations: workers upload b-bit uniform
quantizations of δ_m, and both sides apply the same dequantized value so
server and worker stale copies stay bit-identical.

Per-leaf symmetric uniform quantization with a max-abs scale:
    q = round(x / s · (2^(b-1) − 1)),   x̂ = q · s / (2^(b-1) − 1)
Deterministic rounding (reproducible); the quantization error is bounded
by s / 2^b per entry, which preserves the CADA rule's variance-reduction
argument (the error enters eq. (9) as an O(2^{-2b}) additive term).

Top-k keeps only the k largest-magnitude entries per (worker, leaf); error
feedback carries the dropped/rounded mass in a per-worker residual e_m:
    wire_m = C(δ_m + e_m),   e_m ← (δ_m + e_m) − wire_m   (on upload)
so the compression error re-enters later innovations instead of being lost
(the classic EF-SGD argument transfers — compressed mass is delayed, not
discarded). ``ef_correct``/``ef_residual`` are dtype-polymorphic tree maps,
so they serve BOTH state planes: pytrees of (M, ...) leaves and bare
(M, n_flat) buffers (a bare array is a one-leaf pytree).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def quantize_dequantize(tree, bits: int):
    """Round-trip b-bit uniform quantization of every leaf (what the server
    receives); returns the same pytree structure in fp32."""
    if bits <= 0 or bits >= 32:
        return tree
    levels = float(2 ** (bits - 1) - 1)

    def leaf(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
        q = jnp.round(xf / scale * levels)
        return (q * scale / levels).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def per_worker_quantize_dequantize(tree, bits: int):
    """Same, but leaves carry a leading worker axis: scales are per worker
    (axis 0), matching what each worker would transmit independently."""
    if bits <= 0 or bits >= 32:
        return tree
    levels = float(2 ** (bits - 1) - 1)

    def leaf(x):
        xf = x.astype(jnp.float32)
        axes = tuple(range(1, xf.ndim))
        scale = jnp.maximum(
            jnp.max(jnp.abs(xf), axis=axes, keepdims=True), 1e-12)
        q = jnp.round(xf / scale * levels)
        return (q * scale / levels).astype(x.dtype)

    return jax.tree.map(leaf, tree)


# ------------------------------------------------------------------- top-k

def topk_count(size: int, frac: float) -> int:
    """Entries kept per worker for a leaf/segment of ``size`` (at least 1)."""
    return max(1, min(size, int(np.ceil(frac * size))))


def topk_threshold_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """(M, s) bool mask of the k largest-|x| entries per row — EXACTLY k.

    Ties at the kth magnitude break deterministically toward the LOWER
    index (``lax.top_k``'s stable order), so the mask is identical however
    the row is stored (pytree leaf or flat segment — packing preserves
    index order, the property that keeps the two sparsifiers bit-equal),
    the kept count always matches the k the sparse accounting charges for,
    and the (values, indices) sparse wire payload carries the support
    entry for entry. (The previous |x| >= kth THRESHOLD form kept every
    tie — under systematic ties, e.g. a 2-class softmax whose per-feature
    gradient columns are exact negations, it shipped more than k entries
    than the wire pays for and than a fixed-k payload can carry.)
    """
    k = int(min(max(k, 1), x.shape[1]))
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    rows = jnp.arange(x.shape[0])[:, None]
    return jnp.zeros(x.shape, bool).at[rows, idx].set(True)


def per_worker_topk_sparsify(tree, frac: float):
    """Keep the top-⌈frac·size⌉ largest-magnitude entries per (worker,
    leaf); everything else becomes exactly zero. Leaves carry a leading
    worker axis."""
    if frac >= 1.0:
        return tree

    def leaf(x):
        xf = x.astype(jnp.float32)
        m = xf.shape[0]
        flat = xf.reshape(m, -1)
        mask = topk_threshold_mask(flat, topk_count(flat.shape[1], frac))
        return (flat * mask).reshape(xf.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree)


# ----------------------------------------------------------- error feedback

def ef_correct(delta, residual):
    """δ_m + e_m in fp32 — the innovation the compressor actually sees."""
    return jax.tree.map(
        lambda d, e: d.astype(jnp.float32) + e.astype(jnp.float32),
        delta, residual)


def ef_residual(corrected, wire, upload, residual):
    """Post-upload residual transition (storage dtype follows ``residual``):
    uploaders keep what their wire dropped, e_m ← (δ_m+e_m) − wire_m;
    skippers carry e_m unchanged (their unsent innovation re-enters the
    NEXT δ_m through the stale worker copy, not through e_m)."""
    def leaf(c, w, e):
        mm = upload.reshape((-1,) + (1,) * (c.ndim - 1))
        err = c.astype(jnp.float32) - w.astype(jnp.float32)
        return jnp.where(mm, err.astype(e.dtype), e)
    return jax.tree.map(leaf, corrected, wire, residual)
