"""Uniform gradient-innovation quantization (LAQ-style composition).

The CADA paper's closest sibling, LAQ [Sun et al., 2019], combines the
lazy-upload rule with QUANTIZED innovations: workers upload b-bit uniform
quantizations of δ_m, and both sides apply the same dequantized value so
server and worker stale copies stay bit-identical.

Per-leaf symmetric uniform quantization with a max-abs scale:
    q = round(x / s · (2^(b-1) − 1)),   x̂ = q · s / (2^(b-1) − 1)
Deterministic rounding (reproducible); the quantization error is bounded
by s / 2^b per entry, which preserves the CADA rule's variance-reduction
argument (the error enters eq. (9) as an O(2^{-2b}) additive term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_dequantize(tree, bits: int):
    """Round-trip b-bit uniform quantization of every leaf (what the server
    receives); returns the same pytree structure in fp32."""
    if bits <= 0 or bits >= 32:
        return tree
    levels = float(2 ** (bits - 1) - 1)

    def leaf(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
        q = jnp.round(xf / scale * levels)
        return (q * scale / levels).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def per_worker_quantize_dequantize(tree, bits: int):
    """Same, but leaves carry a leading worker axis: scales are per worker
    (axis 0), matching what each worker would transmit independently."""
    if bits <= 0 or bits >= 32:
        return tree
    levels = float(2 ** (bits - 1) - 1)

    def leaf(x):
        xf = x.astype(jnp.float32)
        axes = tuple(range(1, xf.ndim))
        scale = jnp.maximum(
            jnp.max(jnp.abs(xf), axis=axes, keepdims=True), 1e-12)
        q = jnp.round(xf / scale * levels)
        return (q * scale / levels).astype(x.dtype)

    return jax.tree.map(leaf, tree)
