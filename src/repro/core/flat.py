"""Flat-buffer state plane: Algorithm 1's per-iteration math on contiguous
buffers instead of per-leaf pytrees.

Motivation (§Perf): the reference ``core/comm.py::comm_round`` and the
per-leaf jnp AMSGrad stream walk the parameter pytree ~15 times per
iteration — every ``tree_map`` is one more sweep over HBM (or, on CPU, one
more dispatched kernel inside the scanned step). This module packs the
gradient-shaped state ONCE into padded contiguous buffers and re-expresses
the whole communication round as a handful of whole-buffer ops:

  * :class:`FlatLayout` — a static description of a pytree's flat layout
    (per-leaf offsets/sizes/shapes/dtypes, total padded length ``n_flat``)
    with exact ``pack``/``unpack`` round-tripping, including an (M, n_flat)
    per-worker plane for M-leading trees. The layout is SHARDING-AWARE:
    built with ``shards=S``, ``n_flat`` is padded to a multiple of
    ``S · align`` so the flat axis splits into S equal contiguous shards —
    exactly the split a ``PartitionSpec`` over the state-shard mesh axes
    produces — and :meth:`shard_split`/:meth:`shard_merge` round-trip the
    per-shard view bit-exactly;
  * :class:`FlatCommState` — the Algorithm-1 communication state with
    ``nabla`` as one (n_flat,) buffer and every per-worker tree as one
    (M, n_flat) plane;
  * :func:`flat_comm_round` — the same Algorithm-1 round as
    ``comm.comm_round`` (lines 4-15), but the fresh−stale delta, the mask
    merge, the eq. (3) innovation aggregation and the rule LHS norms are
    single flat ops (the LHS norms via the batched Pallas kernel on TPU, a
    fused flat jnp fallback elsewhere — see ``kernels/ops.py``).

Rule-specific behaviour stays with the :mod:`repro.core.comm` strategy
objects — each strategy carries flat-plane hooks (``flat_lhs``,
``flat_post_upload``, ...) next to its reference pytree hooks, and the
fused-vs-reference engine parity test keeps the two in lockstep.

Model math is untouched: parameters remain a pytree for the loss/grad
evaluation, and the layout is the single conversion point between the two
worlds (gradients are packed once per iteration, right after ``vgrad``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import topk_count, topk_threshold_mask
from repro.kernels import ops as kops

# Minimal flat-buffer alignment. The Pallas wrappers in kernels/ops.py
# re-pad to whole kernel blocks on demand, so the layout itself stays lean:
# on CPU a (M, n_flat) plane carries almost no padding waste even for toy
# models (logreg: 46 -> 48), while TPU kernels see block-aligned buffers
# after the wrapper's pad.
PAD_ALIGN = 8


# ------------------------------------------------------------------- layout

@dataclass(frozen=True)
class FlatLayout:
    """Static flat layout of a pytree: one contiguous padded buffer.

    Hashable and comparable, so it can be closed over by jitted steps and
    compared across engine/trainer instances. ``n`` is the true scalar
    count, ``n_flat`` the padded buffer length (a multiple of both
    ``align`` and ``shards``); padding lanes are identically zero through
    every op in this module. ``shards`` is the state-shard count of the
    target mesh (1 = unsharded): shard ``s`` owns the contiguous slice
    ``[s·shard_len, (s+1)·shard_len)`` — the same equal contiguous split a
    ``PartitionSpec`` over the state-shard axes gives each device, so the
    layout, the sharding specs and the shard-local kernels all agree on
    where every parameter lives.
    """
    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple
    offsets: tuple
    n: int
    n_flat: int
    shards: int = 1

    @property
    def shard_len(self) -> int:
        """Flat entries owned by one state shard (``n_flat / shards``)."""
        return self.n_flat // self.shards

    # ---- per-shard conversions
    def shard_split(self, buf: jnp.ndarray) -> jnp.ndarray:
        """(..., n_flat) buffer -> (..., shards, shard_len) per-shard view.

        A pure reshape (shard s is the contiguous slice it owns), so
        ``shard_merge(shard_split(buf)) == buf`` bit-exactly — the
        invariant the checkpoint resharding path relies on.
        """
        return buf.reshape(buf.shape[:-1] + (self.shards, self.shard_len))

    def shard_merge(self, parts: jnp.ndarray) -> jnp.ndarray:
        """(..., shards, shard_len) per-shard view -> (..., n_flat)."""
        return parts.reshape(parts.shape[:-2] + (self.n_flat,))

    # ---- conversions
    def pack(self, tree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree -> (n_flat,) buffer in ``dtype`` (zero-padded tail)."""
        leaves = jax.tree.leaves(tree)
        flat = jnp.concatenate(
            [jnp.ravel(l).astype(dtype) for l in leaves])
        if self.n_flat > self.n:
            flat = jnp.pad(flat, (0, self.n_flat - self.n))
        return flat

    def pack_worker(self, tree, dtype=jnp.float32) -> jnp.ndarray:
        """M-leading pytree -> (M, n_flat) plane in ``dtype``."""
        leaves = jax.tree.leaves(tree)
        m = leaves[0].shape[0]
        flat = jnp.concatenate(
            [l.reshape(m, -1).astype(dtype) for l in leaves], axis=1)
        if self.n_flat > self.n:
            flat = jnp.pad(flat, ((0, 0), (0, self.n_flat - self.n)))
        return flat

    def unpack(self, buf, dtypes=None):
        """(n_flat,) buffer -> pytree (leaves cast to the layout dtypes)."""
        dtypes = dtypes or self.dtypes
        outs = [buf[o:o + s].reshape(shp).astype(dt)
                for o, s, shp, dt in zip(self.offsets, self.sizes,
                                         self.shapes, dtypes)]
        return jax.tree.unflatten(self.treedef, outs)

    def unpack_worker(self, buf, dtypes=None):
        """(M, n_flat) plane -> M-leading pytree."""
        dtypes = dtypes or self.dtypes
        m = buf.shape[0]
        outs = [buf[:, o:o + s].reshape((m,) + shp).astype(dt)
                for o, s, shp, dt in zip(self.offsets, self.sizes,
                                         self.shapes, dtypes)]
        return jax.tree.unflatten(self.treedef, outs)

    # ---- dtype discipline
    @property
    def all_f32(self) -> bool:
        return all(dt == np.dtype(np.float32) for dt in self.dtypes)

    def cast_roundtrip(self, buf: jnp.ndarray) -> jnp.ndarray:
        """Round-trip a (n_flat,) fp32 buffer through the per-leaf storage
        dtypes, so ``buf == pack(unpack(buf))`` holds exactly even for
        reduced-precision leaves. No-op for all-fp32 layouts (static)."""
        if self.all_f32:
            return buf
        parts = [buf[o:o + s].astype(dt).astype(buf.dtype)
                 for o, s, dt in zip(self.offsets, self.sizes, self.dtypes)]
        if self.n_flat > self.n:
            parts.append(buf[self.n:])
        return jnp.concatenate(parts)


def layout_of(tree, align: int | None = None, shards: int = 1) -> FlatLayout:
    """Build the static :class:`FlatLayout` of ``tree`` (arrays or
    ShapeDtypeStructs both work — only shapes/dtypes are read).

    ``shards`` is the state-shard count the flat axis must divide into
    (``distributed.trainer.flat_state_shards`` resolves it from the mesh);
    ``n_flat`` is padded to a multiple of ``align · shards`` so every shard
    gets an equal, ``align``-aligned contiguous slice. ``shards=1``
    reproduces the unsharded layout exactly (same ``n_flat`` as before).
    """
    if align is None:
        align = PAD_ALIGN
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(np.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1
                  for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    n = off
    step = align * shards
    n_flat = n + ((-n) % step)
    return FlatLayout(treedef=treedef, shapes=shapes, dtypes=dtypes,
                      sizes=sizes, offsets=tuple(offsets), n=n,
                      n_flat=max(n_flat, step), shards=shards)


def spec_dim(axes: tuple) -> Any:
    """One PartitionSpec DIMENSION entry for a tuple of mesh axes:
    ``()`` -> None (replicated), one axis -> its name, several -> the
    tuple (sharded over their product). The single home of the rule, used
    by the flat-plane spec builders here and in distributed/sharding.py."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _segment_ids(layout: FlatLayout) -> np.ndarray:
    """(n_flat,) int32 leaf-segment id per buffer position; the padding
    tail (if any) is its own trailing segment ``len(sizes)``. Static —
    computed from the layout at trace time."""
    ids = np.full((layout.n_flat,), len(layout.sizes), np.int32)
    for i, (o, s) in enumerate(zip(layout.offsets, layout.sizes)):
        ids[o:o + s] = i
    return ids


def per_worker_quantize_dequantize_flat(layout: FlatLayout, buf, bits: int):
    """Flat-plane twin of ``quantize.per_worker_quantize_dequantize``:
    b-bit symmetric uniform round-trip with one max-abs scale per
    (worker, leaf-segment) — bit-identical to the pytree version, since the
    scales are exact maxima over the same entries.

    Vectorized over segments: ONE segment-max sweep computes every
    (worker, leaf) scale and one gather broadcasts them back, instead of a
    Python loop materializing a slice + concatenate per leaf (the loop cost
    scaled with the number of leaves — LM pytrees have hundreds). The
    padding tail passes through untouched (max is exact, so bit-equality
    with the pytree form is preserved)."""
    if bits <= 0 or bits >= 32:
        return buf
    levels = float(2 ** (bits - 1) - 1)
    n_seg = len(layout.sizes)
    seg = jnp.asarray(_segment_ids(layout))
    xf = buf.astype(jnp.float32)
    # (n_seg+1, M) per-segment max-abs; empty segments are never gathered
    # into a non-pad position, so their -inf identity is harmless.
    seg_max = jax.ops.segment_max(jnp.abs(xf).T, seg, num_segments=n_seg + 1,
                                  indices_are_sorted=True)
    scale = jnp.maximum(seg_max, 1e-12)[seg].T          # (M, n_flat)
    q = jnp.round(xf / scale * levels)
    deq = (q * scale / levels).astype(buf.dtype)
    if layout.n_flat > layout.n:
        deq = jnp.where((seg < n_seg)[None, :], deq, buf)
    return deq


def per_worker_topk_sparsify_flat(layout: FlatLayout, buf, frac: float):
    """Flat-plane twin of ``quantize.per_worker_topk_sparsify``: keep
    EXACTLY the top-⌈frac·size⌉ largest-|x| entries per (worker,
    leaf-segment) (ties break toward the lower index — see
    ``topk_threshold_mask``), zero the rest — bit-identical to the pytree
    form (same selection over the same entries in the same order). Top-k
    runs per segment: segments are ragged (one k per segment), and
    bit-equality with the pytree sparsifier is what the parity gates pin,
    so the per-segment loop is the deliberate trade-off here (unlike the
    quantizer above, whose max-scales vectorize exactly). The padding
    tail passes through untouched."""
    if frac >= 1.0:
        return buf
    parts = []
    for o, s in zip(layout.offsets, layout.sizes):
        seg = buf[:, o:o + s]
        mask = topk_threshold_mask(seg.astype(jnp.float32),
                                   topk_count(s, frac))
        parts.append(seg * mask)
    if layout.n_flat > layout.n:
        parts.append(buf[:, layout.n:])
    return jnp.concatenate(parts, axis=1)


def per_worker_topk_extract_flat(layout: FlatLayout, plane, frac: float):
    """Extract the top-k SPARSE WIRE from an (M, n_flat) sparsified plane:
    ((M, K) fp32 values, (M, K) int32 global flat positions) with
    K = Σ_seg ⌈frac·size_seg⌉ — a fixed-size payload, so it can ride a
    collective as-is. Applied to the compressor's output — whose support
    is exactly k entries per segment (``topk_threshold_mask`` keeps
    exactly k, ties index-broken) — the pair reconstructs the dense plane
    bit-exactly via :func:`sparse_rows_to_dense`; the parity test pins
    that equality."""
    vparts, iparts = [], []
    for o, s in zip(layout.offsets, layout.sizes):
        seg = plane[:, o:o + s].astype(jnp.float32)
        k = topk_count(s, frac)
        _, idx = jax.lax.top_k(jnp.abs(seg), k)
        vparts.append(jnp.take_along_axis(seg, idx, axis=1))
        iparts.append(idx.astype(jnp.int32) + o)
    return jnp.concatenate(vparts, axis=1), jnp.concatenate(iparts, axis=1)


def sparse_rows_to_dense(idx, vals, n_flat: int) -> jnp.ndarray:
    """Scatter per-worker (values, indices) wire pairs back onto a dense
    (M, n_flat) plane (the server side of the sparse collective). Indices
    are distinct per row (disjoint per-segment top-k), so add == set."""
    m = vals.shape[0]
    rows = jnp.arange(m)[:, None]
    return jnp.zeros((m, n_flat), vals.dtype).at[rows, idx].add(vals)


# ----------------------------------------------------- local-steps cadence

def batch_has_local_axis(rule, local_steps) -> bool:
    """STATIC: does a delta-payload round's batch lead with the H axis?

    The payload/cadence contract: a delta-payload rule's batch is
    (H, M, b, ...) whenever the rule runs more than one local step
    (``rule.local_steps > 1``) or an explicit per-round schedule is passed
    (``local_steps is not None`` — the sim's adaptive path, which pads the
    batch to the schedule's cap). With the default H = 1 and no schedule
    the batch keeps the plain (M, b, ...) form every gradient-payload path
    uses — so a delta rule at H = 1 drops into any existing engine/sweep
    unchanged.
    """
    return rule.local_steps > 1 or local_steps is not None


def local_steps_vector(rule, m: int, batch_h, local_steps) -> jnp.ndarray:
    """(M,) int32 per-worker local-step counts of one delta-payload round.

    ``batch_h`` leads with the (static) local-steps axis H — its length is
    the padding bound; ``local_steps`` (None | scalar | (M,)) selects how
    many of those H steps each worker actually runs this round (None = all
    H, the fixed-cadence case; the sim's adaptive schedule passes a
    per-worker vector, clipped here into [1, H] so a stale schedule can
    never index past the batch)."""
    h_max = jax.tree.leaves(batch_h)[0].shape[0]
    if local_steps is None:
        return jnp.full((m,), h_max, jnp.int32)
    h = jnp.asarray(local_steps, jnp.int32)
    return jnp.clip(jnp.broadcast_to(h, (m,)), 1, h_max)


# -------------------------------------------------------------- comm state

class FlatCommState(NamedTuple):
    """Algorithm-1 communication state on the flat plane.

    Mirrors ``comm.CommState`` field-for-field; gradient-shaped trees are
    single buffers ((n_flat,) for ∇, (M, n_flat) per-worker planes), so the
    round below touches each exactly once per iteration.
    """
    nabla: jnp.ndarray        # (n_flat,) storage dtype
    worker_grads: jnp.ndarray  # (M, n_flat) storage dtype
    staleness: jnp.ndarray    # (M,) int32
    diff_hist: jnp.ndarray    # (d_max,) fp32 RHS ring buffer
    extras: dict              # strategy-owned flat slices


class FlatCommContext(NamedTuple):
    """What a strategy's flat hooks may consult. ``fresh`` is the packed
    (M, n_flat) fp32 fresh-gradient plane; ``second`` the packed gradients
    at the strategy's second evaluation points (None if it has none);
    ``shard`` the static flat-plane sharding descriptor
    (distributed.sharding.FlatSharding) or None — strategies pass it
    through to the kernels so the batched LHS norms run shard-local with
    one psum instead of resharding whole planes."""
    layout: FlatLayout
    params: Any               # θ^k pytree (model form)
    params_flat: jnp.ndarray  # θ^k packed, fp32
    batch: Any
    fresh: jnp.ndarray
    second: jnp.ndarray | None
    comm: FlatCommState
    step: jnp.ndarray
    m: int
    interpret: Any            # kernel-mode override for kernels/ops.py
    shard: Any = None         # FlatSharding | None (static)
    participation: Any = None  # (M,) bool round-participation mask | None
    # Cohort-virtualized plane (flat_cohort_round): the (C,) int32 sorted
    # global worker ids whose rows are resident this round, or None on the
    # dense plane. When set, ``m`` is C, every per-worker plane in
    # ctx/extras pooled by the strategy has C rows, and full-length (M,)
    # server-resident extras (avp periods, cada2 slots) must be indexed by
    # it — see each strategy's flat hooks.
    cohort: Any = None


class FlatCommRoundResult(NamedTuple):
    losses: jnp.ndarray
    comm: FlatCommState       # diff_hist NOT yet updated (record_progress)
    upload: jnp.ndarray
    metrics: dict


def init_flat_comm_state(strategy, layout: FlatLayout, params, m: int,
                         grad_dtype=jnp.float32,
                         params_flat=None) -> FlatCommState:
    """Fresh flat CommState: τ_m starts at D so iteration 0 uploads."""
    r = strategy.rule
    if params_flat is None:
        params_flat = layout.pack(params)
    return FlatCommState(
        nabla=jnp.zeros((layout.n_flat,), grad_dtype),
        worker_grads=jnp.zeros((m, layout.n_flat), grad_dtype),
        staleness=jnp.full((m,), r.max_delay, jnp.int32),
        diff_hist=jnp.zeros((r.d_max,), jnp.float32),
        extras=strategy.init_flat_extras(layout, params, params_flat, m,
                                         grad_dtype),
    )


def flat_comm_state_specs(strategy, param_spec, worker_param_spec,
                          waxis: str, P, state_axes: tuple = (),
                          col_axes: tuple = ()) -> FlatCommState:
    """PartitionSpec tree matching :func:`init_flat_comm_state` — the
    gradient planes need exactly two spec shapes (server (n_flat,) buffers
    sharded over ``state_axes``, worker-leading (M, n_flat) planes sharded
    worker-axis × ``col_axes``); parameter-shaped extras reuse the param
    specs. ``col_axes`` is ``state_axes`` minus the worker axis (an axis
    may not repeat within one spec)."""
    return FlatCommState(
        nabla=P(spec_dim(state_axes)),
        worker_grads=P(waxis, spec_dim(col_axes)),
        staleness=P(None),
        diff_hist=P(None),
        extras=strategy.flat_extras_specs(param_spec, worker_param_spec,
                                          waxis, P, col_axes=col_axes),
    )


# ------------------------------------------------------------ two-point eval

def stacked_two_point_eval(layout: FlatLayout, params, pts, batch, m: int,
                           vgrad_per):
    """Fresh + second gradients from ONE vmapped call, WITHOUT copying the
    batch: the 2-way eval axis is a broadcast vmap level (in_axes=None for
    the batch), not a doubled (2M,)-leading concatenation — the old form
    materialized every batch leaf twice (``jnp.concatenate([x, x])``) just
    to reuse the flat M-axis vmap. Returns (losses, fresh, second) with the
    planes packed. Values are identical per row (vmap rows are
    independent); the dispatch/pass count is what halves."""
    stacked = jax.tree.map(
        lambda p, w: jnp.stack(
            [jnp.broadcast_to(p[None], (m,) + p.shape), w.astype(p.dtype)]),
        params, pts)
    losses2, grads2 = jax.vmap(vgrad_per, in_axes=(0, None))(stacked, batch)
    fresh = layout.pack_worker(jax.tree.map(lambda g: g[0], grads2))
    second = layout.pack_worker(jax.tree.map(lambda g: g[1], grads2))
    return losses2[0], fresh, second


def grouped_second_plane(layout: FlatLayout, ring, slot, batch, m: int,
                         vgrad) -> jnp.ndarray:
    """The grouped second evaluation: one broadcast-point ``vgrad`` per
    OCCUPIED ring row (a fixed-R masked ``lax.scan``), scattered into the
    (M, n_flat) second plane by each worker's slot. Every worker still
    sees its OWN sample ξ_m^k — only the evaluation point is shared — so
    the plane feeds ``kops.batched_diff_sq_norm`` without any re-gather.

    The weight traffic drops M× → R× (each occupied row fetches θ once for
    all its workers); the arithmetic INFLATES to occupancy × M row-evals,
    so this wins exactly when the eval is weight-bandwidth-bound (large n,
    small per-worker batch, R ≪ M — the federated LM regime) and loses
    when it is compute-bound (CPU logreg). Hence opt-in (``group_evals``).
    """
    rr = jax.tree.leaves(ring)[0].shape[0]

    def body(acc, r):
        def eval_row(a):
            row = jax.tree.map(lambda x: x[r], ring)
            _, g = vgrad(row, batch)
            return jnp.where((slot == r)[:, None], layout.pack_worker(g), a)

        return jax.lax.cond(jnp.any(slot == r), eval_row, lambda a: a,
                            acc), None

    acc0 = jnp.zeros((m, layout.n_flat), jnp.float32)
    plane, _ = jax.lax.scan(body, acc0, jnp.arange(rr))
    return plane


def eval_two_point(strategy, layout: FlatLayout, extras: dict, params,
                   batch, m: int, *, vgrad, vgrad_per=None,
                   fuse_evals: bool = False, group_evals: bool = False,
                   cohort=None):
    """The ONE home of the two-point eval dispatch, shared by
    :func:`flat_comm_round`, :func:`flat_cohort_round` and the async gate
    (sim/runtime.py). Returns ``(losses, fresh, second)`` packed planes
    (``second`` is None for single-eval rules).

    ``cohort`` ((C,) int32 global worker ids, or None): cohort-virtualized
    round. ``m`` is then C, ``batch`` holds only the cohort rows, and the
    indexed family's full-length (M,) slot vector is sliced to the cohort
    before the gather — rings and shared points stay server-resident at
    full M semantics while only C rows are ever evaluated.

    Dispatch order: the strategy's INDEXED family first
    (``second_eval_indexed`` — the stale-iterate ring). ``slot=None``
    degenerates to the shared broadcast point (CADA1's snapshot, exactly
    the old collapsed form). A real slot index picks one of three
    bit-compatible evaluation shapes:

      * default        — gather ``ring[slot]`` (R → M rows) and
        ``vgrad_per``: BIT-IDENTICAL to the old dense plane (same row
        values, same call);
      * ``fuse_evals`` — gather, then stack fresh+second into one vmapped
        call (:func:`stacked_two_point_eval`) — identical values, half the
        dispatches;
      * ``group_evals`` — NO gather: ≤R broadcast-point evals
        (:func:`grouped_second_plane`) — the M× → R× weight-traffic form
        (same math per worker; the broadcast-θ eval may differ from the
        per-row vmap by float ulps, so it is opt-in).

    The legacy dense ``second_eval_per_worker`` hook is honored last, for
    external strategies without a ring.
    """
    indexed = strategy.second_eval_indexed(extras)
    if indexed is not None:
        ring, slot = indexed
        if cohort is not None and slot is not None:
            slot = slot[cohort]
        if slot is None:  # degenerate ring: one shared point
            shared_pt = jax.tree.map(lambda x: jnp.squeeze(x, 0), ring)
            losses, fresh_tree = vgrad(params, batch)
            _, second_tree = vgrad(shared_pt, batch)
            return (losses, layout.pack_worker(fresh_tree),
                    layout.pack_worker(second_tree))
        if group_evals:
            losses, fresh_tree = vgrad(params, batch)
            return (losses, layout.pack_worker(fresh_tree),
                    grouped_second_plane(layout, ring, slot, batch, m,
                                         vgrad))
        pts = jax.tree.map(lambda x: x[slot], ring)
        if fuse_evals:
            return stacked_two_point_eval(layout, params, pts, batch, m,
                                          vgrad_per)
        losses, fresh_tree = vgrad(params, batch)
        _, second_tree = vgrad_per(pts, batch)
        return (losses, layout.pack_worker(fresh_tree),
                layout.pack_worker(second_tree))

    shared_pt = strategy.second_eval_shared(extras)
    perw_pts = strategy.second_eval_per_worker(extras)
    if perw_pts is not None and fuse_evals:
        return stacked_two_point_eval(layout, params, perw_pts, batch, m,
                                      vgrad_per)
    losses, fresh_tree = vgrad(params, batch)
    fresh = layout.pack_worker(fresh_tree)
    if shared_pt is not None:
        _, second_tree = vgrad(shared_pt, batch)
        second = layout.pack_worker(second_tree)
    elif perw_pts is not None:
        _, second_tree = vgrad_per(perw_pts, batch)
        second = layout.pack_worker(second_tree)
    else:
        second = None
    return losses, fresh, second


# ------------------------------------------------------------- shared round

def flat_comm_round(strategy, layout: FlatLayout, comm: FlatCommState,
                    params, params_flat, batch, k, *, vgrad,
                    vgrad_per: Callable | None = None,
                    fuse_evals: bool = True,
                    group_evals: bool = False,
                    interpret=None, shard=None,
                    participation=None,
                    local_steps=None) -> FlatCommRoundResult:
    """One communication round of Algorithm 1 (lines 4-15) on flat buffers.

    Semantically identical to ``comm.comm_round`` (the fused-vs-reference
    parity test pins this); the per-iteration cost is what changes:

      * rules with a second gradient evaluation (CADA1's snapshot, CADA2's
        stale-iterate ring) dispatch through :func:`eval_two_point`:
        ``fuse_evals`` stacks both evaluations onto one vmapped call via a
        broadcast 2-way eval axis (identical values — half the dispatches;
        set False when ``vgrad``/``vgrad_per`` are pod-manual shard_maps
        whose in-specs pin the M-leading axis), ``group_evals`` runs ≤R
        broadcast-point evaluations over the ring instead of gathering M
        rows (the M× → R× weight-traffic form — opt-in, see
        :func:`grouped_second_plane`);
      * the delta / mask-merge / eq. (3) aggregation are whole-plane ops;
      * the LHS norms ride the batched one-pass kernel (kernels/ops.py).

    ``shard`` (static, ``distributed.sharding.FlatSharding`` or None)
    threads the flat-plane sharding through the round: the LHS norms run
    shard-local with one psum, and the wire / eq. (3) aggregation are
    pinned to the worker-plane layout so GSPMD never reshards a full plane
    mid-round. A strategy may also ship a true SPARSE wire
    (``flat_sparse_wire`` returning (values, indices) pairs sized k): the
    pair is what crosses the simulated collective and is scattered back
    server-side — bit-equal to the dense masked plane.

    ``participation`` ((M,) bool or None) models PARTIAL PARTICIPATION
    (repro.sim's heterogeneous-cluster runtime): a non-participating worker
    never uploads this round — not even when its staleness is capped (it is
    offline, so the cap fires on its next participating round) — and its
    staleness keeps growing. ``None`` (the default) leaves the round's
    graph completely unchanged, which is what keeps the sim's degenerate
    zero-latency config bit-exact against the plain engine.

    ``local_steps`` belongs to the PAYLOAD/CADENCE axis and is only legal
    for delta-payload rules (``strategy.delta_payload`` — local_momentum /
    fedadam): those ship an accumulated local-optimizer model delta
    instead of one fresh gradient, the batch leads with the local-steps
    axis H (see :func:`batch_has_local_axis`), and ``local_steps``
    (None | scalar | (M,)) is how many of the H padded steps each worker
    runs this round. For the 8 gradient-payload rules the kwarg must stay
    None and the round's graph is byte-identical to the pre-axis form.
    """
    r = strategy.rule
    m = comm.staleness.shape[0]
    if local_steps is not None and not strategy.delta_payload:
        raise ValueError(
            f"rule kind {r.kind!r} ships per-iteration gradients; "
            "local_steps is only meaningful for delta-payload rules "
            "(local_momentum, fedadam)")

    # Line 4 (rule-owned): e.g. CADA1 snapshot refresh every D iterations.
    extras = strategy.flat_pre_step(comm.extras, params, params_flat, k)

    if strategy.delta_payload:
        # Payload/cadence branch: the worker runs h_w local optimizer
        # steps and ships the accumulated model delta θ^k − θ_m^(h) (fp32)
        # in place of the fresh gradient. Substituting that payload for
        # ``fresh`` leaves the rest of the round untouched: with the
        # always-upload cadence below, worker_grads telescopes to the last
        # shipped payload, so ∇̄ ≡ mean_m(payload) exactly and the rule's
        # server optimizer (sgd(1.0) / server Adam) turns eq. (3) into
        # periodic averaging / FedAdam.
        batch_h = (batch if batch_has_local_axis(r, local_steps)
                   else jax.tree.map(lambda x: x[None], batch))
        h_steps = local_steps_vector(r, m, batch_h, local_steps)
        losses, fresh, cache = strategy.flat_local_payload(
            layout, extras, params, params_flat, batch_h, m, vgrad_per,
            h_steps)
        second = None
        ctx = FlatCommContext(layout=layout, params=params,
                              params_flat=params_flat, batch=batch,
                              fresh=fresh, second=second,
                              comm=comm._replace(extras=extras),
                              step=k, m=m, interpret=interpret, shard=shard,
                              participation=participation)
        # always-upload cadence: the "skip" axis is folded into h_w
        lhs = jnp.full((m,), jnp.inf, jnp.float32)
    else:
        h_steps = None
        # Lines 6/8: fresh gradients at θ^k, plus the rule's second
        # evaluation (ring-indexed / shared / legacy dense — see
        # eval_two_point).
        losses, fresh, second = eval_two_point(
            strategy, layout, extras, params, batch, m, vgrad=vgrad,
            vgrad_per=vgrad_per, fuse_evals=fuse_evals,
            group_evals=group_evals)

        ctx = FlatCommContext(layout=layout, params=params,
                              params_flat=params_flat, batch=batch,
                              fresh=fresh, second=second,
                              comm=comm._replace(extras=extras),
                              step=k, m=m, interpret=interpret, shard=shard,
                              participation=participation)

        # Lines 7/9: rule LHS vs the shared recent-progress RHS.
        lhs, cache = strategy.flat_lhs(ctx, extras)
    rhs = r.rhs(comm.diff_hist)
    # Line 10: upload if the condition is VIOLATED or staleness capped.
    upload = (lhs > rhs) | (comm.staleness >= r.max_delay)
    if participation is not None:
        upload = upload & participation

    # Eq. (3): innovation delta, wire format, masked aggregation — each a
    # single whole-plane op (one (M, n_flat) sweep instead of ~6 tree_maps).
    wg32 = comm.worker_grads.astype(jnp.float32)
    delta = strategy.flat_wire_delta(ctx, extras, cache, fresh - wg32)
    sparse = strategy.flat_sparse_wire(ctx, extras, cache, delta)
    if sparse is not None:
        # True sparse wire: the (M, K) value/index pair is the collective
        # payload; the dense plane is reconstructed server-side. Values are
        # masked and cast exactly like the dense wire, so the two paths
        # are bit-equal wherever the extraction captured the full support.
        vals, idx = sparse
        vals = jnp.where(upload[:, None], vals, 0.0).astype(
            comm.worker_grads.dtype)
        wire = sparse_rows_to_dense(idx, vals, layout.n_flat)
    else:
        wire = jnp.where(upload[:, None], delta, 0.0).astype(
            comm.worker_grads.dtype)
    if shard is not None:
        # pin the wire to the worker-plane layout: the cross-worker mean
        # below IS the gated collective, and an unpinned intermediate lets
        # GSPMD gather the full plane before reducing it.
        wire = shard.constrain_worker(wire)
    # Order-fixed row accumulation (kops.eq3_row_mean): masked zero rows
    # are exact no-ops, so this dense masked mean is BIT-IDENTICAL to the
    # cohort plane's C-row sum below (flat_cohort_round) — the parity the
    # cohort tests pin.
    nabla = (comm.nabla.astype(jnp.float32)
             + kops.eq3_row_mean(wire, m, shard=shard)
             ).astype(comm.nabla.dtype)
    if shard is not None:
        nabla = shard.constrain_server(nabla)
    worker_grads = (wg32 + wire.astype(jnp.float32)
                    ).astype(comm.worker_grads.dtype)

    staleness = jnp.where(upload, 1, comm.staleness + 1)
    extras = strategy.flat_post_upload(extras, cache, upload, ctx)

    uploads = jnp.sum(upload.astype(jnp.int32))
    # offline workers evaluate nothing — charge grad evals to participants
    n_active = (jnp.asarray(m, jnp.int32) if participation is None
                else jnp.sum(participation.astype(jnp.int32)))
    if strategy.delta_payload:
        # one eval per LOCAL step: Σ_active h_w
        grad_evals = jnp.sum(h_steps if participation is None
                             else jnp.where(participation, h_steps, 0))
    else:
        grad_evals = n_active * strategy.grad_evals_per_iter
    metrics = {
        "uploads": uploads,
        # fraction of ACTIVE workers that skipped (an offline worker does
        # not "skip" — it was never asked)
        "skip_rate": 1.0 - uploads.astype(jnp.float32) / n_active,
        "upload_mask": upload,
        "staleness": staleness,
        "rhs": rhs,
        # full per-worker gate LHS (inf for threshold-free rules) — the
        # obs.metrics.CommLedger derives LHS−RHS gate margins from this
        "lhs": lhs,
        "mean_lhs": jnp.mean(jnp.where(jnp.isfinite(lhs), lhs, 0.0)),
        "max_staleness": jnp.max(staleness),
        "grad_evals": grad_evals,
        "bytes_up": (uploads.astype(jnp.float32)
                     * strategy.bytes_per_upload(layout.n)),
    }
    new_comm = FlatCommState(nabla=nabla, worker_grads=worker_grads,
                             staleness=staleness, diff_hist=comm.diff_hist,
                             extras=extras)
    return FlatCommRoundResult(losses=losses, comm=new_comm, upload=upload,
                               metrics=metrics)


# ------------------------------------------------------- cohort-virtualized
#
# At federated scale (M ≥ 10⁴) the dense (M, n_flat) worker planes stop
# fitting on device — and eq. (3) only ever needs the AGGREGATE of the
# uploaded innovations, while each worker's stale-gradient row is touched
# exactly on the rounds that worker is sampled. The cohort plane exploits
# that: per round only the C sampled workers' rows exist on device,
# gathered from a host-resident numpy pool and scattered back after the
# round, while the server keeps only the (n_flat,) aggregate, the (M,)
# staleness/slot/period vectors, the RHS ring and shared extras (CADA1's
# snapshot, CADA2's stale-iterate ring). Device worker-plane bytes and
# per-round eval compute are O(C·n); the O(M·n) planes live on host.
#
# Semantics: a cohort round is EXACTLY the dense plane run with
# ``participation`` = the cohort's indicator mask — offline workers age
# (+1 staleness), upload nothing, keep their rows and periods, and keep
# their ring slots referenced. The order-fixed eq. (3) accumulation
# (kops.eq3_row_mean) makes the parity BIT-exact in fp32, masked dense
# mean vs C-row cohort sum; tests/test_cohort_plane.py pins it for all
# registered rules.


class WorkerPool:
    """Host-resident per-worker state pool backing the cohort plane.

    Numpy-backed (M, n_flat) planes — ``worker_grads`` plus whatever
    per-worker planes the strategy pools (``strategy.pooled_extras()``:
    CADA1's ``worker_delta``, laq/topk's error-feedback ``residual``).
    ``gather`` streams the C sampled rows onto device (ascending worker
    order — the order the parity depends on); ``scatter`` writes the
    round's updated rows back. Planes keep their storage dtype (bf16
    planes round-trip bit-exactly via ml_dtypes' numpy bfloat16).

    Transfers are FUSED: the P planes' cohort rows are staged into one
    preallocated (P, C, n_flat) host buffer, so a round costs a single
    H2D dispatch (``gather_fused``) and a single D2H copy
    (``scatter_fused``) instead of one per plane. The staging buffer is
    double-slotted so the pipelined driver can stage round i+1's rows
    while round i's H2D transfer may still be draining. The dict-valued
    ``gather``/``scatter`` route through the same staging path.

    ``storage="memmap"`` backs each plane with an ``np.memmap`` file
    under ``path`` so M beyond RAM works: only the touched pages are
    resident, gathers/scatters fault in exactly the cohort's rows, and
    checkpoint ``state_dict``/``load_state_dict`` round-trip in place
    through the mapping. ``nbytes`` stays the logical O(M·n) plane total
    (resident for RAM pools, address-space mapped for memmap pools);
    ``mapped_nbytes``/``resident_nbytes`` report the split.
    """

    STORAGES = ("ram", "memmap")

    def __init__(self, planes: dict, storage: str = "ram",
                 path: str | None = None):
        if storage not in self.STORAGES:
            raise ValueError(f"storage must be one of {self.STORAGES}, "
                             f"got {storage!r}")
        if storage == "memmap" and path is None:
            raise ValueError('storage="memmap" needs path= (a directory '
                             "for the plane files)")
        self.storage = storage
        self.path = path
        if storage == "memmap":
            os.makedirs(path, exist_ok=True)
            owned = {}
            for name, v in planes.items():
                src = np.asarray(v)
                mm = np.memmap(os.path.join(path, f"{name}.plane"),
                               dtype=src.dtype, mode="w+", shape=src.shape)
                mm[...] = src
                owned[name] = mm
            self.planes = owned
        else:
            # own the storage: np views of jax arrays arrive read-only,
            # and scatter writes in place
            self.planes = {name: (v if isinstance(v, np.ndarray)
                                  and v.flags.writeable else np.array(v))
                           for name, v in planes.items()}
        shapes = {v.shape for v in self.planes.values()}
        if len(shapes) != 1:
            raise ValueError(f"pool planes disagree on shape: {shapes}")
        self._order = tuple(self.planes)
        dtypes = {v.dtype for v in self.planes.values()}
        self._dtype = dtypes.pop() if len(dtypes) == 1 else None
        self._stage = None        # (2, P, C, n_flat) host staging buffer

    @property
    def m(self) -> int:
        return next(iter(self.planes.values())).shape[0]

    @property
    def n_flat(self) -> int:
        return next(iter(self.planes.values())).shape[1]

    @property
    def plane_order(self) -> tuple:
        """Fixed plane stacking order of the fused (P, C, n_flat) block."""
        return self._order

    @property
    def plane_dtype(self):
        """The planes' common storage dtype (None if they disagree —
        which disables the fused staging path)."""
        return self._dtype

    @property
    def nbytes(self) -> int:
        """Logical plane bytes (the O(M·n) side of the split) — host RAM
        for ``storage="ram"``, mapped address space for memmap pools."""
        return int(sum(v.nbytes for v in self.planes.values()))

    @property
    def mapped_nbytes(self) -> int:
        """Bytes living in memmap files rather than RAM."""
        if self.storage != "memmap":
            return 0
        return int(sum(v.nbytes for v in self.planes.values()))

    @property
    def resident_nbytes(self) -> int:
        """Bytes guaranteed RAM-resident: RAM planes + staging buffers.
        (Memmap planes additionally cache touched pages at the OS's
        discretion — that part is reclaimable and not counted.)"""
        planes = 0 if self.storage == "memmap" else self.nbytes
        stage = self._stage.nbytes if self._stage is not None else 0
        return int(planes + stage)

    def device_row_bytes(self, c: int) -> int:
        """Device bytes a C-row gather materializes (the O(C·n) side)."""
        return int(sum(v.dtype.itemsize * c * v.shape[1]
                       for v in self.planes.values()))

    # ---- fused staging path (one host copy per round per direction)
    def _stage_view(self, c: int, slot: int) -> np.ndarray:
        if self._stage is None or self._stage.shape[2] != c:
            self._stage = np.empty(
                (2, len(self._order), c, self.n_flat), self._dtype)
        return self._stage[slot & 1]

    def gather_fused(self, cohort, slot: int = 0) -> jnp.ndarray:
        """Cohort rows -> device as ONE (P, C, n_flat) block.

        All planes' rows are staged into the reused host buffer (slot
        ``slot & 1`` of the double buffer), then shipped in a single H2D
        dispatch. Plane p is ``plane_order[p]``; rows follow ``cohort``
        order (sorted ascending — the order the parity depends on).
        """
        if self._dtype is None:
            raise ValueError("fused gather needs a uniform plane dtype; "
                             f"pool has {[str(v.dtype) for v in self.planes.values()]}")
        idx = np.asarray(cohort, dtype=np.intp)
        buf = self._stage_view(idx.shape[0], slot)
        for p, name in enumerate(self._order):
            np.take(self.planes[name], idx, axis=0, out=buf[p])
        # jnp.array COPIES out of the staging buffer (jnp.asarray may
        # alias host memory on CPU — the buffer is reused next round)
        return jnp.array(buf)

    def scatter_fused(self, cohort, fused) -> None:
        """Write a (P, C, n_flat) fused block back into the planes.

        ``np.asarray(fused)`` is the round's single D2H copy (it blocks
        until the producing step is done — the pipelined driver calls
        this one round late so the wait rides under the next round's
        compute)."""
        idx = np.asarray(cohort, dtype=np.intp)
        arr = np.asarray(fused)
        for p, name in enumerate(self._order):
            plane = self.planes[name]
            rows = arr[p]
            if rows.dtype != plane.dtype:
                rows = rows.astype(plane.dtype)
            plane[idx] = rows

    def gather(self, cohort) -> dict:
        """Cohort rows -> device: {name: (C, n_flat) jnp array}.

        Routed through the fused staging buffer — one H2D for all
        planes; the per-name values are device views into the block."""
        if self._dtype is None:        # mixed dtypes: per-plane fallback
            idx = np.asarray(cohort)
            return {name: jnp.asarray(plane[idx])
                    for name, plane in self.planes.items()}
        fused = self.gather_fused(cohort)
        return {name: fused[p] for p, name in enumerate(self._order)}

    def scatter(self, cohort, rows: dict) -> None:
        """Write the round's updated (C, n_flat) rows back into the pool
        (one fused D2H copy when the rows are device-resident)."""
        if self._dtype is None:
            idx = np.asarray(cohort)
            for name, vals in rows.items():
                plane = self.planes[name]
                plane[idx] = np.asarray(vals).astype(plane.dtype,
                                                     copy=False)
            return
        vals = [rows[name] for name in self._order]
        if all(isinstance(v, jax.Array) for v in vals):
            fused = jnp.stack([v.astype(self._dtype) for v in vals])
        else:
            fused = np.stack([np.asarray(v).astype(self._dtype,
                                                   copy=False)
                              for v in vals])
        self.scatter_fused(cohort, fused)

    def flush(self) -> None:
        """Sync memmap-backed planes to their files (no-op for RAM)."""
        if self.storage == "memmap":
            for v in self.planes.values():
                v.flush()

    def resum_nabla(self) -> np.ndarray:
        """Drift guard: recompute ∇̄ = mean_m(worker_grads) from the pool.

        The incremental aggregate satisfies ∇̄ ≡ mean(worker_grads)
        exactly in real arithmetic; in fp32 each round adds rounding noise.
        This host-side re-sum (fp64 accumulate, fp32 result) restores the
        invariant — config-off by default (``resum_every`` on the engine),
        cheap (one host pass over the pool, no device traffic).
        """
        wg = self.planes["worker_grads"].astype(np.float64)
        return (wg.sum(axis=0) / wg.shape[0]).astype(np.float32)

    # ---- checkpoint (the planes ride checkpoint.io as ordinary leaves;
    # (M, n_flat) planes reshard through ``_reshard_flat`` like any other
    # flat worker plane)
    def state_dict(self) -> dict:
        return dict(self.planes)

    def load_state_dict(self, d: dict) -> None:
        for name in self.planes:
            arr = np.asarray(d[name])
            if arr.shape != self.planes[name].shape:
                raise ValueError(
                    f"pool plane {name!r}: shape {arr.shape} != "
                    f"{self.planes[name].shape}")
            # in place: memmap planes stay mapped, RAM planes stay owned
            self.planes[name][...] = arr.astype(self.planes[name].dtype,
                                                copy=False)


class CohortServerState(NamedTuple):
    """Device-resident server state under the cohort plane: everything
    that is NOT an O(M·n) per-worker plane. ``extras`` holds the shared /
    indexed strategy extras (snapshot, ring, (M,) slot/period vectors);
    the pooled planes live in the :class:`WorkerPool`.
    ``record_progress`` works on this state unchanged."""
    nabla: jnp.ndarray        # (n_flat,) storage dtype
    staleness: jnp.ndarray    # (M,) int32
    diff_hist: jnp.ndarray    # (d_max,) fp32 RHS ring buffer
    extras: dict              # non-pooled strategy extras


class FlatCohortRoundResult(NamedTuple):
    losses: jnp.ndarray       # (C,)
    server: CohortServerState  # diff_hist NOT yet updated (record_progress)
    rows: dict                # updated pooled rows -> WorkerPool.scatter
    upload: jnp.ndarray       # (C,) bool
    metrics: dict


def init_cohort_state(strategy, layout: FlatLayout, params, m: int,
                      grad_dtype=jnp.float32, params_flat=None,
                      pool_storage: str = "ram",
                      pool_path: str | None = None):
    """Fresh cohort-plane state: (CohortServerState, WorkerPool).

    Field-for-field the split of :func:`init_flat_comm_state`'s state:
    pooled per-worker planes land in the numpy pool (``pool_storage`` /
    ``pool_path`` pick RAM vs memmap backing), everything else on
    device. τ_m starts at D so every worker force-uploads on its first
    sampled round. Plane order is ``worker_grads`` first, then the
    strategy's ``pooled_extras()`` order — the fused staging block's
    stacking order.
    """
    r = strategy.rule
    if params_flat is None:
        params_flat = layout.pack(params)
    full_extras = strategy.init_flat_extras(layout, params, params_flat, m,
                                            grad_dtype)
    pooled = strategy.pooled_extras()
    planes = {"worker_grads": np.zeros((m, layout.n_flat),
                                       np.dtype(grad_dtype))}
    for name in pooled:
        if name in full_extras:
            planes[name] = np.asarray(full_extras[name])
    server_extras = {name: val for name, val in full_extras.items()
                     if name not in planes}
    server = CohortServerState(
        nabla=jnp.zeros((layout.n_flat,), grad_dtype),
        staleness=jnp.full((m,), r.max_delay, jnp.int32),
        diff_hist=jnp.zeros((r.d_max,), jnp.float32),
        extras=server_extras)
    return server, WorkerPool(planes, storage=pool_storage, path=pool_path)


def flat_cohort_round(strategy, layout: FlatLayout,
                      server: CohortServerState, rows: dict, params,
                      params_flat, batch, k, cohort, *, m_total: int,
                      vgrad, vgrad_per: Callable | None = None,
                      fuse_evals: bool = True,
                      interpret=None) -> FlatCohortRoundResult:
    """One Algorithm-1 round on the cohort-virtualized plane.

    ``rows`` is the WorkerPool gather for ``cohort`` ((C,) int32 SORTED
    ascending global worker ids); ``batch`` holds only the cohort rows
    ((C, b, ...) leaves). Bit-exact against :func:`flat_comm_round` run
    with ``participation`` = the cohort indicator on the dense plane:

      * per-row quantities (grads, LHS norms, wires) never mix rows, so
        the C evaluated rows carry the dense run's exact bits;
      * the eq. (3) aggregate is the order-fixed C-row sum / m_total —
        bit-identical to the dense masked mean (see ``kops.eq3_row_mean``),
        with NO full-plane re-sum anywhere;
      * offline workers age exactly like dense non-participants: staleness
        +1, rows/periods untouched, ring slots still refcounted (the
        cohort-aware strategy hooks handle the (M,)-resident extras).
    """
    r = strategy.rule
    c = rows["worker_grads"].shape[0]
    pooled = strategy.pooled_extras()
    merged = {**server.extras, **{name: rows[name] for name in pooled}}
    stale_c = server.staleness[cohort]
    comm_row = FlatCommState(
        nabla=server.nabla, worker_grads=rows["worker_grads"],
        staleness=stale_c, diff_hist=server.diff_hist, extras=merged)

    extras = strategy.flat_pre_step(merged, params, params_flat, k)
    if strategy.delta_payload:
        # Payload/cadence branch on the cohort plane: the C sampled
        # workers run their local steps (fixed H — the cohort plane does
        # not carry the sim's adaptive schedule) and ship model deltas;
        # see flat_comm_round. ``batch`` is (H, C, b, ...) when H > 1.
        batch_h = (batch if batch_has_local_axis(r, None)
                   else jax.tree.map(lambda x: x[None], batch))
        h_steps = local_steps_vector(r, c, batch_h, None)
        losses, fresh, cache = strategy.flat_local_payload(
            layout, extras, params, params_flat, batch_h, c, vgrad_per,
            h_steps)
        second = None
        ctx = FlatCommContext(layout=layout, params=params,
                              params_flat=params_flat, batch=batch,
                              fresh=fresh, second=second,
                              comm=comm_row._replace(extras=extras),
                              step=k, m=c, interpret=interpret, shard=None,
                              participation=None, cohort=cohort)
        lhs = jnp.full((c,), jnp.inf, jnp.float32)
    else:
        h_steps = None
        losses, fresh, second = eval_two_point(
            strategy, layout, extras, params, batch, c, vgrad=vgrad,
            vgrad_per=vgrad_per, fuse_evals=fuse_evals, cohort=cohort)

        ctx = FlatCommContext(layout=layout, params=params,
                              params_flat=params_flat, batch=batch,
                              fresh=fresh, second=second,
                              comm=comm_row._replace(extras=extras),
                              step=k, m=c, interpret=interpret, shard=None,
                              participation=None, cohort=cohort)

        lhs, cache = strategy.flat_lhs(ctx, extras)
    rhs = r.rhs(server.diff_hist)
    upload = (lhs > rhs) | (stale_c >= r.max_delay)

    wg32 = rows["worker_grads"].astype(jnp.float32)
    delta = strategy.flat_wire_delta(ctx, extras, cache, fresh - wg32)
    sparse = strategy.flat_sparse_wire(ctx, extras, cache, delta)
    if sparse is not None:
        vals, idx = sparse
        vals = jnp.where(upload[:, None], vals, 0.0).astype(
            rows["worker_grads"].dtype)
        wire = sparse_rows_to_dense(idx, vals, layout.n_flat)
    else:
        wire = jnp.where(upload[:, None], delta, 0.0).astype(
            rows["worker_grads"].dtype)
    # ∇̄ += Σ_cohort δ_m / M — the incremental aggregate; the (M-C)
    # offline rows would contribute exact zeros, so the dense masked mean
    # is reproduced bit-for-bit without ever materializing it.
    nabla = (server.nabla.astype(jnp.float32)
             + kops.eq3_row_mean(wire, m_total)).astype(server.nabla.dtype)
    worker_grads = (wg32 + wire.astype(jnp.float32)
                    ).astype(rows["worker_grads"].dtype)

    staleness = (server.staleness + 1).at[cohort].set(
        jnp.where(upload, 1, stale_c + 1))
    extras = strategy.flat_post_upload(extras, cache, upload, ctx)
    new_rows = {"worker_grads": worker_grads,
                **{name: extras[name] for name in pooled}}
    server_extras = {name: v for name, v in extras.items()
                     if name not in pooled}

    uploads = jnp.sum(upload.astype(jnp.int32))
    metrics = {
        "uploads": uploads,
        "skip_rate": 1.0 - uploads.astype(jnp.float32) / c,
        "upload_mask": upload,
        "staleness": staleness[cohort],
        "rhs": rhs,
        # per-cohort-member gate LHS for the obs ledger's margin split
        "lhs": lhs,
        "mean_lhs": jnp.mean(jnp.where(jnp.isfinite(lhs), lhs, 0.0)),
        "max_staleness": jnp.max(staleness),
        "grad_evals": (jnp.sum(h_steps) if strategy.delta_payload
                       else jnp.asarray(c, jnp.int32)
                       * strategy.grad_evals_per_iter),
        "bytes_up": (uploads.astype(jnp.float32)
                     * strategy.bytes_per_upload(layout.n)),
    }
    new_server = CohortServerState(nabla=nabla, staleness=staleness,
                                   diff_hist=server.diff_hist,
                                   extras=server_extras)
    return FlatCohortRoundResult(losses=losses, server=new_server,
                                 rows=new_rows, upload=upload,
                                 metrics=metrics)


def record_progress(comm: FlatCommState, dtheta_sq, k) -> FlatCommState:
    """Push ||θ^{k+1} − θ^k||² into the RHS ring buffer (line 17's tail)."""
    d_max = comm.diff_hist.shape[0]
    diff_hist = jax.lax.dynamic_update_index_in_dim(
        comm.diff_hist, dtheta_sq.astype(jnp.float32), k % d_max, axis=0)
    return comm._replace(diff_hist=diff_hist)


def nabla_f32(comm: FlatCommState) -> jnp.ndarray:
    """The server-update driver ∇^k as an fp32 flat buffer (line 16)."""
    return comm.nabla.astype(jnp.float32)


# ------------------------------------------------- pipelined cohort driver
#
# The serial cohort loop is a chain per round: host gather (H2D), jitted
# step, host scatter whose np.asarray BLOCKS on the D2H transfer. XLA
# dispatch is asynchronous, so the chain wastes the device: while the
# host waits on round i's transfers the device is idle, and vice versa.
#
# The pipelined driver reorders TRANSFERS, never arithmetic:
#
#   round i:   enqueue step(i)            [device busy with round i]
#              scatter out(i-1)           [D2H wait rides under step(i)]
#              stage + dispatch rows(i+1) [H2D rides under step(i)]
#
# Deferring round i's scatter one round means the pool misses round i's
# updates when round i+1's rows are staged. When consecutive cohorts
# overlap, the overlapping rows are instead forwarded ON DEVICE: the
# precomputed ``src`` schedule maps each round-(i+1) cohort position to
# its position in round i's output block (or -1), and
# :func:`patch_fused_rows` substitutes round i's exact output rows. The
# substituted values are bit-identical to what the scatter+gather round
# trip would have produced, so the pipeline is bit-exact to the serial
# loop — pinned for every registered rule by tests/test_cohort_pipeline.


def cohort_overlap_schedule(cohorts: np.ndarray) -> np.ndarray:
    """(T, C) int32 forwarding schedule for the deferred-scatter pipeline.

    ``src[i, j]`` = position of worker ``cohorts[i, j]`` inside
    ``cohorts[i-1]`` (whose output block is still on device when round i
    runs), or -1 when the worker was not in the previous cohort. Row 0 is
    all -1. Rows must be sorted ascending (``sample_cohorts`` invariant).
    """
    cohorts = np.asarray(cohorts, np.int64)
    t, c = cohorts.shape
    src = np.full((t, c), -1, np.int32)
    for i in range(1, t):
        prev = cohorts[i - 1]
        pos = np.searchsorted(prev, cohorts[i])
        pos = np.clip(pos, 0, c - 1)
        hit = prev[pos] == cohorts[i]
        src[i] = np.where(hit, pos, -1).astype(np.int32)
    return src


def patch_fused_rows(fused: jnp.ndarray, prev: jnp.ndarray,
                     src: jnp.ndarray) -> jnp.ndarray:
    """Forward the previous round's output rows into this round's gather.

    ``fused``/``prev`` are (P, C, n_flat) / (P, C_prev, n_flat) blocks,
    ``src`` the (C,) schedule row from :func:`cohort_overlap_schedule`.
    Positions with ``src < 0`` keep the gathered rows. All shapes are
    static, so the patch compiles once per (C, C_prev).

    Bit-exactness contract: the pipelined driver runs this as its OWN
    jitted call (:func:`_patch_fused_jit`) and feeds the materialized
    result to the cohort step. Inlining the select into the step is NOT
    safe — XLA duplicates fused consumer chains under the select's two
    branches and LLVM contracts fma differently per copy, so a row
    arriving through the ``prev`` gather picks up different low bits
    than the SAME values arriving through ``fused``. Materializing the
    patch as an executable boundary makes the step consume one memory
    operand on both paths, which pins serial/pipelined parity by plain
    determinism."""
    safe = jnp.clip(src, 0, prev.shape[1] - 1)
    forwarded = prev[:, safe, :]
    return jnp.where((src >= 0)[None, :, None], forwarded, fused)


# the gathered block is staging output and never reused: donate it so the
# patch can write in place; ``prev`` is re-read by the deferred scatter
# and MUST NOT be donated.
_patch_fused_jit = jax.jit(patch_fused_rows, donate_argnums=(0,))


def split_fused_rows(fused: jnp.ndarray, order: tuple) -> dict:
    """(P, C, n_flat) block -> {plane_name: (C, n_flat)} views."""
    return {name: fused[p] for p, name in enumerate(order)}


def stack_fused_rows(rows: dict, order: tuple, dtype) -> jnp.ndarray:
    """{plane_name: (C, n_flat)} -> one (P, C, n_flat) block in the
    pool's storage dtype (the cast the host scatter used to do)."""
    return jnp.stack([rows[name].astype(dtype) for name in order])


def run_cohort_rounds(step_fn, state, pool: WorkerPool, batch_fn,
                      cohorts: np.ndarray, *, pipeline: bool = True,
                      metrics_every: int = 8, on_round=None,
                      on_round_every: int = 0,
                      trace=None, metrics_out: list | None = None):
    """Drive T cohort rounds through a fused jitted step.

    ``step_fn(state, fused, batch, cohort) -> (state, fused_out,
    metrics)`` may donate (state, fused) — serial and pipelined drive
    the SAME executable. ``batch_fn(i, cohorts[i])`` supplies round i's
    cohort batch; ``cohorts`` is (T, C) int32, every row sorted
    ascending with unique ids (validated up front — raises ValueError
    otherwise). An empty schedule returns ``(state, [])``.

    ``pipeline=False`` is the serial parity oracle: eager
    gather → step → scatter per round.

    ``pipeline=True`` double-buffers: round i+1's rows are staged and
    dispatched H2D while round i's step runs, and round i's scatter is
    deferred one round so its D2H wait rides under round i+1's compute.
    Rows that round i+1 shares with round i are stale in that early
    gather; they are forwarded from round i's device output by
    :func:`_patch_fused_jit` — a SEPARATE jitted call, so the step
    consumes one materialized block on both paths and parity with the
    serial oracle is plain single-executable determinism (see
    :func:`patch_fused_rows` for why inlining the select would break
    bit-exactness). Rounds with no overlap skip the patch entirely. The
    pending scatter is drained on ANY exit (including exceptions), so
    an interrupted run leaves the pool consistent through the last
    completed round.

    Metrics are accumulated device-side and fetched with one
    ``jax.device_get`` every ``metrics_every`` rounds (the losses trace
    rides in the same dicts); the partial device-side window is flushed
    on ANY exit too, so a traced/errored run never silently drops the
    tail ``< metrics_every`` rounds — pass ``metrics_out`` (a list; it
    doubles as the return value) to observe metrics through the last
    completed round even when the run raises. ``on_round(i, state) ->
    state|None`` fires every ``on_round_every`` rounds AFTER the pool is
    drained through round i (the ``resum_every`` drift-guard hook).
    ``trace`` is an ``obs.trace.Tracer`` (or None): each round emits
    gather/patch/step/scatter spans on the ``"pipeline"`` track — the
    one home for per-round phase timing; the bench harness reads
    ``trace.aggregate("pipeline")`` instead of keeping its own clocks.
    Returns (state, list-of-host-metric-dicts).
    """
    from ..obs.trace import as_tracer

    cohorts = np.asarray(cohorts, np.int32)
    t_rounds = cohorts.shape[0]
    mets_host: list = metrics_out if metrics_out is not None else []
    if t_rounds == 0:
        return state, mets_host
    # both drivers depend on sorted-unique rows (sample_cohorts already
    # guarantees it): the overlap schedule searchsorts the previous row,
    # so an unsorted cohort would silently forward the WRONG rows —
    # validate once up front instead of re-sorting per round, since
    # sorting here would desynchronize cohorts from batch_fn's batches
    if not (np.diff(cohorts, axis=1) > 0).all():
        raise ValueError(
            "run_cohort_rounds: every cohorts row must be sorted "
            "ascending with unique worker ids (the sample_cohorts "
            "invariant) — sort each cohort AND its batch together "
            "before calling")
    metrics_every = max(1, int(metrics_every))
    tracer = as_tracer(trace)

    mets_dev: list = []

    def flush_metrics():
        if mets_dev:
            mets_host.extend(jax.device_get(mets_dev))
            mets_dev.clear()

    # per-round cohort/src rows ride into the jitted calls as numpy args
    # (one inline transfer) — slicing a staged device matrix per round
    # costs a full op dispatch, ~4x the price of the whole patch call

    if not pipeline:
        # serial oracle: eager gather → step → scatter, same executable
        # as the pipelined path
        try:
            for i in range(t_rounds):
                with tracer.span("gather", track="pipeline"):
                    fused = pool.gather_fused(cohorts[i])
                with tracer.span("step", track="pipeline"):
                    state, out, met = step_fn(state, fused,
                                              batch_fn(i, cohorts[i]),
                                              cohorts[i])
                with tracer.span("scatter", track="pipeline"):
                    pool.scatter_fused(cohorts[i], out)
                mets_dev.append(met)
                if len(mets_dev) >= metrics_every:
                    flush_metrics()
                if on_round is not None and on_round_every \
                        and (i + 1) % on_round_every == 0:
                    state = _maybe(on_round(i, state), state)
        finally:
            flush_metrics()
        return state, mets_host

    src_sched = cohort_overlap_schedule(cohorts)
    has_overlap = (src_sched >= 0).any(axis=1)       # host-side, per round
    prev = None                        # round i-1's device output block
    with tracer.span("gather", track="pipeline"):
        fused_next = pool.gather_fused(cohorts[0], slot=0)
    pending = None                     # (cohort_np, device_out) to scatter
    try:
        for i in range(t_rounds):
            batch = batch_fn(i, cohorts[i])
            if has_overlap[i]:
                # rows shared with round i-1 are stale in the early
                # gather: forward them from prev in a separate jit call
                with tracer.span("patch", track="pipeline"):
                    fused_next = _patch_fused_jit(fused_next, prev,
                                                  src_sched[i])
            with tracer.span("step", track="pipeline"):
                state, out, met = step_fn(state, fused_next,
                                          batch, cohorts[i])
            with tracer.span("scatter", track="pipeline"):
                # round i-1's writeback: its D2H wait rides under step i
                if pending is not None:
                    pool.scatter_fused(*pending)
            pending = (cohorts[i], out)
            prev = out
            # stage round i+1 while step i runs; round i's rows are
            # forwarded on device by the src schedule, everything older
            # is already in the pool
            if i + 1 < t_rounds:
                with tracer.span("gather", track="pipeline"):
                    fused_next = pool.gather_fused(cohorts[i + 1],
                                                   slot=(i + 1) & 1)
            mets_dev.append(met)
            if len(mets_dev) >= metrics_every:
                flush_metrics()
            if on_round is not None and on_round_every \
                    and (i + 1) % on_round_every == 0:
                # the hook reads the pool: drain round i's rows first
                pool.scatter_fused(*pending)
                pending = None
                state = _maybe(on_round(i, state), state)
    finally:
        # drain on ANY exit: the pool is consistent — and the partial
        # metrics window fetched — through the last completed round even
        # when the run is interrupted mid-flight
        if pending is not None:
            pool.scatter_fused(*pending)
        flush_metrics()
    return state, mets_host


def _maybe(new_state, state):
    return state if new_state is None else new_state
