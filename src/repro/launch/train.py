"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the hierarchical-CADA trainer on whatever devices exist (the host
mesh), with checkpointing and metric logging. On a real TPU fleet the same
code runs under the production meshes of launch/mesh.py (the dry-run proves
every assigned architecture lowers against those).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

import repro.configs as C
from repro.checkpoint import io as ckpt
from repro.core.comm import STRATEGIES, strategy_kinds
from repro.core.rules import CommRule
from repro.data.synthetic import lm_tokens
from repro.distributed.trainer import (TrainHParams, flat_state_shards,
                                       init_train_state, jit_train_step,
                                       worker_split)
from repro.launch.mesh import make_host_mesh, set_mesh


def make_token_batches(cfg, *, global_batch, seq, steps, seed=0):
    """Zipfian LM stream -> (steps, B, S+1) token batches."""
    toks = lm_tokens(steps * global_batch * (seq + 1) + 1, cfg.vocab,
                     seed=seed)
    n = steps * global_batch * (seq + 1)
    return toks[:n].reshape(steps, global_batch, seq + 1)


def _flatten_row(row: dict, prefix: str = "") -> dict:
    """One-level flatten of nested dicts into metric-name keys."""
    out = {}
    for k, v in row.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten_row(v, prefix=f"{key}_"))
        else:
            out[key] = v
    return out


def _write_obs(args, tracer, row: dict) -> None:
    """Write the requested telemetry sinks: Chrome-trace JSON
    (``--trace``), metrics JSONL (``--metrics-out``), Prometheus
    textfile (``--metrics-prom``)."""
    from repro.obs import MetricsRegistry, write_chrome_trace, write_jsonl

    if args.trace and tracer:
        write_chrome_trace(tracer, args.trace,
                           meta={"arch": args.arch, "rule": args.rule,
                                 "runtime": args.runtime})
        print(f"[obs] chrome trace ({len(tracer.events)} events, "
              f"{len(tracer.tracks)} tracks) -> {args.trace}")
    if args.metrics_out:
        write_jsonl(args.metrics_out, row)
        print(f"[obs] metrics jsonl -> {args.metrics_out}")
    if args.metrics_prom:
        reg = MetricsRegistry()
        for k, v in _flatten_row(row).items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            reg.gauge(k).set(v)
        reg.write_prom(args.metrics_prom)
        print(f"[obs] prometheus textfile -> {args.metrics_prom}")


def run_sim(cfg, rule, args) -> None:
    """`--runtime sim`: train under the discrete-event heterogeneous-
    cluster runtime (repro.sim) — simulated wall-clock under the chosen
    network profile, synchronous barrier or bounded-staleness async
    (`--async-tau`). No mesh: workers are simulated processes.
    `--trace` exports every simulated compute/transfer/gate event as a
    span on the simulated clock (one track per worker + a server track)."""
    import jax.numpy as jnp

    from repro.models.model import init_params, lm_loss
    from repro.obs import Tracer
    from repro.sim import simulate, summarize

    m = args.workers or 4
    steps = args.steps
    toks = make_token_batches(cfg, global_batch=args.global_batch,
                              seq=args.seq, steps=steps)
    # delta-payload rules consume (H, M, b, ·) per round; adaptive H runs
    # against batches padded to the adaptation cap (the realized schedule
    # masks each worker's scan to its own H_m)
    h = _round_local_steps(rule, args)
    per_step = [worker_split({"tokens": toks[i]}, m, local_steps=h)
                for i in range(steps)]
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)

    mode = "async" if args.async_tau else "barrier"
    tracer = Tracer() if args.trace else None
    params = init_params(cfg, jax.random.PRNGKey(0))
    res = simulate(lambda p, wb: lm_loss(cfg, p, wb)[0], rule, params,
                   batches, n_workers=m, network=args.network, mode=mode,
                   async_tau=args.async_tau,
                   participation=args.participation,
                   cohort_size=args.cohort_size,
                   host_pool=bool(args.async_tau
                                  and (args.host_pool or args.pool_memmap)),
                   pipeline=not args.no_pipeline,
                   metrics_every=args.metrics_every,
                   pool_storage="memmap" if args.pool_memmap else "ram",
                   pool_path=args.pool_memmap or None, lr=args.lr,
                   eval_s=args.sim_eval_ms * 1e-3, trace=tracer)
    row = summarize(res, args.target_loss or None)
    print(f"[sim] {args.network}/{mode} rule={rule.kind}: "
          f"{res.steps} server steps in {res.wall_s:.3f} simulated s, "
          f"loss {row['final_loss']:.4f}, uploads {res.uploads}, "
          f"up {row['mbytes_up']:.3f} MB, "
          f"utilization {row['utilization_mean']:.2f}")
    print(json.dumps(row, indent=1))
    _write_obs(args, tracer, row)


def _round_local_steps(rule: CommRule, args) -> int:
    """Local-step axis H of one round's batch: the adaptation cap for
    adaptive-H runs, the fixed period otherwise, 1 for gradient-payload
    rules. Validates the global batch divides into H · M slices."""
    if not STRATEGIES[rule.kind].delta_payload:
        return 1
    h = (rule.resolved_local_steps_max if rule.adapt_local_steps
         else rule.local_steps)
    m = args.workers or 4
    if args.global_batch % (h * m):
        raise SystemExit(
            f"--global-batch {args.global_batch} must divide into "
            f"local_steps*workers = {h}*{m} per-local-step slices")
    return h


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=C.list_archs())
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-sized)")
    p.add_argument("--runtime", default="mesh", choices=["mesh", "sim"],
                   help="mesh = run on the host devices; sim = the "
                        "discrete-event heterogeneous-cluster runtime "
                        "(repro.sim) — simulated wall-clock under "
                        "--network, no accelerator mesh")
    p.add_argument("--network", default="lan",
                   help="sim runtime: network profile "
                        "(zero | lan | wan | hetero)")
    p.add_argument("--async-tau", type=int, default=0,
                   help="sim runtime: >0 runs the bounded-staleness ASYNC "
                        "mode with staleness cap tau (uploads applied as "
                        "they arrive); 0 = synchronous barrier mode")
    p.add_argument("--participation", type=float, default=1.0,
                   help="sim barrier mode: fraction of workers "
                        "participating per round")
    p.add_argument("--cohort-size", type=int, default=0,
                   help="sim barrier mode: >0 runs the FEDERATED cohort "
                        "plane — C sampled workers per round through the "
                        "host WorkerPool, O(C*n) device state")
    p.add_argument("--no-pipeline", action="store_true",
                   help="cohort rounds: disable the double-buffered "
                        "transfer pipeline (serial parity oracle)")
    p.add_argument("--metrics-every", type=int, default=8,
                   help="cohort rounds: fetch device-side metrics every "
                        "K rounds instead of per round")
    p.add_argument("--host-pool", action="store_true",
                   help="sim async mode: stream per-worker rows through "
                        "the host WorkerPool instead of holding the "
                        "(M, n) plane on device (implied by "
                        "--pool-memmap; this flag enables the RAM-backed "
                        "pool without memmap spill)")
    p.add_argument("--pool-memmap", default="",
                   help="back the WorkerPool's O(M*n) planes with "
                        "np.memmap files under this directory (M beyond "
                        "RAM); empty = RAM")
    p.add_argument("--sim-eval-ms", type=float, default=1.0,
                   help="sim runtime: simulated milliseconds per worker "
                        "gradient evaluation")
    p.add_argument("--target-loss", type=float, default=0.0,
                   help="sim runtime: report simulated "
                        "time-to-target-loss for this target (0 = off)")
    p.add_argument("--rule", default="cada2", choices=list(strategy_kinds()),
                   help="communication rule; every strategy registered in "
                        "repro.core.comm is launchable")
    p.add_argument("--quantize-bits", type=int, default=0,
                   help="b-bit innovation uploads (0 = rule default)")
    p.add_argument("--topk-frac", type=float, default=0.1,
                   help="topk rule: fraction of innovation entries "
                        "uploaded per (worker, leaf)")
    p.add_argument("--sparse-wire", action="store_true",
                   help="topk rule: ship (values, indices) pairs sized k "
                        "through the gated collective instead of the "
                        "dense masked plane")
    p.add_argument("--no-error-feedback", action="store_true",
                   help="laq/topk: drop the compression error instead of "
                        "carrying the per-worker residual e_m")
    p.add_argument("--period-min", type=int, default=1,
                   help="avp rule: per-worker upload-period lower bound")
    p.add_argument("--period-max", type=int, default=0,
                   help="avp rule: upper bound (0 = max-delay)")
    p.add_argument("--avp-compose", action="store_true",
                   help="avp rule: upload only when due AND the "
                        "innovation energy clears the CADA RHS")
    p.add_argument("--local-steps", type=int, default=1,
                   help="delta-payload rules (local_momentum | fedadam): "
                        "local optimizer steps per communication round — "
                        "the payload becomes the accumulated model delta")
    p.add_argument("--adapt-local-steps", action="store_true",
                   help="sim runtime only: adapt each worker's local-step "
                        "count from observed comm vs compute time (avp's "
                        "period rule generalized to local steps)")
    p.add_argument("--local-steps-min", type=int, default=1,
                   help="adaptive local steps: per-worker lower bound")
    p.add_argument("--local-steps-max", type=int, default=0,
                   help="adaptive local steps: upper bound (0 = max-delay)")
    p.add_argument("--local-lr", type=float, default=0.1,
                   help="delta-payload rules: local optimizer step size")
    p.add_argument("--state-fsdp-axes", default="",
                   help="comma list of mesh axes to ZeRO the flat "
                        "optimizer/comm state over (e.g. 'data')")
    p.add_argument("--moments-dtype", default="float32",
                   choices=["float32", "bfloat16"],
                   help="storage dtype of the flat {h, v̂} moment planes")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--c", type=float, default=1.0)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--workers", type=int, default=0,
                   help="0 = mesh data-axis size")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--trace", default="",
                   help="write a Chrome-trace/Perfetto JSON timeline "
                        "here: sim runtime = every simulated compute/"
                        "transfer/gate event on the simulated clock (one "
                        "track per worker + server); mesh runtime = "
                        "per-step train spans on the wall clock. Open in "
                        "chrome://tracing or ui.perfetto.dev")
    p.add_argument("--metrics-out", default="",
                   help="append the run's summary + per-rule comm ledger "
                        "(uploads, bytes split, staleness histogram, gate "
                        "margins) as one JSONL row to this path")
    p.add_argument("--metrics-prom", default="",
                   help="also write the metrics as a Prometheus "
                        "textfile-collector snapshot to this path")
    args = p.parse_args()

    cfg = (C.get_smoke_config(args.arch) if args.smoke
           else C.get_config(args.arch))
    if not cfg.embed_input:
        raise SystemExit(f"{args.arch} consumes modality embeddings; use "
                         "examples/serve_decode.py or the dry-run for it")
    if args.adapt_local_steps and args.runtime != "sim":
        raise SystemExit(
            "--adapt-local-steps needs --runtime sim: the adaptation "
            "signal is comm vs compute time from the sim's link model — "
            "the mesh runtime has no clock to adapt from")
    rule = CommRule(kind=args.rule, c=args.c, d_max=10, max_delay=50,
                    quantize_bits=args.quantize_bits,
                    error_feedback=not args.no_error_feedback,
                    topk_frac=args.topk_frac,
                    sparse_wire=args.sparse_wire,
                    period_min=args.period_min,
                    period_max=args.period_max,
                    avp_compose=args.avp_compose,
                    local_steps=args.local_steps,
                    adapt_local_steps=args.adapt_local_steps,
                    local_steps_min=args.local_steps_min,
                    local_steps_max=args.local_steps_max,
                    local_lr=args.local_lr,
                    server_lr=args.lr)
    if args.runtime == "sim":
        run_sim(cfg, rule, args)
        return
    mesh = make_host_mesh()
    hp = TrainHParams(rule=rule,
                      lr=args.lr, microbatches=args.microbatches,
                      moments_dtype=args.moments_dtype,
                      state_fsdp_axes=tuple(
                          a for a in args.state_fsdp_axes.split(",") if a))
    make, _, m = jit_train_step(cfg, mesh, hp)
    # the flat layout pads to the mesh's state-shard count: state init
    # must use the SAME count as the compiled step
    shards = flat_state_shards(cfg, mesh, hp)
    if args.workers:
        m = args.workers  # host-mesh override (simulated workers)
        shards = 1        # mesh-free step builder: unsharded flat plane
        from repro.distributed.trainer import make_train_step
        # donate the state: the train loop threads it linearly, so the
        # buffers alias in place instead of being copied every step
        step = jax.jit(make_train_step(cfg, hp, m), donate_argnums=(0,))
    else:
        step = None

    batches = make_token_batches(cfg, global_batch=args.global_batch,
                                 seq=args.seq, steps=args.steps)
    # mesh runtime: delta-payload rules run their FIXED local-step count
    # (adaptive H was rejected above); the global batch carves into
    # H · M per-local-step slices
    h = (rule.local_steps
         if STRATEGIES[rule.kind].delta_payload else 1)
    if args.global_batch % (h * m):
        raise SystemExit(
            f"--global-batch {args.global_batch} must divide into "
            f"local_steps*workers = {h}*{m} per-local-step slices")
    # telemetry: per-step train spans on the wall clock + a comm ledger
    # fed from device-side metric buffers fetched every --metrics-every
    # steps (same cadence contract as the cohort driver)
    obs_on = bool(args.trace or args.metrics_out or args.metrics_prom)
    tracer = None
    ledger = None
    obs_buf: list = []
    if obs_on:
        from repro.core.comm import strategy_for
        from repro.obs import CommLedger, Tracer
        tracer = Tracer() if args.trace else None
        ledger = CommLedger.for_strategy(strategy_for(rule))
    from repro.obs.trace import as_tracer
    tr = as_tracer(tracer)

    def drain_obs():
        if ledger is not None and obs_buf:
            for met in jax.device_get(obs_buf):
                ledger.observe_round(met)
            obs_buf.clear()

    with set_mesh(mesh):
        state = init_train_state(cfg, hp, m, jax.random.PRNGKey(0),
                                 shards=shards)
        if step is None:
            sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                worker_split({"tokens": batches[0]}, m, local_steps=h))
            step = make(sds)

        t0 = time.time()
        history = []
        for i in range(args.steps):
            batch = worker_split({"tokens": batches[i]}, m, local_steps=h)
            with tr.span("train_step", track="train", args={"step": i}):
                state, mets = step(state, batch)
            if obs_on:
                obs_buf.append(mets)
                if len(obs_buf) >= max(1, args.metrics_every):
                    drain_obs()
            if i % args.log_every == 0 or i == args.steps - 1:
                # scalars only: per-worker arrays (upload_mask, staleness)
                # don't belong in the scalar history log
                row = {k: float(v) for k, v in mets.items()
                       if np.ndim(v) == 0}
                row["step"] = i
                row["wall_s"] = round(time.time() - t0, 1)
                history.append(row)
                print(f"step {i:5d} loss={row['loss']:.4f} "
                      f"uploads={int(row['uploads'])}/{m} "
                      f"skip={row['skip_rate']:.2f} "
                      f"({row['wall_s']}s)", flush=True)
            if (args.ckpt_every and args.ckpt_dir
                    and i and i % args.ckpt_every == 0):
                ckpt.save(os.path.join(args.ckpt_dir, f"step_{i}"),
                          state.params, step=i)

    if args.ckpt_dir:
        ckpt.save(os.path.join(args.ckpt_dir, f"step_{args.steps}"),
                  state.params, step=args.steps)
        with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
            json.dump(history, f, indent=1)
    final = np.mean([h["loss"] for h in history[-3:]])
    print(f"done: final loss {final:.4f}")
    if obs_on:
        drain_obs()
        row = {"runtime": "mesh", "arch": args.arch, "rule": args.rule,
               "steps": args.steps, "final_loss": float(final),
               **ledger.summary()}
        _write_obs(args, tracer, row)


if __name__ == "__main__":
    main()
