"""Per-architecture training policy: which CADA rule, precision, and
microbatching a (config, mesh) pair gets.

The paper's protocol (fp32 stale state, CADA on every worker) is kept
wherever it fits; the 34B/314B/405B archs need production memory policy
(ZeRO over the pod axis, bf16 stale/moment storage, gradient accumulation) —
every deviation is recorded here in one place and noted in DESIGN.md
§Arch-applicability and the EXPERIMENTS.md roofline table.

``rule_kind`` may be ANY strategy registered in :mod:`repro.core.comm` —
paper rules plus the beyond-paper compressed-upload family (``cinn``,
``laq``, ``topk``) and the variance-adaptive period rule (``avp``); the
policy only decides hyper-parameters and memory knobs, never rule
behaviour. For ``topk`` the kept fraction scales down with model size
(the absolute kept count is what the DCN wire pays for).
"""
from __future__ import annotations

from repro.core.comm import strategy_kinds
from repro.core.rules import CommRule
from repro.distributed.trainer import TrainHParams
from repro.launch.mesh import POD
from repro.models.config import ModelConfig, param_count


def train_policy(cfg: ModelConfig, mesh, rule_kind: str | None = None
                 ) -> TrainHParams:
    """Defaults chosen by napkin math over v5e HBM (16 GB/chip); see
    EXPERIMENTS.md §Dry-run for the measured per-device bytes."""
    n = param_count(cfg)
    multi = POD in mesh.shape

    if rule_kind is None:
        rule_kind = "cada2"  # the paper's best-performing rule
    if rule_kind not in strategy_kinds():
        raise ValueError(f"unknown rule kind {rule_kind!r}; registered "
                         f"strategies: {strategy_kinds()}")

    # topk: a 34B+ innovation at frac=0.1 still ships gigabytes per upload;
    # 1% keeps the sparse wire proportionate on the big archs.
    topk_frac = 0.01 if (rule_kind == "topk" and n > 20e9) else 0.1
    rule = CommRule(kind=rule_kind, c=0.6, d_max=10, max_delay=50,
                    topk_frac=topk_frac)

    if n > 100e9:  # grok-1-314b, llama3-405b
        if not multi:
            # Per-worker CADA state cannot fit 16 data-axis workers on one
            # pod; run the paper's own baseline (distributed AMSGrad) and
            # exercise CADA across pods (DESIGN.md §Arch-applicability).
            rule = CommRule(kind="always")
        # Params FSDP stays POD-LOCAL (pod-spanning param gathers ride DCN
        # per layer per microbatch: measured 1.9e3 s/step); only the
        # once-per-step optimizer state ZeROs across pods (§Perf: 511×).
        return TrainHParams(
            rule=rule, microbatches=16, cada_dtype="bfloat16",
            moments_dtype="bfloat16", fsdp=True, fsdp_axes=("data",),
            state_fsdp_axes=("data", "pod") if multi else ())

    if n > 20e9:  # yi-34b
        return TrainHParams(rule=rule, microbatches=16,
                            cada_dtype="bfloat16", fsdp=True)

    if n > 3e9:  # falcon-mamba-7b
        return TrainHParams(rule=rule, microbatches=8, fsdp=True)

    return TrainHParams(rule=rule, microbatches=4)
