"""Production meshes (TPU v5e targets) + jax version-compat shims.

single pod:  (16, 16)    axes ("data", "model")        — 256 chips
multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips

Functions, not module constants: importing this module never touches jax
device state (the dry-run launcher must set XLA_FLAGS before first init).

Version compat: the pinned jax (0.4.x) predates ``jax.sharding.AxisType``
(and the ``axis_types=`` kwarg of ``jax.make_mesh``), ``jax.set_mesh``, and
``jax.shard_map``. The helpers below feature-detect once and fall back:

  * :func:`compat_make_mesh` — drops ``axis_types`` when unavailable (all
    axes are Auto by default there anyway);
  * :func:`set_mesh` — falls back to the ``Mesh`` context manager;
  * :func:`partial_auto_shard_map` — maps onto
    ``jax.experimental.shard_map`` with ``auto=``/``check_rep=``.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5-era explicit-sharding API
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pinned 0.4.x: meshes are implicitly all-Auto
    _AxisType = None

DATA, MODEL, POD = "data", "model", "pod"

# TPU v5e hardware constants used by the roofline model.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (intra-pod)
DCN_BW = 6.25e9               # B/s per host pair (inter-pod, ~50 Gbit)


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with every axis Auto, on any supported jax."""
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where it exists; the classic ``with mesh:`` context
    (which jit/with_sharding_constraint consult) on 0.4.x.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager


def partial_auto_shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """shard_map that is MANUAL over ``manual_axes`` and auto elsewhere.

    New jax spells this ``jax.shard_map(..., axis_names=..., check_vma=
    False)``; 0.4.x spells it ``jax.experimental.shard_map.shard_map(...,
    auto=<complement>, check_rep=False)``.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False,
                     auto=frozenset(mesh.axis_names) - manual)


def make_production_mesh(*, multi_pod: bool = False, model_par: int = 16):
    """Production meshes. ``model_par`` re-factorizes the 256 chips/pod
    between the data and model axes (16×16 default; e.g. 32×8 lets yi-34b's
    56 heads shard — §Perf hillclimb). Chip count is invariant."""
    per_pod = 256
    assert per_pod % model_par == 0
    data = per_pod // model_par
    shape = (2, data, model_par) if multi_pod else (data, model_par)
    axes = (POD, DATA, MODEL) if multi_pod else (DATA, MODEL)
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (tests / CPU smoke runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return compat_make_mesh((n // model, model), (DATA, MODEL))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
