"""Production meshes (TPU v5e targets).

single pod:  (16, 16)    axes ("data", "model")        — 256 chips
multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips

Functions, not module constants: importing this module never touches jax
device state (the dry-run launcher must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

DATA, MODEL, POD = "data", "model", "pod"

# TPU v5e hardware constants used by the roofline model.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (intra-pod)
DCN_BW = 6.25e9               # B/s per host pair (inter-pod, ~50 Gbit)


def make_production_mesh(*, multi_pod: bool = False, model_par: int = 16):
    """Production meshes. ``model_par`` re-factorizes the 256 chips/pod
    between the data and model axes (16×16 default; e.g. 32×8 lets yi-34b's
    56 heads shard — §Perf hillclimb). Chip count is invariant."""
    per_pod = 256
    assert per_pod % model_par == 0
    data = per_pod // model_par
    shape = (2, data, model_par) if multi_pod else (data, model_par)
    axes = (POD, DATA, MODEL) if multi_pod else (DATA, MODEL)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever devices exist locally (tests / CPU smoke runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), (DATA, MODEL),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
