"""Serving launcher: prefill a batch of prompts, then lock-step decode.

``python -m repro.launch.serve --arch falcon-mamba-7b --smoke --tokens 32``

Uses the same jit_prefill_step / jit_decode_step builders the multi-pod
dry-run lowers, on the host mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.distributed.serving import jit_decode_step, jit_prefill_step
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.models.model import init_params


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=C.list_archs())
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    args = p.parse_args()

    cfg = (C.get_smoke_config(args.arch) if args.smoke
           else C.get_config(args.arch))
    mesh = make_host_mesh()
    b, s = args.batch, args.prompt_len
    max_seq = s + args.tokens

    with set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        if cfg.embed_input:
            inputs = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)}
        else:
            inputs = {"embeds": jax.random.normal(
                jax.random.PRNGKey(1), (b, s, cfg.d_model), cfg.jnp_dtype)}
        batch_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), inputs)

        # prefill once, directly at the serving cache width
        from repro.models.model import prefill as _prefill
        t0 = time.time()
        prefill_fn = jax.jit(lambda p, i: _prefill(
            cfg, p, tokens=i.get("tokens"), embeds=i.get("embeds"),
            max_seq=max_seq))
        logits, cache = prefill_fn(params, inputs)
        print(f"prefill({b}x{s}): {time.time() - t0:.2f}s "
              f"logits {logits.shape}")
        decode_fn, _, _ = jit_decode_step(cfg, mesh, b, max_seq)

        key = jax.random.PRNGKey(2)
        out_tokens = []
        t0 = time.time()
        next_tok = jnp.argmax(logits, axis=-1)
        for i in range(args.tokens):
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                next_tok = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1)
            step_inputs = ({"tokens": next_tok} if cfg.embed_input else
                           {"embeds": jnp.zeros((b, 1, cfg.d_model),
                                                cfg.jnp_dtype)})
            logits, cache = decode_fn(params, cache, step_inputs)
            next_tok = jnp.argmax(logits, axis=-1)
            out_tokens.append(next_tok)
        dt = time.time() - t0
        toks = jnp.stack(out_tokens, axis=1)
        print(f"decoded {args.tokens} tokens x {b} seqs in {dt:.2f}s "
              f"({args.tokens * b / dt:.1f} tok/s)")
        print("sample token ids:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
