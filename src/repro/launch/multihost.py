"""Multi-host bootstrap for the production meshes.

A v5e-256 pod is 64 hosts × 4 chips; the 2×16×16 multi-pod mesh is 128
hosts. Each host runs the same binary; this module wires
``jax.distributed.initialize`` from the scheduler's environment (GKE/GCE
metadata or explicit flags) and asserts the global device count matches
the requested mesh before any jit is traced.

Usage (every host):
    from repro.launch.multihost import bootstrap
    bootstrap()                       # no-op on single-process runs
    mesh = make_production_mesh(...)  # now sees the global fleet
"""
from __future__ import annotations

import os

import jax

EXPECTED = {"16x16": 256, "2x16x16": 512}


def bootstrap(coordinator: str | None = None,
              num_processes: int | None = None,
              process_id: int | None = None) -> bool:
    """Initialize jax.distributed from args or environment.

    Environment (set by launch/cluster.sh or the job scheduler):
      REPRO_COORDINATOR   host:port of process 0
      REPRO_NUM_PROCESSES total host count
      REPRO_PROCESS_ID    this host's rank

    Returns True if distributed init ran, False for single-process runs
    (the CPU container, unit tests).
    """
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR")
    if not coordinator:
        return False
    num_processes = int(num_processes
                        or os.environ["REPRO_NUM_PROCESSES"])
    process_id = int(process_id
                     if process_id is not None
                     else os.environ["REPRO_PROCESS_ID"])
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True


def assert_fleet(mesh_name: str) -> None:
    """Fail fast (before tracing) if the fleet doesn't match the mesh."""
    want = EXPECTED[mesh_name]
    have = jax.device_count()
    if have != want:
        raise RuntimeError(
            f"mesh {mesh_name} needs {want} chips; the fleet has {have}. "
            "Check REPRO_NUM_PROCESSES / TPU topology flags.")


def is_coordinator() -> bool:
    return jax.process_index() == 0
