"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production meshes and extract the roofline
terms from the compiled artifact.

Run as:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun.jsonl

The FIRST TWO LINES below must stay first: jax locks the device count on
first init, and the production meshes need 512 placeholder host devices.
Smoke tests and benches must NOT import this module (they want 1 device).
"""
import os  # noqa: E402  (the two-line contract of the task spec)
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro import configs as cfgs                              # noqa: E402
from repro.configs.base import SHAPES, adapt_for_shape, input_specs  # noqa: E402
from repro.distributed.serving import (                        # noqa: E402
    jit_decode_step, jit_prefill_step,
)
from repro.distributed.trainer import (                        # noqa: E402
    abstract_train_state, flat_state_shards, jit_train_step,
    worker_split_abstract,
)
from repro.launch.mesh import (                                # noqa: E402
    DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
    set_mesh,
)
from repro.launch.policy import train_policy                   # noqa: E402
from repro.models.config import active_param_count, param_count  # noqa: E402
from repro.models.model import abstract_params                 # noqa: E402
from repro.utils.hlo_cost import analyze as hlo_analyze        # noqa: E402


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                rule_kind: str | None = None, hp_override=None,
                model_par: int = 16, cfg_override=None):
    """Lower one (arch, shape, mesh) combo. Returns (lowered, meta)."""
    cfg = cfg_override or cfgs.get_config(arch)
    shape = SHAPES[shape_name]
    cfg = adapt_for_shape(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod, model_par=model_par)
    aps = abstract_params(cfg)

    if shape.kind == "train":
        hp = hp_override or train_policy(cfg, mesh, rule_kind)
        make, _, m = jit_train_step(cfg, mesh, hp)
        batch_sds = worker_split_abstract(
            input_specs(cfg, shape)["batch"], m)
        # state shapes must match the step's: the flat layout pads to the
        # mesh's state-shard count
        state_sds = abstract_train_state(
            cfg, hp, m, shards=flat_state_shards(cfg, mesh, hp))
        with set_mesh(mesh):
            lowered = make(batch_sds).lower(state_sds, batch_sds)
        meta = {"step": "train_step", "rule": hp.rule.kind,
                "microbatches": hp.microbatches,
                "cada_dtype": hp.cada_dtype,
                "moments_dtype": hp.moments_dtype}
    elif shape.kind == "prefill":
        specs = input_specs(cfg, shape)
        with set_mesh(mesh):
            jitted = jit_prefill_step(cfg, mesh, specs)
            lowered = jitted.lower(aps, specs)
        meta = {"step": "prefill"}
    else:  # decode
        specs = input_specs(cfg, shape)
        with set_mesh(mesh):
            jitted, cache_sds, inputs_sds = jit_decode_step(
                cfg, mesh, shape.batch, shape.seq)
            lowered = jitted.lower(aps, cache_sds, inputs_sds)
        meta = {"step": "serve_step",
                "sliding_window": cfg.sliding_window}

    meta.update(arch=arch, shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                chips=512 if multi_pod else 256)
    return lowered, cfg, shape, meta


def roofline_terms(compiled, lowered, cfg, shape, meta) -> dict:
    """The three roofline terms, per chip, from the compiled artifact.

    XLA's flat cost_analysis counts while bodies once; we re-derive flops /
    bytes / collective traffic with the trip-count-aware analyzer
    (utils/hlo_cost.py) over the post-optimization per-device HLO.
    """
    cost = hlo_analyze(compiled.as_text())
    flops = float(cost.flops)
    bytes_acc = float(cost.bytes_fused)   # TPU-fused estimate (see hlo_cost)

    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_acc / HBM_BW
    t_coll = cost.collective_bytes / ICI_BW
    t_dcn = cost.dcn_bytes / DCN_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]

    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.batch
        model_flops = 2 * n_active * tokens
    model_flops_per_chip = model_flops / meta["chips"]
    useful = model_flops_per_chip / flops if flops else 0.0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not expose it
        mem["error"] = str(e)

    return {
        **meta,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "hlo_bytes_unfused_per_chip": float(cost.bytes_accessed),
        "collective_bytes_per_chip": cost.collective_bytes,
        "dcn_bytes_per_chip": cost.dcn_bytes,
        "t_dcn_s": t_dcn,
        "collectives": dict(cost.coll_count),
        "collective_bytes_by_kind": dict(cost.coll_by_kind),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": useful,
        "params": param_count(cfg),
        "active_params": n_active,
        "memory_analysis": mem,
    }


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              rule_kind: str | None = None, verbose: bool = True,
              hp_override=None, model_par: int = 16, cfg_override=None,
              tag: str = "") -> dict:
    t0 = time.time()
    lowered, cfg, shape, meta = lower_combo(
        arch, shape_name, multi_pod=multi_pod, rule_kind=rule_kind,
        hp_override=hp_override, model_par=model_par,
        cfg_override=cfg_override)
    if model_par != 16:
        meta["mesh"] = meta["mesh"].replace(
            "16x16", f"{256 // model_par}x{model_par}")
    if tag:
        meta["tag"] = tag
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    row = roofline_terms(compiled, lowered, cfg, shape, meta)
    row["t_lower_s"] = round(t_lower, 1)
    row["t_compile_s"] = round(t_compile, 1)
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {row['mesh']} "
              f"({row['step']}): OK  "
              f"compute={row['t_compute_s']:.3e}s "
              f"memory={row['t_memory_s']:.3e}s "
              f"collective={row['t_collective_s']:.3e}s "
              f"dominant={row['dominant']} "
              f"useful={row['useful_flops_ratio']:.2f} "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)",
              flush=True)
        if row["memory_analysis"]:
            print(f"         memory_analysis: {row['memory_analysis']}",
                  flush=True)
    return row


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None, help="architecture id")
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--all", action="store_true",
                   help="every (arch × shape) combination")
    p.add_argument("--multi-pod", action="store_true",
                   help="2x16x16 (512-chip) mesh instead of 16x16")
    p.add_argument("--rule", default=None,
                   choices=["cada1", "cada2", "lag", "always"])
    p.add_argument("--model-par", type=int, default=16,
                   help="model-axis size (256/model_par becomes data)")
    p.add_argument("--set", dest="overrides", action="append", default=[],
                   help="config override field=value (repeatable; §Perf)")
    p.add_argument("--hp-set", dest="hp_overrides", action="append",
                   default=[],
                   help="TrainHParams override field=value (repeatable)")
    p.add_argument("--out", default=None, help="append JSONL rows here")
    args = p.parse_args()

    def cfg_override_for(arch):
        if not args.overrides:
            return None
        cfg = cfgs.get_config(arch)
        kw = {}
        for ov in args.overrides:
            key, val = ov.split("=", 1)
            for cast in (int, float):
                try:
                    val = cast(val)
                    break
                except ValueError:
                    continue
            if val in ("True", "False"):
                val = val == "True"
            kw[key] = val
        return cfg.with_(**kw)

    combos = []
    if args.all:
        for arch in cfgs.list_archs():
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            hp_override = None
            if args.hp_overrides:
                import dataclasses
                from repro.launch.policy import train_policy as _tp
                cfg0 = cfgs.get_config(arch)
                mesh0 = make_production_mesh(multi_pod=args.multi_pod,
                                             model_par=args.model_par)
                hp_override = _tp(cfg0, mesh0, args.rule)
                kw = {}
                for ov in args.hp_overrides:
                    key, val = ov.split("=", 1)
                    if key.endswith("_axes"):
                        val = tuple(a for a in val.split(",") if a)
                    else:
                        for cast in (int, float):
                            try:
                                val = cast(val)
                                break
                            except ValueError:
                                continue
                        if val in ("True", "False"):
                            val = val == "True"
                    kw[key] = val
                hp_override = dataclasses.replace(hp_override, **kw)
            row = run_combo(arch, shape, multi_pod=args.multi_pod,
                            rule_kind=args.rule, model_par=args.model_par,
                            cfg_override=cfg_override_for(arch),
                            hp_override=hp_override,
                            tag=";".join(args.overrides
                                         + args.hp_overrides))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(row) + "\n")
        except Exception:
            failures.append((arch, shape))
            print(f"[dryrun] {arch} × {shape}: FAILED", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} combos failed: {failures}")
    print(f"[dryrun] all {len(combos)} combos passed", flush=True)


if __name__ == "__main__":
    main()
