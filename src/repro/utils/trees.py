"""Pytree arithmetic helpers.

All of the CADA bookkeeping (stale gradients, innovations, rule norms) is
expressed as whole-pytree arithmetic; keeping these helpers centralized keeps
the optimizer / engine code close to the paper's vector notation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """b + s * a, elementwise over matching pytrees."""
    return jax.tree.map(lambda x, y: y + s * x, a, b)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_sq_norm(a):
    """Sum of squared entries across the whole pytree (fp32 accumulate)."""
    leaves = jax.tree.leaves(a)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


def tree_dot(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return sum(
        jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
        for x, y in zip(la, lb)
    )


def tree_size(a):
    """Total number of scalar parameters in the pytree."""
    return sum(x.size for x in jax.tree.leaves(a))


def tree_where_mask(mask, a, b):
    """Select a where (scalar/broadcastable) bool mask else b, per leaf."""
    return jax.tree.map(lambda x, y: jnp.where(mask, x, y), a, b)


def tree_bytes(a):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))
