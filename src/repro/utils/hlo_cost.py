"""Trip-count-aware cost analysis over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE, which
under-reports a scanned-layers transformer by ~n_layers × microbatches and
silently zeroes the collectives inside the loop. The compiled HLO text,
however, carries ``backend_config={"known_trip_count":{"n":"24"}}`` on every
bounded while op — so this module re-derives the three roofline inputs by
walking the computation graph and multiplying through trip counts:

  * flops            — dot ops: 2 · |out| · K (contraction size from the
                       operand shape table); elementwise/reduce ops: |out|
                       (1 flop per element, transcendentals included);
  * bytes accessed   — per instruction: operand + result array bytes,
                       skipping pure data-movement ops (tuple plumbing,
                       parameters, constants, bitcasts) — a fusion is one
                       instruction, so internal temporaries are not charged
                       (the same convention XLA's own analysis uses);
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-scaled, split by kind.

Validated against XLA's analysis on scan-free modules (tests/test_hlo_cost).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# one array shape, e.g. bf16[256,4096,512]{2,1,0}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# an instruction line: %name = <shape...> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "power", "tanh", "logistic",
    "negate", "abs", "and", "or", "xor", "not", "select", "compare",
    "floor", "ceil", "sign", "cosine", "sine", "exponential-minus-one",
    "log-plus-one", "atan2", "remainder", "clamp",
}
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}
# additionally skipped for the "as-if-fused" (TPU-optimistic) byte count
_FUSABLE = _ELEMENTWISE | {
    "broadcast", "reshape", "transpose", "convert", "slice", "pad",
    "reverse", "copy", "reduce", "concatenate", "dynamic-slice",
    "exponential", "rsqrt", "sqrt",
}


def _shape_bytes_and_elems(shape_text: str):
    """Total bytes and element count over every array in a shape string
    (handles tuples by summing)."""
    nbytes = 0
    nelems = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nelems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return nbytes, nelems


@dataclass
class _Instr:
    name: str
    opcode: str
    shape_text: str
    line: str


@dataclass
class Cost:
    """``bytes_accessed`` follows XLA's HloCostAnalysis convention (operand +
    result charged at every top-level instruction). The CPU backend fuses far
    less than Mosaic/TPU would, so that is pessimistic for a TPU roofline;
    ``bytes_fused`` additionally skips bare elementwise / layout ops at the
    top level — i.e. charges only fusion boundaries, dots, gathers/scatters,
    dynamic-update-slices, reduces and collectives — approximating what a
    TPU-fused module would move through HBM. Report both; roofline dominance
    uses the fused number."""
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_fused: float = 0.0
    collective_bytes: float = 0.0
    dcn_bytes: float = 0.0   # collectives whose replica_groups span pods
    coll_by_kind: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        self.bytes_fused += other.bytes_fused
        self.collective_bytes += other.collective_bytes
        self.dcn_bytes += other.dcn_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v
        return self

    def scaled(self, n: int) -> "Cost":
        return Cost(self.flops * n, self.bytes_accessed * n,
                    self.bytes_fused * n,
                    self.collective_bytes * n, self.dcn_bytes * n,
                    {k: v * n for k, v in self.coll_by_kind.items()},
                    {k: v * n for k, v in self.coll_count.items()})


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,{} ]*)\}")
# iota form: replica_groups=[G,N]<=[d0,d1,...]T(p0,p1,...)
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _spans_pods(line: str, pod_size: int) -> bool:
    """True if any replica group mixes device ids from different pods."""
    m = _IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        groups = ids.reshape(g, n)
        return bool(((groups // pod_size).max(axis=1)
                     != (groups // pod_size).min(axis=1)).any())
    m = _GROUPS_RE.search(line)
    if not m:
        return False
    for grp in m.group(1).split("},{"):
        ids = [int(x) for x in re.findall(r"\d+", grp)]
        if ids and len({i // pod_size for i in ids}) > 1:
            return True
    return False


class HloCostModel:
    def __init__(self, hlo_text: str, pod_size: int = 256):
        self.computations: dict[str, list[_Instr]] = {}
        self.param_shapes: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self.pod_size = pod_size
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}

    def _inplace_dus_correction(self, comp_name: str) -> float:
        """Bytes to SUBTRACT from a fusion's (operands + output) charge for
        windowed buffer access INSIDE the fusion:

        * dynamic-update-slice: the buffer is threaded through untouched
          except for the update window — charge 2×window instead of
          2×buffer (XLA's kInPlaceDynamicUpdateSlice special case);
        * dynamic-slice / gather on a fusion *parameter*: only the window is
          read, not the whole stacked buffer the parameter carries.
        """
        corr = 0.0
        params = self.param_shapes.get(comp_name, {})
        shapes = dict(params)
        for i in self.computations.get(comp_name, []):
            shapes[i.name] = i.shape_text
        for i in self.computations.get(comp_name, []):
            paren = i.line.find(i.opcode + "(")
            if paren < 0:
                continue
            args = i.line[paren + len(i.opcode) + 1:]
            names = re.findall(r"%([\w.\-]+)", args)
            if i.opcode == "dynamic-update-slice":
                buf_b, _ = _shape_bytes_and_elems(i.shape_text)
                upd_b = 0
                if len(names) >= 2 and names[1] in shapes:
                    upd_b, _ = _shape_bytes_and_elems(shapes[names[1]])
                corr += max(0.0, 2.0 * (buf_b - upd_b))
            elif i.opcode in ("dynamic-slice", "gather") and names:
                if names[0] in params:  # windowed read of a fusion operand
                    buf_b, _ = _shape_bytes_and_elems(params[names[0]])
                    out_b, _ = _shape_bytes_and_elems(i.shape_text)
                    corr += max(0.0, buf_b - out_b)
        return corr

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        current = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR.match(line)
            if hdr and line.endswith("{"):
                current = hdr.group(1)
                self.computations[current] = []
                self.param_shapes.setdefault(current, {})
                # parameter shapes live in the header: (p0: f32[2,3], ...)
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|"
                                      r"(?:[\w\[\],]+))", line):
                    self.param_shapes[current][pm.group(1)] = pm.group(2)
                if line.startswith("ENTRY"):
                    self.entry = current
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                self.computations[current].append(
                    _Instr(m.group(1), m.group(3), m.group(2), line))

    # ------------------------------------------------------------- costing
    def _dot_flops(self, instr: _Instr, shapes: dict) -> float:
        _, out_elems = _shape_bytes_and_elems(instr.shape_text)
        # contraction size from the lhs operand's shape. Depending on the
        # XLA version the operand list is either bare names
        # ``dot(%a, %b)`` — resolve via the shape table — or carries inline
        # annotations ``dot(f32[64,64]{1,0} %a, ...)`` — take the first
        # inline shape, which is the lhs.
        args = instr.line[instr.line.index(instr.opcode + "(")
                          + len(instr.opcode) + 1:]
        k = 1
        cm = _LHS_CONTRACT.search(instr.line)
        if cm:
            lhs_shape = None
            first_op = re.match(r"\s*%([\w.\-]+)", args)
            if first_op and first_op.group(1) in shapes:
                lhs_shape = shapes[first_op.group(1)]
            else:
                # only trust an inline annotation that belongs to the FIRST
                # operand (anchored at the start of the argument list) —
                # a later match would be the rhs's shape
                inline = re.match(
                    r"\s*(?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\]",
                    args)
                if inline:
                    lhs_shape = inline.group(0)
            if lhs_shape:
                dims_m = _SHAPE_RE.search(lhs_shape)
                if dims_m and dims_m.group(2):
                    dims = [int(d) for d in dims_m.group(2).split(",")]
                    for ci in cm.group(1).split(","):
                        if ci:
                            k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def _computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        instrs = self.computations.get(name, [])
        shapes = {i.name: i.shape_text for i in instrs}
        for instr in instrs:
            op = instr.opcode
            c = Cost()
            base = op.rstrip("-start").rstrip("-done")
            if op == "while":
                body = _BODY_RE.search(instr.line)
                cond = _COND_RE.search(instr.line)
                trip = _TRIP_RE.search(instr.line)
                n = int(trip.group(1)) if trip else 1
                inner = Cost()
                if body:
                    inner += self._computation_cost(body.group(1))
                if cond:
                    inner += self._computation_cost(cond.group(1))
                c = inner.scaled(n)
            elif op == "conditional":
                bm = _BRANCHES_RE.search(instr.line)
                if bm:
                    branches = [b.strip().lstrip("%")
                                for b in bm.group(1).split(",")]
                    costs = [self._computation_cost(b) for b in branches]
                    if costs:  # pessimistic: the most expensive branch
                        c = max(costs, key=lambda x: x.flops
                                + x.bytes_accessed)
            elif op in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(instr.line)
                if cm:
                    inner = self._computation_cost(cm.group(1))
                    # flops/collectives recurse; bytes charged at this site
                    c.flops = inner.flops
                    c.collective_bytes = inner.collective_bytes
                    c.coll_by_kind = dict(inner.coll_by_kind)
                    c.coll_count = dict(inner.coll_count)
            elif any(op.startswith(k) for k in COLLECTIVES):
                if not op.endswith("-done"):
                    kind = next(k for k in COLLECTIVES if op.startswith(k))
                    args = instr.line[instr.line.index(op + "(") + len(op)
                                      + 1:]
                    nbytes = 0
                    for srm in _SHAPE_RE.finditer(args):
                        nb, _ = _shape_bytes_and_elems(srm.group(0))
                        nbytes += nb
                    if nbytes == 0:
                        # operands given by name: use the result shape
                        nbytes, _ = _shape_bytes_and_elems(instr.shape_text)
                    c.collective_bytes = nbytes
                    if _spans_pods(instr.line, self.pod_size):
                        c.dcn_bytes = nbytes
                    c.coll_by_kind = {kind: nbytes}
                    c.coll_count = {kind: 1}
            elif op == "dot":
                c.flops = self._dot_flops(instr, shapes)
            elif op in _ELEMENTWISE or op in ("reduce", "reduce-window",
                                              "scatter", "gather", "sort",
                                              "cumsum"):
                _, elems = _shape_bytes_and_elems(instr.shape_text)
                c.flops = float(elems)

            # bytes: operands + result at this instruction site. Slicing ops
            # follow XLA's convention: only the touched window is charged
            # (dynamic-update-slice writes ONE slot of a KV cache, not the
            # whole cache; gather reads the gathered rows only).
            if op not in _SKIP_BYTES and op != "while":
                out_b, _ = _shape_bytes_and_elems(instr.shape_text)
                if op in ("dynamic-slice", "slice", "gather"):
                    nbytes = 2 * out_b                     # window in + out
                elif op in ("dynamic-update-slice", "scatter"):
                    # window = the update operand (2nd arg)
                    paren = instr.line.find(op + "(")
                    args = instr.line[paren + len(op) + 1:]
                    names = re.findall(r"%([\w.\-]+)", args)
                    upd_b = 0
                    if len(names) >= 2 and names[1] in shapes:
                        upd_b, _ = _shape_bytes_and_elems(shapes[names[1]])
                    nbytes = 2 * upd_b
                else:
                    arg_b = 0
                    paren = instr.line.find(op + "(")
                    if paren >= 0:
                        args = instr.line[paren + len(op) + 1:]
                        for opm in re.finditer(r"%([\w.\-]+)", args):
                            st = shapes.get(opm.group(1))
                            if st:
                                ab, _ = _shape_bytes_and_elems(st)
                                arg_b += ab
                    nbytes = out_b + arg_b
                    if op == "fusion":
                        cm2 = _CALLS_RE.search(instr.line)
                        if cm2:
                            nbytes = max(
                                2.0 * 1024,
                                nbytes - self._inplace_dus_correction(
                                    cm2.group(1)))
                c.bytes_accessed += nbytes
                if op not in _FUSABLE:
                    c.bytes_fused += nbytes
            total += c
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self._computation_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


_ALIAS_RE = re.compile(r"\b(?:may|must)-alias\b")


def donation_aliases(hlo_text: str) -> int:
    """Number of input→output buffer aliases in a compiled module.

    ``jax.jit(..., donate_argnums=...)`` only avoids the per-call copy of
    the state buffers when XLA actually records the donation in the
    module's ``input_output_alias`` table — a donated argument that cannot
    alias (dtype/layout mismatch, consumed twice) is silently copied.
    Benches and tests assert this count is positive so "donated" means
    "aliased", not just "requested".
    """
    return len(_ALIAS_RE.findall(hlo_text))
