from repro.utils import trees, hlo
from repro.utils.trees import (
    tree_add, tree_sub, tree_scale, tree_zeros_like, tree_sq_norm,
    tree_dot, tree_axpy, tree_cast, tree_size, tree_where_mask,
)

__all__ = [
    "trees", "hlo",
    "tree_add", "tree_sub", "tree_scale", "tree_zeros_like", "tree_sq_norm",
    "tree_dot", "tree_axpy", "tree_cast", "tree_size", "tree_where_mask",
]
