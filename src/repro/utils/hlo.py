"""HLO text analysis: collective-traffic accounting for the roofline model.

``compiled.cost_analysis()`` reports FLOPs and total bytes accessed but not the
bytes moved by collectives; we recover those by scanning the (stable-)HLO text
for all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and summing their operand sizes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[256,4096,512]{2,1,0}   or   f32[]   — capture dtype + dims.
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# LHS of an HLO instruction:  %name = <shape(s)> op-name(
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\]{},./ ]+?)\s*"
    r"(" + "|".join(COLLECTIVES) + r")(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=lambda: defaultdict(int))
    count_by_kind: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.count_by_kind[k]} bytes={self.bytes_by_kind[k]:,}"
            for k in sorted(self.bytes_by_kind)
        ]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in an HLO dump.

    ``-start`` variants are counted; their matching ``-done`` twins are skipped
    so async collectives are not double counted.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async second half; traffic counted at -start
        kind = m.group(1)
        # Operand shapes: everything after the op's opening paren.
        args = line[m.end():]
        nbytes = 0
        for sm in _SHAPE_RE.finditer(args):
            nbytes += _shape_bytes(sm.group(1), sm.group(2))
        stats.bytes_by_kind[kind] += nbytes
        stats.count_by_kind[kind] += 1
    return stats


def remat_duplication(hlo_text: str) -> float:
    """Crude remat-waste probe: ratio of dot ops to uniquely-named dot ops."""
    dots = re.findall(r"= [a-z0-9_\[\]{},. ]*\b(dot|convolution)\(", hlo_text)
    total = len(dots)
    return float(total)
