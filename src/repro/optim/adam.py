"""Adam (Kingma & Ba, 2014) and AMSGrad (Reddi et al., 2018).

CADA's server update (paper eq. 2a–2c) is the AMSGrad form:
    h^{k+1} = β1 h^k + (1-β1) ∇^k
    v^{k+1} = β2 v̂^k + (1-β2) (∇^k)²
    v̂^{k+1} = max(v^{k+1}, v̂^k)
    θ^{k+1} = θ^k − α (εI + V̂^{k+1})^{-1/2} h^{k+1}
Note ε sits *inside* the square root in the paper; we follow that convention
(``eps_inside_sqrt=True``) and also offer the common ε-outside variant.

No bias correction is applied in the paper's update; ``bias_correction`` is
off by default for faithfulness and available for the beyond-paper runs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


class AdamState(NamedTuple):
    count: jnp.ndarray
    h: object  # first moment  (paper's h)
    v: object  # second moment (paper's v)
    vhat: object  # running max of v (AMSGrad); aliases v when amsgrad=False


def _scaled_update(h, vhat, lr, eps, eps_inside_sqrt):
    if eps_inside_sqrt:
        denom = jnp.sqrt(eps + vhat)
    else:
        denom = jnp.sqrt(vhat) + eps
    return -lr * h / denom


def adam(
    lr: float | object = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    amsgrad: bool = True,
    eps_inside_sqrt: bool = True,
    bias_correction: bool = False,
    state_dtype=jnp.float32,
) -> Optimizer:
    """Adam/AMSGrad in the paper's (2a)-(2c) convention.

    ``lr`` may be a float or a callable step -> float schedule.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, dtype=state_dtype), params
        )
        return AdamState(count=jnp.zeros([], jnp.int32), h=zeros, v=zeros,
                         vhat=zeros)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        h = jax.tree.map(
            lambda m, g: b1 * m + (1.0 - b1) * g.astype(m.dtype),
            state.h, grads)
        # Paper (2b): v^{k+1} = β2 v̂^k + (1-β2)(∇^k)²  — note v̂, not v.
        base = state.vhat if amsgrad else state.v
        v = jax.tree.map(
            lambda s, g: b2 * s + (1.0 - b2)
            * jnp.square(g.astype(s.dtype)),
            base, grads)
        vhat = jax.tree.map(jnp.maximum, v, state.vhat) if amsgrad else v
        step = lr_fn(state.count)
        if bias_correction:
            c1 = 1.0 - b1 ** count.astype(jnp.float32)
            c2 = 1.0 - b2 ** count.astype(jnp.float32)
            step = step * jnp.sqrt(c2) / c1
        updates = jax.tree.map(
            lambda m, s: _scaled_update(m, s, step, eps, eps_inside_sqrt),
            h, vhat)
        return updates, AdamState(count=count, h=h, v=v, vhat=vhat)

    return Optimizer(init, update)


def amsgrad(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, **kw) -> Optimizer:
    return adam(lr=lr, b1=b1, b2=b2, eps=eps, amsgrad=True, **kw)
