"""SGD and momentum SGD (used by the LAG and local-momentum baselines)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


class MomentumState(NamedTuple):
    count: jnp.ndarray
    momentum: object


def sgd(lr: float | object = 1e-2) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return jnp.zeros([], jnp.int32)

    def update(grads, state, params=None):
        del params
        step = lr_fn(state)
        return jax.tree.map(lambda g: -step * g, grads), state + 1

    return Optimizer(init, update)


def momentum(lr: float | object = 1e-2, beta: float = 0.9,
             nesterov: bool = False) -> Optimizer:
    """Heavy-ball momentum: u^{k+1} = β u^k + g;  θ -= α u^{k+1}."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return MomentumState(
            count=jnp.zeros([], jnp.int32),
            momentum=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state, params=None):
        del params
        buf = jax.tree.map(lambda m, g: beta * m + g, state.momentum, grads)
        if nesterov:
            d = jax.tree.map(lambda m, g: beta * m + g, buf, grads)
        else:
            d = buf
        step = lr_fn(state.count)
        updates = jax.tree.map(lambda u: -step * u, d)
        return updates, MomentumState(state.count + 1, buf)

    return Optimizer(init, update)
