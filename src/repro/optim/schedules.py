"""Learning-rate schedules.

The paper uses a constant stepsize α = O(1/√K) for Theorem 4 and the
PL-condition schedule α_k = 2/(μ(k+K0)) for Theorem 5.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def inv_sqrt_horizon(eta: float, horizon: int):
    """α = η/√K, the Theorem-4 choice (constant over the run)."""
    return constant(eta / float(horizon) ** 0.5)


def pl_schedule(mu: float, k0: float = 1.0):
    """α_k = 2 / (μ (k + K0)) — Theorem 5's O(1/K) schedule."""
    return lambda step: 2.0 / (mu * (step.astype(jnp.float32) + k0))


def cosine(peak: float, total_steps: int, warmup: int = 0,
           floor: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total_steps - warmup),
                        0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn
