"""Kernel-backed fused AMSGrad/CADA optimizer.

The optax-style ``Optimizer`` protocol returns *updates* so transforms can be
chained; the Pallas kernel instead applies the whole step in one HBM pass and
returns ||Δθ||² (the CADA rule's RHS entry) for free. ``FusedAMSGrad``
exposes that direct interface; ``as_optimizer`` adapts it back to the
protocol (for drop-in tests), at the cost of one extra subtraction pass.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim.base import Optimizer


class FusedState(NamedTuple):
    """Persistent AMSGrad state — {h, v̂} only (the raw v is a temporary,
    see kernels/cada_update.py): 8P bytes instead of optax's 12P."""
    count: jnp.ndarray
    h: Any
    vhat: Any


class FusedAMSGrad(NamedTuple):
    lr: Any                 # float or step -> float schedule
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params) -> FusedState:
        # h and v̂ must be DISTINCT buffers: donated states with aliased
        # leaves trip XLA's donate-the-same-buffer-twice check
        def zeros():
            return jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return FusedState(count=jnp.zeros([], jnp.int32), h=zeros(),
                          vhat=zeros())

    def apply(self, params, state: FusedState, grads):
        """One fused step. Returns (params', state', ||Δθ||²)."""
        lr = self.lr(state.count) if callable(self.lr) else self.lr
        p, h, vhat, sq = kops.fused_cada_update(
            params, state.h, state.vhat, grads, lr,
            b1=self.b1, b2=self.b2, eps=self.eps)
        return p, FusedState(count=state.count + 1, h=h, vhat=vhat), sq

    # ---- flat-plane interface (core/flat.py hot paths)
    def init_flat(self, n_flat: int, dtype=jnp.float32) -> FusedState:
        """State over pre-flattened (n_flat,) buffers — no pytree
        bookkeeping, so the step needs no pack/unpack of the moments.
        ``dtype`` is the moment STORAGE dtype (bf16 halves the 8P-byte
        footprint; math stays fp32 — see kernels/cada_update.py).
        (h and v̂ are distinct buffers — donation-safe.)"""
        return FusedState(count=jnp.zeros([], jnp.int32),
                          h=jnp.zeros((n_flat,), dtype),
                          vhat=jnp.zeros((n_flat,), dtype))

    def apply_flat(self, theta, state: FusedState, grad, *, interpret=None,
                   shard=None):
        """One fused step over flat buffers: (theta', state', ||Δθ||²).

        ``interpret`` is the 3-way kernel-mode flag of kernels/ops.py
        (None = Pallas on TPU / fused flat jnp elsewhere); ``shard`` the
        static FlatSharding for the shard-local, psum-reduced form.
        """
        lr = self.lr(state.count) if callable(self.lr) else self.lr
        t, h, vhat, sq = kops.fused_amsgrad_flat(
            theta, state.h, state.vhat, grad, lr,
            b1=self.b1, b2=self.b2, eps=self.eps, interpret=interpret,
            shard=shard)
        return t, FusedState(count=state.count + 1, h=h, vhat=vhat), sq


def as_optimizer(fused: FusedAMSGrad) -> Optimizer:
    """Protocol adapter: updates = θ' − θ (one extra pass, tests only)."""

    def update(grads, state, params):
        p_new, new_state, _ = fused.apply(params, state, grads)
        updates = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            p_new, params)
        return updates, new_state

    return Optimizer(fused.init, update)
