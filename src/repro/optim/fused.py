"""Kernel-backed fused AMSGrad/CADA optimizer.

The optax-style ``Optimizer`` protocol returns *updates* so transforms can be
chained; the Pallas kernel instead applies the whole step in one HBM pass and
returns ||Δθ||² (the CADA rule's RHS entry) for free. ``FusedAMSGrad``
exposes that direct interface; ``as_optimizer`` adapts it back to the
protocol (for drop-in tests), at the cost of one extra subtraction pass.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim.base import Optimizer


class FusedState(NamedTuple):
    """Persistent AMSGrad state — {h, v̂} only (the raw v is a temporary,
    see kernels/cada_update.py): 8P bytes instead of optax's 12P."""
    count: jnp.ndarray
    h: Any
    vhat: Any


class FusedAMSGrad(NamedTuple):
    lr: Any                 # float or step -> float schedule
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params) -> FusedState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return FusedState(count=jnp.zeros([], jnp.int32), h=zeros,
                          vhat=zeros)

    def apply(self, params, state: FusedState, grads):
        """One fused step. Returns (params', state', ||Δθ||²)."""
        lr = self.lr(state.count) if callable(self.lr) else self.lr
        p, h, vhat, sq = kops.fused_cada_update(
            params, state.h, state.vhat, grads, lr,
            b1=self.b1, b2=self.b2, eps=self.eps)
        return p, FusedState(count=state.count + 1, h=h, vhat=vhat), sq


def as_optimizer(fused: FusedAMSGrad) -> Optimizer:
    """Protocol adapter: updates = θ' − θ (one extra pass, tests only)."""

    def update(grads, state, params):
        p_new, new_state, _ = fused.apply(params, state, grads)
        updates = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            p_new, params)
        return updates, new_state

    return Optimizer(fused.init, update)
