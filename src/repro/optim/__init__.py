from repro.optim.base import Optimizer, apply_updates, chain_weight_decay
from repro.optim.adam import AdamState, adam, amsgrad
from repro.optim.sgd import MomentumState, momentum, sgd
from repro.optim import schedules

__all__ = [
    "Optimizer", "apply_updates", "chain_weight_decay",
    "AdamState", "adam", "amsgrad",
    "MomentumState", "momentum", "sgd",
    "schedules",
]
