"""Minimal optax-style optimizer protocol (self-contained; optax not vendored).

An optimizer is a pair of pure functions:
  init(params) -> state
  update(grads, state, params) -> (updates, new_state)
and ``apply_updates(params, updates)`` adds the updates in.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def chain_weight_decay(opt: Optimizer, weight_decay: float) -> Optimizer:
    """Decoupled (AdamW-style) weight decay wrapped around any optimizer."""
    if weight_decay == 0.0:
        return opt

    def update(grads, state, params):
        updates, new_state = opt.update(grads, state, params)
        updates = jax.tree.map(
            lambda u, p: u - weight_decay * p, updates, params
        )
        return updates, new_state

    return Optimizer(opt.init, update)
