"""Discrete-event heterogeneous-cluster simulation: CADA in wall-clock.

See README.md in this directory for the event model, the staleness
semantics of the async mode, and the network-profile definitions.
"""
from repro.sim.clock import (ComputeModel, LinkModel, NetworkProfile,
                             PROFILES, network_profile)
from repro.sim.events import EventQueue, ParticipationModel
from repro.sim.report import summarize, time_to_target
from repro.sim.runtime import MODES, SimConfig, SimResult, SimRuntime, simulate

__all__ = [
    "ComputeModel", "LinkModel", "NetworkProfile", "PROFILES",
    "network_profile", "EventQueue", "ParticipationModel", "summarize",
    "time_to_target", "MODES", "SimConfig", "SimResult", "SimRuntime",
    "simulate",
]
