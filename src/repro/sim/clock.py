"""Wall-clock models of the heterogeneous-cluster simulation.

Two model families turn the engine's round-accounting into simulated
seconds:

  * :class:`ComputeModel` — per-worker gradient-evaluation times. Three
    kinds: ``deterministic`` (fixed per-worker mean), ``lognormal``
    (mean-preserving multiplicative jitter), ``trace`` (replay recorded
    per-eval durations). Rules that evaluate twice per iteration (CADA1's
    snapshot gradient, CADA2's stale-iterate gradient) are charged per
    ``strategy.grad_evals_per_iter`` — the runtime asks for ``n_evals``
    draws per iteration, so the second evaluation costs real simulated
    time, exactly as §2.2 counts it (discountable via
    ``second_eval_factor`` when the fused/grouped second-eval forms make
    it cheaper than a full extra pass).
  * :class:`LinkModel` — per-worker latency + bandwidth. Transfer time is
    ``latency + nbytes / bandwidth``; the byte counts come from each
    strategy's ``bytes_per_upload`` accounting, so quantized (laq/cinn)
    and sparse (topk ``--sparse-wire``) rules get *faster* uploads, not
    just cheaper-in-rounds ones.

Both models are deterministic given their seed: random draws are keyed on
``(seed, worker, local_iter)``, never on call order, so barrier and async
runtimes (which visit workers in different orders) see identical samples
and every simulation replays exactly.

Straggler injection lives here too: permanent per-worker slowdown factors
and transient windows ``(worker, t_start, t_end, factor)`` multiply the
compute draw for events that start inside the window.

:func:`network_profile` packages the named scenario presets the launcher
and benchmarks expose (``zero`` / ``lan`` / ``wan`` / ``hetero``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def _per_worker(value, m: int) -> np.ndarray:
    """Broadcast a scalar or length-M sequence to an (M,) float array."""
    arr = np.asarray(value, np.float64)
    if arr.ndim == 0:
        arr = np.full((m,), float(arr))
    if arr.shape != (m,):
        raise ValueError(f"expected scalar or shape ({m},), got {arr.shape}")
    return arr


@dataclass(frozen=True)
class ComputeModel:
    """Per-worker gradient-evaluation times (simulated seconds).

    ``eval_s`` is the mean seconds per single gradient evaluation (scalar
    or per-worker). ``kind``:

      * ``deterministic`` — every eval takes exactly its worker's mean;
      * ``lognormal`` — each eval draws ``eval_s · exp(N(−σ²/2, σ))``
        (mean-preserving, heavy right tail — the classic straggler shape);
      * ``trace`` — ``traces[m][j]`` is worker m's j-th eval duration,
        cycled when the trace is shorter than the run.

    ``second_eval_factor`` scales every evaluation after the first of an
    iteration (``eval_idx >= 1``). The default 1.0 is the paper's flat
    ``grad_evals_per_iter = 2`` charge for cada1/cada2; the optimized
    second-eval forms are cheaper than a full extra pass — the stacked
    ``fuse_evals`` eval shares dispatch/activation traffic with the fresh
    one, and the grouped ring eval fetches R ≪ M weight copies — so
    simulated wall-clock (``BENCH_sim.json``) can reflect the optimization
    (e.g. 0.5 ≈ "the second eval costs half a pass") instead of
    double-charging it.
    """
    m: int
    eval_s: tuple
    kind: str = "deterministic"
    sigma: float = 0.0
    traces: tuple = ()
    slowdown: tuple = ()            # per-worker permanent factors (M,)
    transient: tuple = ()           # (worker, t_start, t_end, factor) rows
    seed: int = 0
    second_eval_factor: float = 1.0

    @classmethod
    def make(cls, m: int, eval_s=1e-3, kind: str = "deterministic",
             sigma: float = 0.0, traces=None, slowdown=None,
             transient=(), seed: int = 0,
             second_eval_factor: float = 1.0) -> "ComputeModel":
        if kind not in ("deterministic", "lognormal", "trace"):
            raise ValueError(f"unknown compute kind {kind!r}")
        if kind == "trace" and not traces:
            raise ValueError("kind='trace' needs per-worker traces")
        return cls(
            m=m,
            eval_s=tuple(_per_worker(eval_s, m)),
            kind=kind,
            sigma=float(sigma),
            traces=tuple(tuple(float(t) for t in tr)
                         for tr in (traces or ())),
            slowdown=tuple(_per_worker(1.0 if slowdown is None else slowdown,
                                       m)),
            transient=tuple(tuple(row) for row in transient),
            seed=seed,
            second_eval_factor=float(second_eval_factor),
        )

    def _factor(self, worker: int, now: float) -> float:
        f = self.slowdown[worker]
        for w, t0, t1, fac in self.transient:
            if w == worker and t0 <= now < t1:
                f *= fac
        return f

    def eval_time(self, worker: int, local_iter: int, eval_idx: int,
                  now: float) -> float:
        """Seconds for ONE gradient evaluation (the ``eval_idx``-th of
        iteration ``local_iter``), starting at simulated time ``now``."""
        if self.kind == "trace":
            tr = self.traces[worker % len(self.traces)]
            base = tr[(local_iter + eval_idx) % len(tr)]
        else:
            base = self.eval_s[worker]
            if self.kind == "lognormal" and self.sigma > 0.0:
                rng = np.random.default_rng(
                    (self.seed, worker, local_iter, eval_idx))
                base *= math.exp(rng.normal(-0.5 * self.sigma ** 2,
                                            self.sigma))
        if eval_idx >= 1:
            base *= self.second_eval_factor
        return base * self._factor(worker, now)

    def iter_time(self, worker: int, local_iter: int, now: float,
                  n_evals: int) -> float:
        """Seconds of compute for one local iteration = ``n_evals``
        sequential gradient evaluations."""
        t = 0.0
        for e in range(n_evals):
            t += self.eval_time(worker, local_iter, e, now + t)
        return t

    def round_time(self, worker: int, first_iter: int, now: float,
                   h: int, n_evals: int) -> float:
        """Seconds of compute for one COMM ROUND of ``h`` sequential local
        iterations starting at local iteration index ``first_iter`` — the
        delta-payload rules' pricing unit (a worker runs h local optimizer
        steps between uploads). Each local iteration draws its own
        eval times at index ``first_iter + j`` (callers space rounds by
        the schedule's H cap so draws never collide across rounds), and
        transient slowdown windows apply at the accumulated clock.
        ``h=1`` is bitwise :meth:`iter_time` at ``first_iter``.
        """
        t = 0.0
        for j in range(h):
            t += self.iter_time(worker, first_iter + j, now + t, n_evals)
        return t


_BYTES_PER_MBIT = 1e6 / 8.0


@dataclass(frozen=True)
class LinkModel:
    """Per-worker link: transfer time = latency + bytes / bandwidth.

    ``bandwidth`` is bytes/second; ``math.inf`` (or 0 latency with inf
    bandwidth — the ``zero`` profile) makes transfers free. Uplink and
    downlink are symmetric unless ``down_bandwidth`` is given (WAN links
    are usually asymmetric; the broadcast direction is the fat one).

    ``trace`` makes the bandwidth TIME-VARYING: per-worker series of
    ``(t_seconds, up_mbit_s[, down_mbit_s])`` rows (Mbit/s, the unit
    network traces ship in; two-column rows mean a symmetric link).
    Between points the bandwidth is linearly interpolated; before the
    first and after the last point it HOLDS the edge value (``np.interp``
    semantics). When fewer traces than workers are given they cycle
    (``worker % len(trace)``), like :class:`ComputeModel` traces. A
    transfer is priced at the bandwidth in effect at its START time
    (``now``) — the piecewise-constant-per-transfer approximation; the
    event-driven runtimes pass their current simulated clock.
    """
    m: int
    latency_s: tuple
    bandwidth: tuple
    down_bandwidth: tuple
    trace: tuple = ()       # per-worker ((t,...), (up_Bps,...), (down_Bps,...))

    @classmethod
    def make(cls, m: int, latency_s=0.0, bandwidth=math.inf,
             down_bandwidth=None, trace=None) -> "LinkModel":
        return cls(
            m=m,
            latency_s=tuple(_per_worker(latency_s, m)),
            bandwidth=tuple(_per_worker(bandwidth, m)),
            down_bandwidth=tuple(_per_worker(
                bandwidth if down_bandwidth is None else down_bandwidth, m)),
            trace=tuple(cls._norm_trace(tr) for tr in (trace or ())),
        )

    @staticmethod
    def _norm_trace(tr):
        rows = np.asarray(tr, np.float64)
        if rows.ndim != 2 or rows.shape[1] not in (2, 3) or not rows.size:
            raise ValueError(
                "a bandwidth trace is (t_seconds, up_mbit_s[, down_mbit_s]) "
                f"rows, got shape {rows.shape}")
        if np.any(np.diff(rows[:, 0]) < 0):
            raise ValueError("bandwidth trace times must be non-decreasing")
        up = rows[:, 1] * _BYTES_PER_MBIT
        down = (rows[:, 2] * _BYTES_PER_MBIT if rows.shape[1] == 3 else up)
        if np.any(up <= 0) or np.any(down <= 0):
            raise ValueError("bandwidth trace rates must be positive")
        return (tuple(rows[:, 0]), tuple(up), tuple(down))

    def _bw(self, worker: int, now: float, down: bool) -> float:
        if self.trace:
            t, up, dn = self.trace[worker % len(self.trace)]
            return float(np.interp(now, t, dn if down else up))
        return (self.down_bandwidth if down else self.bandwidth)[worker]

    def _xfer(self, latency: float, bw: float, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return latency + (0.0 if math.isinf(bw) else nbytes / bw)

    def up_time(self, worker: int, nbytes: float,
                now: float = 0.0) -> float:
        return self._xfer(self.latency_s[worker],
                          self._bw(worker, now, down=False), nbytes)

    def down_time(self, worker: int, nbytes: float,
                  now: float = 0.0) -> float:
        return self._xfer(self.latency_s[worker],
                          self._bw(worker, now, down=True), nbytes)


@dataclass(frozen=True)
class NetworkProfile:
    """A named (compute, link) scenario the runtime simulates under."""
    name: str
    compute: ComputeModel
    link: LinkModel


PROFILES = ("zero", "lan", "wan", "hetero")


def network_profile(name: str, m: int, *, eval_s: float = 1e-3,
                    seed: int = 0, second_eval_factor: float = 1.0,
                    trace=None) -> NetworkProfile:
    """The scenario presets (`--network` on the launcher, swept by
    ``benchmarks.ablations.sweep_network``):

      * ``zero``   — zero latency, infinite bandwidth, homogeneous
        deterministic compute: wall-clock is compute only. This is the
        DEGENERATE config whose barrier-mode trajectories must reproduce
        the plain engine bit-exactly (the sim parity gate).
      * ``lan``    — 0.1 ms latency, 10 GB/s links, homogeneous compute:
        communication is nearly free, so per-iteration convergence wins.
      * ``wan``    — 20 ms latency, 1 Mbit/s up / 10 Mbit/s down (the
        constrained federated-uplink regime), homogeneous compute:
        uploads dominate and are BANDWIDTH-bound — skipping rounds and
        shrinking wires is where the communication-adaptive rules earn
        wall-clock.
      * ``hetero`` — heterogeneous cluster: per-worker compute means
        spread ×1..×3 with lognormal jitter (σ=0.3), the last worker a
        permanent ×4 straggler, per-worker bandwidth spread around LAN
        numbers. The straggler-tolerance scenario of Adaptive Worker
        Grouping (PAPERS.md).

    ``eval_s`` rescales the compute grain (a real LM step is not a logreg
    step); all link numbers are absolute. ``second_eval_factor`` is
    forwarded to :class:`ComputeModel` (see there — the fused/grouped
    second-eval discount). ``trace`` overlays TIME-VARYING bandwidth on
    any preset: per-worker ``(t_seconds, up_mbit_s[, down_mbit_s])`` row
    series (see :class:`LinkModel`) replace the preset's static rates
    while keeping its latency — e.g. ``wan`` latency with a measured
    diurnal uplink trace.
    """
    sef = second_eval_factor
    if trace is not None:
        prof = network_profile(name, m, eval_s=eval_s, seed=seed,
                               second_eval_factor=sef)
        link = LinkModel.make(m, latency_s=prof.link.latency_s,
                              trace=trace)
        return NetworkProfile(name=name, compute=prof.compute, link=link)
    if name == "zero":
        return NetworkProfile(
            name=name,
            compute=ComputeModel.make(m, eval_s=eval_s, seed=seed,
                                      second_eval_factor=sef),
            link=LinkModel.make(m, latency_s=0.0, bandwidth=math.inf),
        )
    if name == "lan":
        return NetworkProfile(
            name=name,
            compute=ComputeModel.make(m, eval_s=eval_s, seed=seed,
                                      second_eval_factor=sef),
            link=LinkModel.make(m, latency_s=1e-4, bandwidth=1e10),
        )
    if name == "wan":
        # federated-WAN numbers: 20 ms RTT-ish latency, 1 Mbit/s uplink
        # (the constrained direction), 10 Mbit/s downlink — uploads are
        # BANDWIDTH-dominated, so shrinking the wire (laq 8-bit, topk
        # sparse) buys wall-clock directly, on top of skipped rounds
        return NetworkProfile(
            name=name,
            compute=ComputeModel.make(m, eval_s=eval_s, seed=seed,
                                      second_eval_factor=sef),
            link=LinkModel.make(m, latency_s=2e-2, bandwidth=1.25e5,
                                down_bandwidth=1.25e6),
        )
    if name == "hetero":
        spread = np.linspace(1.0, 3.0, m)
        slowdown = np.ones(m)
        slowdown[-1] = 4.0
        bw = np.linspace(2e9, 5e8, m)
        return NetworkProfile(
            name=name,
            compute=ComputeModel.make(m, eval_s=spread * eval_s,
                                      kind="lognormal", sigma=0.3,
                                      slowdown=slowdown, seed=seed,
                                      second_eval_factor=sef),
            link=LinkModel.make(m, latency_s=1e-3, bandwidth=bw),
        )
    raise ValueError(f"unknown network profile {name!r}; "
                     f"known: {PROFILES}")
