"""Metrics over simulated runs: time-to-target-loss, bytes-on-wire,
worker utilization.

Everything here consumes a :class:`repro.sim.runtime.SimResult` and
returns plain floats/dicts (JSON-ready — ``benchmarks.run --only sim``
writes them to ``BENCH_sim.json`` verbatim).

``time_to_target`` is the wall-clock twin of
``benchmarks.common.uploads_to_target``: the first simulated second after
which the smoothed loss stays at/below the target for the REST of the run
(suffix-max over a sliding mean), so a transient dip cannot claim the
target. Async runs interleave per-worker losses on one clock; the sliding
window therefore spans at least one gate per worker before it trusts a
level.
"""
from __future__ import annotations

import numpy as np

from repro.sim.runtime import SimResult

TARGET_SLACK = 1.02   # smoothed loss must stay within 2% of the target


def smoothed_loss(result: SimResult, window: int = 0):
    """(times, smoothed) sliding-mean loss series in time order."""
    order = np.argsort(result.loss_times, kind="stable")
    t = np.asarray(result.loss_times)[order]
    x = np.asarray(result.losses)[order]
    if len(x) == 0:                  # zero-round run: nothing to smooth
        return t, x
    w = window or max(5, 2 * len(result.utilization))
    w = min(w, len(x)) or 1
    smooth = np.convolve(x, np.ones(w) / w, mode="valid")
    return t[w - 1:], smooth


def time_to_target(result: SimResult, target_loss: float,
                   window: int = 0) -> float | None:
    """First simulated second after which the smoothed loss stays ≤ the
    target (within :data:`TARGET_SLACK`) for the rest of the run, or None
    if the run never settles there."""
    t, smooth = smoothed_loss(result, window)
    if len(smooth) == 0:
        return None
    suffix_max = np.maximum.accumulate(smooth[::-1])[::-1]
    ok = suffix_max <= target_loss * TARGET_SLACK
    if not ok.any():
        return None
    return float(t[int(np.argmax(ok))])


def final_loss(result: SimResult, tail: int = 20) -> float | None:
    """Mean loss over the last ``tail`` observations (time-ordered);
    None for a zero-round run (NaN would poison the JSON sinks)."""
    order = np.argsort(result.loss_times, kind="stable")
    x = np.asarray(result.losses)[order]
    if len(x) == 0:
        return None
    return float(x[-min(tail, len(x)):].mean())


def summarize(result: SimResult, target_loss: float | None = None) -> dict:
    """JSON-ready summary row of one simulated run."""
    util = np.asarray(result.utilization)
    row = {
        "mode": result.mode,
        "profile": result.profile,
        "steps": int(result.steps),
        "sim_wall_s": round(result.wall_s, 6),
        "steps_per_sim_sec": (round(result.steps / result.wall_s, 3)
                              if result.wall_s > 0 else None),
        "final_loss": final_loss(result),
        "uploads": int(result.uploads),
        "grad_evals": int(result.grad_evals),
        "mbytes_up": round(result.bytes_up / 1e6, 6),
        "mbytes_down": round(result.bytes_down / 1e6, 6),
        "utilization_mean": round(float(util.mean()), 4),
        "utilization_min": round(float(util.min()), 4),
        "max_staleness": int(result.max_staleness),
    }
    if target_loss is not None:
        ttt = time_to_target(result, target_loss)
        row["target_loss"] = target_loss
        row["time_to_target_s"] = (round(ttt, 6) if ttt is not None
                                   else None)
    # obs ledger fields (additive — every pre-ledger key above is
    # byte-identical with or without them): the per-rule byte split,
    # staleness histogram and gate-margin quantiles record WHY a rule
    # won, not just when it hit target
    if result.ledger is not None:
        led = result.ledger
        row["wire_format"] = led["wire_format"]
        for wf in ("dense", "quantized", "sparse"):
            row[f"mbytes_up_{wf}"] = round(led[f"mbytes_up_{wf}"], 6)
        row["staleness_hist"] = led["staleness_hist"]
        if "gate_margin" in led:
            row["gate_margin"] = {k: round(v, 8)
                                  for k, v in led["gate_margin"].items()}
        for key in ("ring_occupancy", "ring_capacity", "pool_nbytes",
                    "pool_resident_nbytes", "pool_mapped_nbytes",
                    "async_pending_max"):
            if key in led:
                row[key] = led[key]
    return row
