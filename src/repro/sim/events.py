"""Discrete-event machinery: the queue, worker/server processes, and the
participation model.

The queue is a plain time-ordered heap with a deterministic FIFO tie-break
(events at equal times pop in push order), so simulations replay exactly.
The numeric state (flat planes, strategies, the fused server optimizer)
lives in :mod:`repro.sim.runtime`; this module owns only the *schedule*:

  * :class:`EventQueue` / :class:`Event` — the heap;
  * :class:`WorkerProc` — one async worker's timing state machine
    (``DOWNLOAD → COMPUTE → GATE → [UPLOAD]`` and back), tracking the
    utilization bookkeeping (busy compute seconds, bytes moved, local
    iteration count, last-upload server version);
  * :class:`ParticipationModel` — per-round worker sampling for barrier
    mode (⌈frac·M⌉ workers drawn without replacement, seeded per round).

Straggler *injection* is a compute-model concern (permanent and transient
slowdowns live on :class:`repro.sim.clock.ComputeModel`); the processes
here simply experience the slowed draws.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

# async event kinds, in the order one worker cycles through them
DOWNLOAD_DONE = "download_done"   # worker received θ (and shared state)
COMPUTE_DONE = "compute_done"     # fresh (+ second) gradients ready → gate
UPLOAD_ARRIVE = "upload_arrive"   # wire reached the server → fused update


@dataclass(order=True)
class Event:
    time: float
    seq: int                      # FIFO tie-break at equal times
    kind: str = field(compare=False)
    worker: int = field(compare=False, default=-1)
    payload: dict = field(compare=False, default_factory=dict)


class EventQueue:
    """Time-ordered heap of :class:`Event` with deterministic ties."""

    def __init__(self):
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: str, worker: int = -1,
             **payload) -> Event:
        ev = Event(time=float(time), seq=next(self._seq), kind=kind,
                   worker=worker, payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


@dataclass
class WorkerProc:
    """Timing state of one async worker (the numeric row state stays with
    the runtime). ``since_upload`` is the worker's local iterations since
    it last uploaded — the sync rule's staleness counter lifted to the
    async loop (the version lag ``k_srv − upload_version`` is tracked
    separately; the τ_max cap fires on whichever is larger)."""
    worker: int
    local_iter: int = 0
    upload_version: int = 0       # server version at the last upload
    since_upload: int = 0         # local iterations since the last upload
    busy_s: float = 0.0           # compute seconds (utilization numerator)
    bytes_up: float = 0.0
    bytes_down: float = 0.0
    uploads: int = 0
    max_staleness: int = 0

    def staleness(self, k_srv: int) -> int:
        """Effective staleness: local rounds since upload, or server
        versions since upload — whichever is larger."""
        return max(self.since_upload, k_srv - self.upload_version)


class ParticipationModel:
    """Barrier-mode partial participation: each round, ⌈frac·M⌉ workers
    are drawn without replacement (at least one). Draws are keyed on
    ``(seed, round)``, so the schedule is independent of anything the
    trajectory does."""

    def __init__(self, m: int, frac: float = 1.0, seed: int = 0):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"participation frac must be in (0, 1], "
                             f"got {frac}")
        self.m = m
        self.frac = float(frac)
        self.seed = seed
        self.k_active = max(1, int(np.ceil(frac * m)))

    @property
    def full(self) -> bool:
        return self.k_active == self.m

    def mask(self, round_idx: int) -> np.ndarray:
        """(M,) bool participation mask for one round."""
        if self.full:
            return np.ones((self.m,), bool)
        rng = np.random.default_rng((self.seed, round_idx))
        mask = np.zeros((self.m,), bool)
        mask[rng.choice(self.m, self.k_active, replace=False)] = True
        return mask

    def masks(self, steps: int) -> np.ndarray:
        """(steps, M) bool matrix of per-round masks."""
        return np.stack([self.mask(k) for k in range(steps)])
