"""Event-driven heterogeneous-cluster runtimes: Algorithm 1 in wall-clock.

Two runtimes lift the engine's lock-step rounds onto simulated time:

  * **barrier** (synchronous): the numerics are EXACTLY the engine's own
    scan — ``CADAEngine.run`` with an optional per-round participation
    mask — and the discrete-event layer prices each round afterwards:
    every participating worker downloads θ^k, computes its
    ``grad_evals_per_iter`` gradient evaluations, uploads if its rule
    fired, and the server closes the round when the LAST participant
    finishes (stragglers stall everyone — the cost the async mode
    removes). Under the ``zero`` profile with full participation the
    trajectory is bit-for-bit the plain engine's (the parity gate pins
    masks/staleness exact and params equal for every registered rule).

  * **async** (bounded staleness): workers free-run — download θ, compute,
    gate with the UNMODIFIED :mod:`repro.core.comm` strategy hooks against
    their stale row of the (M, n_flat) plane, and upload when the rule
    fires or their staleness reaches τ_max. The server applies the fused
    flat-plane Adam update (``FusedAMSGrad.apply_flat``) the moment each
    upload arrives — no barrier, so one straggler no longer prices every
    round. Staleness is the max of the worker's local iterations since its
    last upload (the sync counter) and the server versions since that
    upload; τ_max defaults to the rule's ``max_delay``.

The link models price bytes via each strategy's ``bytes_per_upload``, so
compressed wires (laq 8-bit, topk sparse) are *faster*, not just cheaper
in rounds; the downlink broadcast of θ is charged dense (``4n`` bytes by
default) every download. Transfers are priced at the bandwidth in effect
at their start time (``now=`` on the link calls), so trace-driven
time-varying links (``LinkModel.trace``) shape both runtimes.

Federated scale rides the cohort-virtualized worker plane:
``cohort_size > 0`` (barrier) samples C workers per round through the
host :class:`repro.core.flat.WorkerPool` — device worker-plane state is
O(C·n), so M = 10⁴ workers runs where the dense (M, n_flat) plane cannot
— and ``host_pool=True`` (async) streams single worker rows from the
same pool instead of holding the (M, n_flat) plane on device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flat as F
from repro.core.comm import STRATEGIES, adapt_period
from repro.core.engine import CADAEngine, sample_cohorts
from repro.core.rules import CommRule
from repro.obs.metrics import CommLedger
from repro.obs.trace import as_tracer
from repro.optim.fused import FusedAMSGrad
from repro.sim.clock import NetworkProfile, network_profile
from repro.sim.events import (COMPUTE_DONE, DOWNLOAD_DONE, UPLOAD_ARRIVE,
                              EventQueue, ParticipationModel, WorkerProc)

MODES = ("barrier", "async")

# async host_pool: most deferred-writeback rows parked on device at once.
# Past the cap the OLDEST parked row is flushed, so the async device
# overhead is a constant number of (P, 1, n_flat) rows however large M
# gets — never the O(M·n) plane the pool exists to avoid.
ASYNC_PENDING_CAP = 4


@dataclass(frozen=True)
class SimConfig:
    """What to simulate: the network scenario and the runtime mode."""
    network: NetworkProfile
    mode: str = "barrier"
    async_tau: int = 0            # staleness cap τ_max (0 → rule.max_delay)
    participation: float = 1.0    # barrier mode: fraction of workers/round
    server_update_s: float = 0.0  # simulated cost of the fused Adam step
    download_bytes: float | None = None   # None → dense fp32 θ (4·n bytes)
    async_lr_scale: float | None = None   # None → 1/M: the Adam step fires
    #                               per ARRIVAL, so M arrivals ≈ one sync
    #                               round — unscaled, async runs at an
    #                               effective M× learning rate (Adam steps
    #                               are ~lr-sized whatever ∇'s magnitude)
    #                               and visibly oscillates
    cohort_size: int = 0          # barrier mode: > 0 runs the FEDERATED
    #                               cohort plane — C sampled workers per
    #                               round through the host WorkerPool,
    #                               O(C·n) device state, rounds priced
    #                               over cohort members only
    host_pool: bool = False       # async mode: per-worker rows (grads +
    #                               pooled extras) live in a numpy
    #                               WorkerPool instead of an (M, n_flat)
    #                               device plane
    pipeline: bool = True         # cohort rounds: double-buffered
    #                               transfer pipeline (False = the serial
    #                               parity oracle)
    metrics_every: int = 8        # cohort rounds: fetch device metrics
    #                               every K rounds instead of per round
    pool_storage: str = "ram"     # "memmap" spills the WorkerPool's
    #                               O(M·n) planes to files under
    #                               pool_path (M beyond RAM)
    pool_path: str | None = None
    seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, "
                             f"got {self.mode!r}")
        if self.async_tau < 0:
            raise ValueError("async_tau must be >= 0")
        if self.cohort_size < 0:
            raise ValueError("cohort_size must be >= 0")
        if self.cohort_size and self.mode != "barrier":
            raise ValueError("cohort_size is a barrier-mode knob (async "
                             "workers free-run; use host_pool to bound "
                             "async device state instead)")
        if self.host_pool and self.mode != "async":
            raise ValueError("host_pool is an async-mode knob (barrier "
                             "federated runs get the pool via cohort_size)")
        if self.cohort_size and self.participation != 1.0:
            raise ValueError("cohort_size and participation are two ways "
                             "to sample the same thing — set one")
        if self.mode == "async" and self.participation != 1.0:
            raise ValueError(
                "participation sampling is a barrier-mode knob (async "
                "workers free-run; model slow/absent workers with the "
                "ComputeModel's straggler injection instead)")
        if self.metrics_every < 1:
            raise ValueError("metrics_every must be >= 1")
        if self.pool_storage not in ("ram", "memmap"):
            raise ValueError('pool_storage must be "ram" or "memmap", '
                             f"got {self.pool_storage!r}")
        if self.pool_storage == "memmap" and self.pool_path is None:
            raise ValueError('pool_storage="memmap" needs pool_path=')
        if self.pool_storage == "memmap" and not (self.cohort_size
                                                  or self.host_pool):
            raise ValueError("pool_storage is a WorkerPool knob — set "
                             "cohort_size (barrier) or host_pool (async)")


@dataclass
class SimResult:
    """One simulated run. ``loss_times``/``losses`` are the wall-clock loss
    series (barrier: per round; async: per worker gate); ``times`` are the
    server-update completion times."""
    mode: str
    profile: str
    steps: int                     # server updates completed
    wall_s: float
    times: np.ndarray              # (steps,) server-update times
    loss_times: np.ndarray
    losses: np.ndarray
    uploads: int
    grad_evals: int
    bytes_up: float
    bytes_down: float
    utilization: np.ndarray        # (M,) compute-busy fraction of wall
    max_staleness: int
    final_params: Any
    upload_masks: np.ndarray | None = None    # barrier: (steps, M)
    staleness: np.ndarray | None = None       # barrier: (steps, M)
    participation_masks: np.ndarray | None = None  # barrier: (steps, M)
    metrics: dict = field(default_factory=dict)  # barrier: raw engine mets
    ledger: dict | None = None     # obs.metrics.CommLedger.summary()


class SimRuntime:
    """Simulate Algorithm 1 under a :class:`SimConfig`.

    The engine's numerics are reused wholesale: barrier mode IS
    ``CADAEngine.run`` (plus participation); async mode drives the same
    strategy flat hooks one worker row at a time and the same fused Adam
    kernel server-side.
    """

    def __init__(self, loss_fn, rule: CommRule, n_workers: int,
                 config: SimConfig, *, lr: float = 0.01, optimizer=None,
                 interpret=None, trace=None):
        self.cfg = config
        self.m = n_workers
        self.rule = rule
        # obs.trace.Tracer or None: every simulated download/compute/
        # upload/gate/server-apply becomes a span on the SIMULATED clock,
        # one track per worker plus a "server" track
        self.tracer = as_tracer(trace)
        if STRATEGIES[rule.kind].delta_payload:
            # delta-payload rules PRESCRIBE their server optimizer
            # (engine resolves strategy.server_optimizer() on None) —
            # the sim's FusedAMSGrad default would silently override it
            if config.mode == "async":
                raise ValueError(
                    "async mode gates one fresh gradient per local "
                    "iteration; delta-payload rules (local_momentum / "
                    "fedadam — local steps between uploads) are "
                    "barrier-only")
            if rule.adapt_local_steps and config.cohort_size:
                raise ValueError(
                    "adapt_local_steps is not supported on the cohort "
                    "plane yet — run adaptive H dense, or fixed "
                    "local_steps cohort-virtualized")
        elif optimizer is None:
            optimizer = FusedAMSGrad(lr=lr)
        # the sim IS the clock adapt_local_steps requires: allow it here
        # (the bare-engine constructor rejects it)
        self.engine = CADAEngine(
            loss_fn, optimizer, rule, n_workers, interpret=interpret,
            allow_adaptive_local_steps=True)
        if config.mode == "async" and not self.engine._fused_opt:
            raise ValueError("async mode applies the fused flat-plane Adam "
                             "update server-side; pass a FusedAMSGrad")

    # ------------------------------------------------------------- shared
    def _byte_costs(self, n: int) -> tuple[float, float]:
        up = self.engine.strategy.bytes_per_upload(n)
        down = (4.0 * n if self.cfg.download_bytes is None
                else float(self.cfg.download_bytes))
        return up, down

    def _new_ledger(self) -> CommLedger:
        return CommLedger.for_strategy(self.engine.strategy)

    def _observe_ring(self, led: CommLedger, extras: dict) -> None:
        """Fold stale-ring occupancy (cada2's slot map) into the ledger."""
        if "slot" in extras and "ring_version" in extras:
            led.observe_ring(np.asarray(extras["slot"]),
                             capacity=int(np.asarray(
                                 extras["ring_version"]).shape[0]))

    def run(self, params, batches, rounds: int | None = None) -> SimResult:
        """Simulate over pre-sampled batches with leading axis
        (steps, M, ...). Barrier mode runs exactly ``steps`` rounds; async
        mode runs until the server has applied ``steps`` updates (batches
        are cycled per worker as needed).

        Federated cohort mode (``cohort_size > 0``) additionally accepts a
        CALLABLE ``batches``: ``batches(round_idx, cohort) -> (C, b, ...)``
        leaves — at M = 10⁴ a dense (steps, M, b, ·) batch plane is the
        memory wall, so the sampler materializes one cohort's rows at a
        time. ``rounds`` is required with a callable (arrays carry their
        own step count)."""
        if self.cfg.mode == "barrier":
            if self.cfg.cohort_size:
                return self._run_barrier_cohort(params, batches, rounds)
            return self._run_barrier(params, batches)
        return self._run_async(params, batches)

    # ------------------------------------------------------------ barrier
    def _run_barrier(self, params, batches) -> SimResult:
        eng, cfg = self.engine, self.cfg
        if eng.strategy.delta_payload:
            return self._run_barrier_delta(params, batches)
        compute, link = cfg.network.compute, cfg.network.link
        steps = jax.tree.leaves(batches)[0].shape[0]
        part = ParticipationModel(self.m, cfg.participation, cfg.seed)

        st = eng.init(params)
        if part.full:
            # no participation arg at all: the compiled graph is byte-for-
            # byte the plain engine's — the degenerate-parity anchor
            pmasks = np.ones((steps, self.m), bool)
            fst, mets = jax.jit(eng.run)(st, batches)
        else:
            pmasks = part.masks(steps)
            fst, mets = jax.jit(eng.run)(st, batches, jnp.asarray(pmasks))

        masks = np.asarray(mets["upload_mask"])          # (steps, M)
        staleness = np.asarray(mets["staleness"])
        losses = np.asarray(mets["loss"], np.float64)
        n = eng._layout.n if eng.fused else sum(
            x.size for x in jax.tree.leaves(params))
        up_bytes, down_bytes = self._byte_costs(n)
        evals = eng.strategy.grad_evals_per_iter

        tr = self.tracer
        t = 0.0
        t_end = np.zeros(steps)
        busy = np.zeros(self.m)
        bytes_up = bytes_down = 0.0
        for k in range(steps):
            finish = t
            for w in range(self.m):
                if not pmasks[k, w]:
                    continue
                dt_down = link.down_time(w, down_bytes, now=t)
                dt_comp = compute.iter_time(w, k, t + dt_down, evals)
                dt_up = (link.up_time(w, up_bytes,
                                      now=t + dt_down + dt_comp)
                         if masks[k, w] else 0.0)
                busy[w] += dt_comp
                bytes_down += down_bytes
                if masks[k, w]:
                    bytes_up += up_bytes
                if tr:
                    trk = f"worker {w}"
                    tr.add_span("download", t, dt_down, track=trk,
                                cat="transfer")
                    tr.add_span("compute", t + dt_down, dt_comp,
                                track=trk, cat="compute")
                    tr.instant("gate", t + dt_down + dt_comp, track=trk,
                               args={"round": k,
                                     "upload": bool(masks[k, w]),
                                     "staleness": int(staleness[k, w])})
                    if masks[k, w]:
                        tr.add_span("upload", t + dt_down + dt_comp,
                                    dt_up, track=trk, cat="transfer")
                finish = max(finish, t + dt_down + dt_comp + dt_up)
            if tr:
                tr.add_span("round", t, finish + cfg.server_update_s - t,
                            track="server",
                            args={"round": k,
                                  "uploads": int(masks[k].sum())})
            t = finish + cfg.server_update_s
            t_end[k] = t

        led = self._new_ledger()
        led.observe_run(mets, participation=pmasks)
        led.add_bytes_down(bytes_down)
        self._observe_ring(led, fst.comm.extras)
        wall = float(t)
        return SimResult(
            mode="barrier", profile=cfg.network.name, steps=steps,
            wall_s=wall, times=t_end, loss_times=t_end, losses=losses,
            uploads=int(masks.sum()),
            grad_evals=int(np.asarray(mets["grad_evals"]).sum()),
            bytes_up=bytes_up, bytes_down=bytes_down,
            utilization=busy / wall if wall > 0 else np.zeros(self.m),
            max_staleness=int(staleness.max()),
            final_params=fst.params,
            upload_masks=masks, staleness=staleness,
            participation_masks=pmasks, metrics=mets,
            ledger=led.summary())

    # ------------------------------------------ barrier, delta payloads
    def _run_barrier_delta(self, params, batches) -> SimResult:
        """Barrier rounds for delta-payload (local-steps) rules.

        Batches carry a local axis: (rounds, H, M, b...) — or the plain
        (rounds, M, b...) form at the H = 1 degenerate point. Delta rules
        always upload, so the wall-clock schedule is TRAJECTORY-
        INDEPENDENT: the per-round per-worker local-step counts H_m and
        all link/compute times are computed host-side in one pass BEFORE
        the numeric run, then (for adaptive H) handed to the engine as a
        (rounds, M) int32 schedule that masks each worker's scan to its
        first H_m local steps.

        Adaptation generalizes avp's period rule from "skip uploads" to
        "take local steps": a worker whose observed comm time (download +
        upload) exceeded its compute time for the round grows H by one,
        else shrinks — clipped to [local_steps_min, min(local_steps_max,
        batch H capacity)] via :func:`repro.core.comm.adapt_period`.
        Offline rounds freeze a worker's H. Pricing charges
        ``compute.round_time(w, k * h_pad, ·, H_m, evals)`` — H_m
        successive local-iteration draws per round, rounds spaced by the
        batch's H capacity so draws never collide across rounds."""
        eng, cfg, rule = self.engine, self.cfg, self.rule
        compute, link = cfg.network.compute, cfg.network.link
        leaves = jax.tree.leaves(batches)[0]
        has_h = rule.local_steps > 1 or rule.adapt_local_steps
        steps = leaves.shape[0]
        h_pad = leaves.shape[1] if has_h else 1
        adaptive = rule.adapt_local_steps
        h_min = rule.local_steps_min
        h_cap = (min(rule.resolved_local_steps_max, h_pad) if adaptive
                 else min(rule.local_steps, h_pad))
        if adaptive and h_pad < h_min:
            raise ValueError(
                f"adaptive local steps need batches with at least "
                f"local_steps_min={h_min} local iterations per round; "
                f"got H axis {h_pad}")
        part = ParticipationModel(self.m, cfg.participation, cfg.seed)
        pmasks = (np.ones((steps, self.m), bool) if part.full
                  else part.masks(steps))

        st = eng.init(params)
        n = eng._layout.n if eng.fused else sum(
            x.size for x in jax.tree.leaves(params))
        up_bytes, down_bytes = self._byte_costs(n)
        evals = eng.strategy.grad_evals_per_iter

        tr = self.tracer
        h = np.full(self.m, min(max(rule.local_steps, h_min), h_cap)
                    if adaptive else h_cap, np.int64)
        hsched = np.zeros((steps, self.m), np.int64)
        t = 0.0
        t_end = np.zeros(steps)
        busy = np.zeros(self.m)
        bytes_up = bytes_down = 0.0
        comm_s = np.zeros(self.m)
        comp_s = np.zeros(self.m)
        for k in range(steps):
            hsched[k] = h
            finish = t
            for w in range(self.m):
                if not pmasks[k, w]:
                    continue
                dt_down = link.down_time(w, down_bytes, now=t)
                dt_comp = compute.round_time(w, k * h_pad, t + dt_down,
                                             int(h[w]), evals)
                dt_up = link.up_time(w, up_bytes,
                                     now=t + dt_down + dt_comp)
                busy[w] += dt_comp
                bytes_down += down_bytes
                bytes_up += up_bytes
                comm_s[w] = dt_down + dt_up
                comp_s[w] = dt_comp
                if tr:
                    trk = f"worker {w}"
                    tr.add_span("download", t, dt_down, track=trk,
                                cat="transfer")
                    tr.add_span("compute", t + dt_down, dt_comp,
                                track=trk, cat="compute",
                                args={"round": k, "local_steps": int(h[w])})
                    tr.add_span("upload", t + dt_down + dt_comp, dt_up,
                                track=trk, cat="transfer")
                finish = max(finish, t + dt_down + dt_comp + dt_up)
            if tr:
                tr.add_span("round", t, finish + cfg.server_update_s - t,
                            track="server",
                            args={"round": k,
                                  "uploads": int(pmasks[k].sum())})
            if adaptive:
                h = np.where(
                    pmasks[k],
                    np.asarray(adapt_period(h, comm_s > comp_s,
                                            h_min, h_cap)),
                    h)
            t = finish + cfg.server_update_s
            t_end[k] = t

        part_arg = None if part.full else jnp.asarray(pmasks)
        hs_arg = jnp.asarray(hsched, jnp.int32) if adaptive else None
        fst, mets = jax.jit(eng.run)(st, batches, part_arg, hs_arg)

        masks = np.asarray(mets["upload_mask"])          # (steps, M)
        staleness = np.asarray(mets["staleness"])
        losses = np.asarray(mets["loss"], np.float64)
        led = self._new_ledger()
        led.observe_run(mets, participation=pmasks)
        led.add_bytes_down(bytes_down)
        self._observe_ring(led, fst.comm.extras)
        wall = float(t)
        return SimResult(
            mode="barrier", profile=cfg.network.name, steps=steps,
            wall_s=wall, times=t_end, loss_times=t_end, losses=losses,
            uploads=int(masks.sum()),
            grad_evals=int(np.asarray(mets["grad_evals"]).sum()),
            bytes_up=bytes_up, bytes_down=bytes_down,
            utilization=busy / wall if wall > 0 else np.zeros(self.m),
            max_staleness=int(staleness.max()),
            final_params=fst.params,
            upload_masks=masks, staleness=staleness,
            participation_masks=pmasks,
            metrics={**mets, "local_steps": hsched},
            ledger=led.summary())

    # -------------------------------------------- barrier, federated cohort
    def _run_barrier_cohort(self, params, batches,
                            rounds: int | None = None) -> SimResult:
        """Federated barrier rounds on the cohort-virtualized plane.

        Per round a fresh C-worker cohort (seeded like
        :class:`ParticipationModel`: independent per-round draws) is
        gathered from the host :class:`repro.core.flat.WorkerPool`, runs
        one :func:`repro.core.flat.flat_cohort_round`, and scatters back —
        device worker-plane state is O(C·n) whatever M is. The round is
        priced over COHORT MEMBERS ONLY (non-sampled workers are idle:
        no download, no compute, no upload), so wall-clock reflects the
        federated cross-device regime rather than the all-M cluster.
        Numerically each round is bit-exact to the dense plane run with
        the cohort's indicator mask as participation (the
        tests/test_cohort_plane.py parity gate).

        The numerics run FIRST, through the engine's pipelined cohort
        driver (``cfg.pipeline`` / ``cfg.metrics_every`` — transfers
        overlap device compute, metrics fetch every K rounds); the
        wall-clock pricing loop then replays the returned host metrics.
        Pricing never feeds back into the numerics, so the split is
        exact."""
        eng, cfg = self.engine, self.cfg
        compute, link = cfg.network.compute, cfg.network.link
        c = cfg.cohort_size
        if c > self.m:
            raise ValueError(f"cohort_size {c} > n_workers {self.m}")
        if callable(batches):
            if not rounds:
                raise ValueError("a callable batch sampler needs rounds=")
            steps = int(rounds)
        else:
            steps = jax.tree.leaves(batches)[0].shape[0]
        cohorts = sample_cohorts(self.m, c, steps, seed=cfg.seed)

        st, pool = eng.init_cohort(params, pool_storage=cfg.pool_storage,
                                   pool_path=cfg.pool_path)
        n = eng._layout.n
        up_bytes, down_bytes = self._byte_costs(n)
        evals = eng.strategy.grad_evals_per_iter
        # delta-payload rules run a fixed H local steps per round on the
        # cohort plane; grad rules price exactly one iteration (h = 1
        # collapses round_time to the pre-local-steps iter_time bitwise)
        h_static = (self.rule.local_steps if eng.strategy.delta_payload
                    else 1)
        has_h = eng.strategy.delta_payload and self.rule.local_steps > 1

        def batch_fn(k, cohort):
            if callable(batches):
                return batches(k, cohort)
            return jax.tree.map(
                (lambda x: x[k][:, cohort]) if has_h
                else (lambda x: x[k][cohort]), batches)

        # numerics first, through the pipelined driver
        st, all_mets = eng.run_cohort(st, pool, batch_fn, cohorts,
                                      pipeline=cfg.pipeline,
                                      metrics_every=cfg.metrics_every)

        # wall-clock pricing replays the host metrics
        tr = self.tracer
        led = self._new_ledger()
        t = 0.0
        t_end = np.zeros(steps)
        busy = np.zeros(self.m)
        bytes_up = bytes_down = 0.0
        masks = np.zeros((steps, c), bool)
        stal = np.zeros((steps, c), np.int64)
        losses = np.zeros(steps, np.float64)
        grad_evals = 0
        max_stale = 0
        for k in range(steps):
            cohort = cohorts[k]
            mets = all_mets[k]
            masks[k] = np.asarray(mets["upload_mask"])
            stal[k] = np.asarray(mets["staleness"])
            losses[k] = float(mets["loss"])
            grad_evals += int(mets["grad_evals"])
            max_stale = max(max_stale, int(mets["max_staleness"]))
            led.observe_round(mets)
            finish = t
            for j, w in enumerate(int(x) for x in cohort):
                dt_down = link.down_time(w, down_bytes, now=t)
                dt_comp = compute.round_time(w, k * h_static, t + dt_down,
                                             h_static, evals)
                dt_up = (link.up_time(w, up_bytes,
                                      now=t + dt_down + dt_comp)
                         if masks[k, j] else 0.0)
                busy[w] += dt_comp
                bytes_down += down_bytes
                if masks[k, j]:
                    bytes_up += up_bytes
                if tr:
                    trk = f"worker {w}"
                    tr.add_span("download", t, dt_down, track=trk,
                                cat="transfer")
                    tr.add_span("compute", t + dt_down, dt_comp,
                                track=trk, cat="compute",
                                args={"round": k})
                    tr.instant("gate", t + dt_down + dt_comp, track=trk,
                               args={"round": k,
                                     "upload": bool(masks[k, j]),
                                     "staleness": int(stal[k, j])})
                    if masks[k, j]:
                        tr.add_span("upload", t + dt_down + dt_comp,
                                    dt_up, track=trk, cat="transfer")
                finish = max(finish, t + dt_down + dt_comp + dt_up)
            if tr:
                tr.add_span("round", t, finish + cfg.server_update_s - t,
                            track="server",
                            args={"round": k, "cohort_size": c,
                                  "uploads": int(masks[k].sum())})
            t = finish + cfg.server_update_s
            t_end[k] = t

        led.add_bytes_down(bytes_down)
        led.observe_pool(pool)
        self._observe_ring(led, st.server.extras)
        wall = float(t)
        return SimResult(
            mode="barrier", profile=cfg.network.name, steps=steps,
            wall_s=wall, times=t_end, loss_times=t_end, losses=losses,
            uploads=int(masks.sum()), grad_evals=grad_evals,
            bytes_up=bytes_up, bytes_down=bytes_down,
            utilization=busy / wall if wall > 0 else np.zeros(self.m),
            max_staleness=max_stale,
            final_params=st.params,
            upload_masks=masks, staleness=stal,
            metrics={"cohorts": cohorts,
                     "host_pool_bytes": pool.nbytes,
                     "host_pool_mapped_bytes": pool.mapped_nbytes,
                     "host_pool_resident_bytes": pool.resident_nbytes,
                     "pipeline": cfg.pipeline,
                     "device_worker_plane_bytes": pool.device_row_bytes(c)},
            ledger=led.summary())

    # -------------------------------------------------------------- async
    def _slice_extras(self, extras: dict, w: int, stale_point=None) -> dict:
        """Worker w's one-row view of the flat extras.

        Three families: ``async_shared_extras`` pass through whole (CADA1's
        snapshot), ``async_indexed_extras`` (the stale-iterate RING) are
        REPLACED by a synthetic one-row ring built from ``stale_point`` —
        the worker's own θ^{k−τ_m}, tracked host-side by ``_run_async``
        (the bounded-slot server ring assumes the sync schedule and cannot
        represent per-worker async staleness) — and everything else is
        sliced on its leading (M,) axis.
        """
        strat = self.engine.strategy
        shared, indexed = strat.async_shared_extras, strat.async_indexed_extras
        row = {key: (val if key in shared
                     else jax.tree.map(lambda x: x[w:w + 1], val))
               for key, val in extras.items() if key not in indexed}
        if indexed:
            row.update(strat.async_indexed_row(stale_point))
        return row

    def _merge_extras(self, extras: dict, row: dict, w: int) -> dict:
        """Write worker w's gate-updated extras row back. Shared extras
        pass through; INDEXED (ring) keys are skipped — the server-side
        ring is dead state in async mode (each gate sees a fresh synthetic
        row; the real stale points live in ``_run_async``'s host list)."""
        strat = self.engine.strategy
        shared, indexed = strat.async_shared_extras, strat.async_indexed_extras
        return {key: (val if key in shared or key in indexed
                      else jax.tree.map(
                          lambda full, r: full.at[w].set(r[0]), val,
                          row[key]))
                for key, val in extras.items()}

    def _build_gate(self, tau: int):
        """Jitted per-worker gate: fresh (+second) gradient evaluation, the
        strategy's LHS vs the server RHS, wire formation and the worker-row
        state transition — :func:`repro.core.flat.flat_comm_round`'s lines
        7-14 on a single (1, n_flat) row."""
        eng = self.engine
        strategy, layout, rule = eng.strategy, eng._layout, self.rule

        def gate(wparams, wflat, batch1, wg_row, stale1, diff_hist,
                 extras_row):
            # the shared eval dispatch (ring-indexed / shared / legacy
            # dense); on the gate's one-row view the ring gather degrades
            # to exactly the old dense per-worker evaluation, so async
            # numerics are untouched by the ring.
            losses, fresh, second = F.eval_two_point(
                strategy, layout, extras_row, wparams, batch1, 1,
                vgrad=eng._vgrad, vgrad_per=eng._vgrad_per,
                fuse_evals=False, group_evals=False)
            comm_row = F.FlatCommState(
                nabla=jnp.zeros_like(wg_row[0]), worker_grads=wg_row,
                staleness=stale1, diff_hist=diff_hist, extras=extras_row)
            ctx = F.FlatCommContext(
                layout=layout, params=wparams, params_flat=wflat,
                batch=batch1, fresh=fresh, second=second, comm=comm_row,
                step=jnp.zeros([], jnp.int32), m=1,
                interpret=eng._interpret, shard=None)
            lhs, cache = strategy.flat_lhs(ctx, extras_row)
            rhs = rule.rhs(diff_hist)
            upload = (lhs > rhs) | (stale1 >= tau)
            wg32 = wg_row.astype(jnp.float32)
            delta = strategy.flat_wire_delta(ctx, extras_row, cache,
                                             fresh - wg32)
            wire = jnp.where(upload[:, None], delta, 0.0).astype(
                wg_row.dtype)
            new_wg = (wg32 + wire.astype(jnp.float32)).astype(wg_row.dtype)
            new_extras = strategy.flat_post_upload(extras_row, cache,
                                                   upload, ctx)
            # lhs/rhs ride out for the obs ledger's gate-margin split
            return (losses[0], upload[0], wire[0], new_wg[0], new_extras,
                    lhs[0], rhs)

        return jax.jit(gate)

    def _build_apply(self):
        """Jitted server transition on upload arrival: eq. (3)'s ∇ refine
        with ONE worker's wire, the fused Adam step, the RHS ring push, and
        the strategy's shared pre-step (CADA1's snapshot refresh cadence is
        the server version counter)."""
        eng, cfg = self.engine, self.cfg
        strategy, layout = eng.strategy, eng._layout
        m, d_max = self.m, self.rule.d_max
        scale = (1.0 / m if cfg.async_lr_scale is None
                 else cfg.async_lr_scale)
        lr = eng.optimizer.lr
        opt = eng.optimizer._replace(
            lr=(lambda k, _lr=lr: _lr(k) * scale) if callable(lr)
            else lr * scale)

        def apply(theta, opt_state, nabla, wire, diff_hist, k_srv, extras):
            nabla32 = nabla.astype(jnp.float32) + wire.astype(
                jnp.float32) / m
            new_nabla = nabla32.astype(nabla.dtype)
            theta, opt_state, dsq = opt.apply_flat(
                theta, opt_state, nabla32, interpret=eng._interpret)
            theta = layout.cast_roundtrip(theta)
            diff_hist = jax.lax.dynamic_update_index_in_dim(
                diff_hist, dsq.astype(jnp.float32), k_srv % d_max, axis=0)
            params = layout.unpack(theta)
            extras = strategy.flat_pre_step(extras, params, theta,
                                            k_srv + 1)
            return theta, params, opt_state, new_nabla, diff_hist, extras

        return jax.jit(apply)

    def _run_async(self, params, batches) -> SimResult:
        eng, cfg = self.engine, self.cfg
        compute, link = cfg.network.compute, cfg.network.link
        n_batches = jax.tree.leaves(batches)[0].shape[0]
        steps = n_batches                      # target server versions
        tau = cfg.async_tau or self.rule.max_delay
        evals = eng.strategy.grad_evals_per_iter

        st = eng.init(params)
        layout = eng._layout
        up_bytes, down_bytes = self._byte_costs(layout.n)
        gate = self._build_gate(tau)
        apply = self._build_apply()

        # server numeric state
        theta, opt_state = st.params_flat, st.opt_state
        srv_params = st.params
        nabla, diff_hist = st.comm.nabla, st.comm.diff_hist
        worker_grads, extras = st.comm.worker_grads, st.comm.extras
        k_srv = 0

        # host_pool: the O(M·n) per-worker rows (grads + pooled extras)
        # move to a numpy WorkerPool; each gate streams ONE row in/out.
        # Gate traffic is PIPELINED: the row comes up in one fused H2D
        # (all planes in one block) and the gate's writeback is DEFERRED —
        # parked device-side and flushed before the same worker's next
        # gather, at loop exit, or (oldest first) whenever more than
        # ASYNC_PENDING_CAP rows are parked. Only w's own gate ever reads
        # w's row, so flushing at ANY point up to its next gather is
        # bit-exact — the cap keeps async device state at O(n) + a
        # CONSTANT number of rows however large M gets.
        pool = None
        pooled = ()
        pending_rows: dict = {}        # w -> (P, 1, n_flat) device block
        if cfg.host_pool:
            pooled = eng.strategy.pooled_extras()
            planes = {"worker_grads": np.asarray(worker_grads)}
            extras = dict(extras)
            for name in pooled:
                planes[name] = np.asarray(extras.pop(name))
            pool = F.WorkerPool(planes, storage=cfg.pool_storage,
                                path=cfg.pool_path)
            worker_grads = None

        def flush_pending(w=None):
            if w is None:
                while pending_rows:
                    flush_pending(next(iter(pending_rows)))
            elif w in pending_rows:
                pool.scatter_fused(np.asarray([w], np.int32),
                                   pending_rows.pop(w))

        # per-worker copies of θ (everyone starts at the init point, free)
        wparams = [srv_params] * self.m
        wflat = [theta] * self.m
        # per-worker stale evaluation point θ^{k−τ_m} for ring-indexed
        # rules (cada2): host-side Python refs ALIASING server pytrees —
        # O(distinct iterates) device memory, exactly the ring's bound
        stale_eval = [srv_params] * self.m
        procs = [WorkerProc(w, since_upload=tau, upload_version=-tau)
                 for w in range(self.m)]

        q = EventQueue()
        for w in range(self.m):
            dt = compute.iter_time(w, 0, 0.0, evals)
            procs[w].busy_s += dt
            q.push(dt, COMPUTE_DONE, w)
            self.tracer.add_span("compute", 0.0, dt, track=f"worker {w}",
                                 cat="compute")

        tr = self.tracer
        led = self._new_ledger()
        loss_t, loss_v, srv_times = [], [], []
        t = 0.0
        max_events = steps * self.m * 64 + 1024    # runaway guard
        n_events = 0
        while q and k_srv < steps:
            n_events += 1
            if n_events > max_events:
                raise RuntimeError(
                    f"async sim exceeded {max_events} events at version "
                    f"{k_srv}/{steps} — check the rule's staleness cap")
            ev = q.pop()
            t, w = ev.time, ev.worker
            p = procs[w]

            if ev.kind == COMPUTE_DONE:
                batch1 = jax.tree.map(
                    lambda x: x[p.local_iter % n_batches, w:w + 1], batches)
                stale = p.staleness(k_srv)
                p.max_staleness = max(p.max_staleness, stale)
                row_view = self._slice_extras(extras, w, stale_eval[w])
                if pool is not None:
                    flush_pending(w)   # w's deferred writeback, if parked
                    fused_row = pool.gather_fused(
                        np.asarray([w], np.int32))   # one H2D, all planes
                    rowd = F.split_fused_rows(fused_row, pool.plane_order)
                    wg_in = rowd["worker_grads"]
                    row_view.update({name: rowd[name] for name in pooled})
                else:
                    wg_in = worker_grads[w:w + 1]
                loss, upload, wire, wg_row, extras_row, g_lhs, g_rhs = gate(
                    wparams[w], wflat[w], batch1, wg_in,
                    jnp.full((1,), stale, jnp.int32), diff_hist, row_view)
                led.observe_margin(float(g_lhs), float(g_rhs))
                led.observe_staleness(stale)
                if pool is not None:
                    # defer the D2H: park the fused row on device; it
                    # lands in the pool before w's next gather (or at
                    # loop exit), riding under other workers' gates
                    pending_rows[w] = F.stack_fused_rows(
                        {"worker_grads": wg_row[None],
                         **{name: extras_row[name] for name in pooled}},
                        pool.plane_order, pool.plane_dtype)
                    # bounded parking: dict order is parking order, so
                    # this evicts the OLDEST row(s) past the cap
                    while len(pending_rows) > ASYNC_PENDING_CAP:
                        flush_pending(next(iter(pending_rows)))
                else:
                    worker_grads = worker_grads.at[w].set(wg_row)
                extras = self._merge_extras(extras, extras_row, w)
                loss_t.append(t)
                loss_v.append(float(loss))
                p.local_iter += 1
                if tr:
                    tr.instant("gate", t, track=f"worker {w}",
                               args={"upload": bool(upload),
                                     "staleness": int(stale)})
                if bool(upload):
                    # restart at 1, matching the sync engine's post-upload
                    # staleness (flat_comm_round: where(upload, 1, τ+1)),
                    # so τ_max = max_delay reproduces the rule's cap
                    # exactly — e.g. τ_max=1 forces an upload every
                    # local iteration, as max_delay=1 does per round
                    p.since_upload = 1
                    p.uploads += 1
                    # the worker's stale point becomes the iterate it just
                    # evaluated (post_upload's θ̂_m ← θ^k, async form)
                    stale_eval[w] = wparams[w]
                    p.bytes_up += up_bytes
                    dt_up = link.up_time(w, up_bytes, now=t)
                    if tr:
                        tr.add_span("upload", t, dt_up,
                                    track=f"worker {w}", cat="transfer")
                    q.push(t + dt_up, UPLOAD_ARRIVE, w, wire=wire)
                else:
                    p.since_upload += 1
                    p.bytes_down += down_bytes
                    dt_down = link.down_time(w, down_bytes, now=t)
                    if tr:
                        tr.add_span("download", t, dt_down,
                                    track=f"worker {w}", cat="transfer")
                    q.push(t + dt_down, DOWNLOAD_DONE, w)
                if pool is not None:
                    led.observe_pending(len(pending_rows))

            elif ev.kind == UPLOAD_ARRIVE:
                theta, srv_params, opt_state, nabla, diff_hist, extras = \
                    apply(theta, opt_state, nabla, ev.payload["wire"],
                          diff_hist, jnp.asarray(k_srv, jnp.int32), extras)
                k_srv += 1
                srv_times.append(t + cfg.server_update_s)
                p.upload_version = k_srv
                p.bytes_down += down_bytes
                if tr:
                    tr.add_span("apply_update", t, cfg.server_update_s,
                                track="server",
                                args={"version": k_srv, "worker": w})
                dt_down = link.down_time(w, down_bytes,
                                         now=t + cfg.server_update_s)
                if tr:
                    tr.add_span("download", t + cfg.server_update_s,
                                dt_down, track=f"worker {w}",
                                cat="transfer")
                q.push(t + cfg.server_update_s + dt_down,
                       DOWNLOAD_DONE, w)

            elif ev.kind == DOWNLOAD_DONE:
                wparams[w], wflat[w] = srv_params, theta
                dt = compute.iter_time(w, p.local_iter, t, evals)
                p.busy_s += dt
                if tr:
                    tr.add_span("compute", t, dt, track=f"worker {w}",
                                cat="compute")
                q.push(t + dt, COMPUTE_DONE, w)

        if pool is not None:
            flush_pending()            # drain deferred rows on exit
            led.observe_pool(pool)
        led.uploads = sum(p.uploads for p in procs)
        led.rounds = k_srv
        led.bytes_up = sum(p.bytes_up for p in procs)
        led.add_bytes_down(sum(p.bytes_down for p in procs))
        wall = float(srv_times[-1] if srv_times else t)
        return SimResult(
            mode="async", profile=cfg.network.name, steps=k_srv,
            wall_s=wall, times=np.asarray(srv_times),
            loss_times=np.asarray(loss_t),
            losses=np.asarray(loss_v, np.float64),
            uploads=sum(p.uploads for p in procs),
            grad_evals=sum(p.local_iter for p in procs) * evals,
            bytes_up=sum(p.bytes_up for p in procs),
            bytes_down=sum(p.bytes_down for p in procs),
            utilization=(np.asarray([p.busy_s for p in procs]) / wall
                         if wall > 0 else np.zeros(self.m)),
            max_staleness=max(p.max_staleness for p in procs),
            final_params=srv_params,
            ledger=led.summary())


def simulate(loss_fn, rule: CommRule, params, batches, *,
             n_workers: int, network: str | NetworkProfile = "zero",
             mode: str = "barrier", async_tau: int = 0,
             participation: float = 1.0, cohort_size: int = 0,
             host_pool: bool = False, pipeline: bool = True,
             metrics_every: int = 8, pool_storage: str = "ram",
             pool_path: str | None = None, rounds: int | None = None,
             lr: float = 0.01, eval_s: float = 1e-3, seed: int = 0,
             optimizer=None, interpret=None, trace=None) -> SimResult:
    """One-call front door: build the profile + config + runtime and run.

    ``trace`` (an ``obs.trace.Tracer`` or None) records every simulated
    compute/transfer/gate event as a span on the simulated clock — export
    with ``obs.export.write_chrome_trace`` for the timeline viewer."""
    if isinstance(network, str):
        network = network_profile(network, n_workers, eval_s=eval_s,
                                  seed=seed)
    cfg = SimConfig(network=network, mode=mode, async_tau=async_tau,
                    participation=participation, cohort_size=cohort_size,
                    host_pool=host_pool, pipeline=pipeline,
                    metrics_every=metrics_every, pool_storage=pool_storage,
                    pool_path=pool_path, seed=seed)
    rt = SimRuntime(loss_fn, rule, n_workers, cfg, lr=lr,
                    optimizer=optimizer, interpret=interpret, trace=trace)
    return rt.run(params, batches, rounds=rounds)
