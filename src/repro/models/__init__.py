from repro.models.config import (
    ModelConfig, active_param_count, param_count,
)
from repro.models.model import (
    DecodeCache, abstract_params, decode_step, forward, init_cache,
    init_params, lm_loss, prefill,
)

__all__ = [
    "ModelConfig", "param_count", "active_param_count",
    "DecodeCache", "abstract_params", "decode_step", "forward",
    "init_cache", "init_params", "lm_loss", "prefill",
]
