"""Model configuration shared by all 10 assigned architectures.

One frozen dataclass covers dense/GQA, MoE, Mamba1, Mamba2+shared-attention
hybrid, M-RoPE VLM and audio decoders; per-arch files in `repro.configs`
instantiate it with the published numbers (citations in each file).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp

BLOCK_DENSE = "dense"
BLOCK_MOE = "moe"
BLOCK_MAMBA1 = "mamba1"
BLOCK_MAMBA2 = "mamba2"

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # one of ARCH_TYPES (reporting only)
    block: str                # dense | moe | mamba1 | mamba2
    n_layers: int
    d_model: int
    vocab: int
    # attention (ignored by pure-SSM blocks)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0         # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    rotary_pct: float = 1.0
    mrope: bool = False       # qwen2-vl M-RoPE (t/h/w sections)
    mrope_sections: tuple = (16, 24, 24)   # halves of head_dim/2 per axis
    # MLP
    d_ff: int = 0
    mlp_act: str = "swiglu"   # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coeff: float = 0.01
    moe_local_dispatch: bool = False   # route per batch row (sharded
    #                                    gather stays local — §Perf)
    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0          # 0 -> d_model // 16   (mamba1)
    mamba_headdim: int = 64   # mamba2
    # hybrid: a single SHARED attention+MLP block applied every `attn_every`
    # SSM layers (zamba2-style). 0 disables.
    attn_every: int = 0
    # inference
    sliding_window: int = 0   # 0 = full attention; >0 = ring-buffer KV cache
    # embedding / IO
    embed_input: bool = True  # False: consumes precomputed embeddings (stub
    #                           modality frontend; vlm/audio carve-out)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True        # activation checkpointing over blocks
    source: str = ""          # paper / model-card citation

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.mamba_headdim

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float16": jnp.float16}[self.dtype]

    @property
    def mrope_sections_(self) -> tuple:
        """M-RoPE t/h/w sections, scaled to this head_dim if the configured
        ones (Qwen2-VL's 16/24/24 for hd=128) don't fit."""
        half = self.hd // 2
        if sum(self.mrope_sections) == half:
            return self.mrope_sections
        t = max(1, half // 4)
        h = (half - t) // 2
        return (t, h, half - t - h)

    @property
    def has_attention(self) -> bool:
        return self.block in ("dense", "moe") or self.attn_every > 0

    @property
    def subquadratic(self) -> bool:
        """Can this config decode at 500k+ context without O(S) full-KV?"""
        return self.block in ("mamba1", "mamba2") or self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def validate(self) -> None:
        assert self.arch_type in ARCH_TYPES, self.arch_type
        assert self.block in (BLOCK_DENSE, BLOCK_MOE, BLOCK_MAMBA1,
                              BLOCK_MAMBA2), self.block
        if self.block in (BLOCK_DENSE, BLOCK_MOE):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.block == BLOCK_MOE:
            assert 0 < self.top_k <= self.n_experts
        if self.block in (BLOCK_MAMBA1, BLOCK_MAMBA2):
            assert self.ssm_state > 0
        if self.block == BLOCK_MAMBA2:
            assert self.d_inner % self.mamba_headdim == 0
        if self.attn_every:
            assert self.n_layers % self.attn_every == 0
            assert self.n_heads > 0


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6ND model-FLOPs and memory napkin
    math; cross-checked against the real init in tests)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    n = V * d  # embedding
    if not cfg.tie_embeddings:
        n += d * V  # lm head
    n += d  # final norm

    def attn_params():
        return (d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd
                + cfg.n_heads * cfg.hd * d)

    def mlp_params(ff):
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        return mult * d * ff

    if cfg.block == "dense":
        per = attn_params() + mlp_params(cfg.d_ff) + 2 * d
        n += L * per
    elif cfg.block == "moe":
        per = (attn_params() + d * cfg.n_experts
               + cfg.n_experts * mlp_params(cfg.d_ff) + 2 * d)
        n += L * per
    elif cfg.block == "mamba1":
        di, N, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
        per = (d * 2 * di + cfg.ssm_conv * di + di            # in_proj, conv
               + di * (r + 2 * N) + r * di + di               # x_proj, dt
               + di * N + di + di * d + d)                    # A, D, out, ln
        n += L * per
    elif cfg.block == "mamba2":
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = (d * (2 * di + 2 * N + H) + cfg.ssm_conv * di + di
               + 3 * H + di + di * d + d)          # dt_bias/A/D, norm, out
        n += L * per
    if cfg.attn_every:
        n += attn_params() + mlp_params(cfg.d_ff) + 2 * d    # one shared block
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) params — MoE counts top_k experts only."""
    if cfg.block != "moe":
        return param_count(cfg)
    dense_like = param_count(cfg.with_(block="dense"))
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    extra = cfg.n_layers * (cfg.d_model * cfg.n_experts                 # router
                            + (cfg.top_k - 1) * mult * cfg.d_model * cfg.d_ff)
    return dense_like + extra
