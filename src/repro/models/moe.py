"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

TPU-minded design (GShard/Switch style, adapted for expert-parallel sharding):
  * router in fp32; top-k gates renormalized over the selected experts;
  * each expert takes its top-C tokens by gate score (C = capacity), the
    rest are dropped — dispatch is two gathers + one scatter-add, so the
    expert matmuls are dense (E, C, d) x (E, d, ff) einsums whose E axis
    shards over the "model"/expert axis of the mesh;
  * load-balance auxiliary loss (Switch eq. 4): E * Σ_e f_e · p_e.

FLOPs are the *active* FLOPs (top_k·tokens·capacity_factor), not E× dense —
this keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ffn_stacked


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes
    return min(c, n_tokens)     # top_k can't exceed the token count


def moe_ffn(params, cfg: ModelConfig, x):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Global dispatch flattens all B·S tokens before the per-expert top-C —
    maximum routing freedom, but on a sharded mesh the token gather crosses
    the data axis (GSPMD lowers it to collective-permute chains). With
    ``cfg.moe_local_dispatch`` routing happens per batch row (vmap over B):
    capacity is enforced per sequence and every gather/scatter stays on the
    row's own shard — the §Perf fix for collective-bound MoE prefill.
    """
    if cfg.moe_local_dispatch:
        def row(xr):
            return _dispatch(params, cfg, xr[None])
        y, aux = jax.vmap(row)(x)
        return y.reshape(x.shape), jnp.mean(aux)
    return _dispatch(params, cfg, x)


def _dispatch(params, cfg: ModelConfig, x):
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (N, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # (N, E) combine weights: renormalized gate if selected else 0.
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)        # (N, k, E)
    combine = jnp.einsum("nk,nke->ne", gate_vals, sel)

    # Capacity dispatch: each expert picks its top-C tokens by gate weight.
    cap = _capacity(n, cfg)
    score_en = combine.T                                        # (E, N)
    top_gate, top_tok = jax.lax.top_k(score_en, cap)            # (E, C)

    xe = xf[top_tok.reshape(-1)].reshape(e, cap, d)             # gather
    ye = ffn_stacked(params, cfg, xe)                           # (E, C, d)
    ye = ye * top_gate[..., None].astype(ye.dtype)

    out = jnp.zeros((n, d), ye.dtype).at[top_tok.reshape(-1)].add(
        ye.reshape(-1, d))

    # Switch-style load-balance loss.
    frac_tokens = jnp.mean(sel.sum(1), axis=0)                  # f_e
    mean_prob = jnp.mean(probs, axis=0)                         # p_e
    aux = cfg.router_aux_coeff * e * jnp.sum(frac_tokens * mean_prob)
    return out.reshape(b, s, d), aux
