"""Modality frontend STUBS (the single allowed carve-out).

qwen2-vl's ViT and musicgen's EnCodec are not implemented; instead the
frontends provide precomputed patch/frame embeddings of the right shape —
random but deterministic for smoke tests, ShapeDtypeStructs for the dry-run.
M-RoPE position ids for the VLM are synthesized as a (text, image-grid) plan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def vision_embeds(cfg: ModelConfig, rng, batch: int, seq: int):
    """Stub ViT+projector output: (B, S, d) patch+text embedding stream."""
    return jax.random.normal(rng, (batch, seq, cfg.d_model),
                             jnp.float32).astype(cfg.jnp_dtype)


def audio_embeds(cfg: ModelConfig, rng, batch: int, seq: int):
    """Stub EnCodec frame embeddings (sum over codebooks): (B, S, d)."""
    return jax.random.normal(rng, (batch, seq, cfg.d_model),
                             jnp.float32).astype(cfg.jnp_dtype)


def mrope_positions(batch: int, seq: int, image_grid: tuple = (16, 16)):
    """(3, B, S) t/h/w positions: a text prefix followed by an image whose
    patches advance h/w but share t (the Qwen2-VL dynamic-resolution plan)."""
    gh, gw = image_grid
    n_img = gh * gw
    n_txt = max(seq - n_img, 0)
    t_txt = jnp.arange(n_txt)
    img_t = jnp.full((min(n_img, seq),), n_txt)
    h_img = jnp.repeat(jnp.arange(gh), gw)[: min(n_img, seq)]
    w_img = jnp.tile(jnp.arange(gw), gh)[: min(n_img, seq)]
    t = jnp.concatenate([t_txt, img_t])[:seq]
    h = jnp.concatenate([t_txt, h_img + n_txt])[:seq]
    w = jnp.concatenate([t_txt, w_img + n_txt])[:seq]
    pos = jnp.stack([t, h, w])                      # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq)).astype(
        jnp.int32)
