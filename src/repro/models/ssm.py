"""Selective-state-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

TPU adaptation (see DESIGN.md §6): the CUDA selective-scan is replaced by a
*chunked* scan with the decay/drive terms computed PER CHUNK inside the
`lax.scan` body — the (B, S, D, N) state-trajectory tensors of a naive
implementation are never materialized (only one (B, chunk, D, N) tile lives
at a time, exactly the VMEM working set the Pallas kernel
kernels/ssm_scan.py tiles). Intra-chunk the recurrence is a parallel
`associative_scan`; inter-chunk a sequential carry.

Both variants lower to ONE generic scan over a flattened channel axis D:
  mamba1: D = d_inner,             A: (D, N) dense matrix
  mamba2: D = heads × head_dim,    A/Δ: per-head, repeated across head_dim
so the jnp path, the Pallas kernel, and ref.py all share one contract:
  (dt, x, a, b, c) -> (y, h_final)   with h_t = exp(Δ_t A) h + (Δ_t x_t)⊗B_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import causal_conv1d, conv1d_step, rmsnorm

DEFAULT_CHUNK = 128

# scan implementation: "jnp" (chunked associative scan, the XLA path) or
# "pallas" (kernels/ssm_scan.py — interpret mode on CPU, Mosaic on TPU).
_SCAN_IMPL = "jnp"


def set_scan_impl(name: str) -> None:
    global _SCAN_IMPL
    assert name in ("jnp", "pallas"), name
    _SCAN_IMPL = name


def _scan(dt, x, a, b, c, h0, chunk):
    """Dispatch to the configured scan implementation (same contract)."""
    if _SCAN_IMPL == "pallas" and h0 is None:
        from repro.kernels import ops as kops
        g, _, d = dt.shape
        a_g = jnp.broadcast_to(a.astype(jnp.float32)[None],
                               (g, d, a.shape[-1]))
        return kops.selective_scan(dt.astype(jnp.float32),
                                   x.astype(jnp.float32), a_g,
                                   b.astype(jnp.float32),
                                   c.astype(jnp.float32), chunk=chunk)
    return selective_scan_jnp(dt, x, a, b, c, h0, chunk)


def _assoc_combine(a, b):
    a1, b1 = a
    a2, b2 = b
    return a2 * a1, a2 * b1 + b2


def selective_scan_jnp(dt, x, a, b, c, h0=None, chunk: int = DEFAULT_CHUNK):
    """Chunked fused selective scan.

    dt, x: (B, S, D); a: (D, N); b, c: (B, S, N). All math fp32.
    Returns (y (B, S, D) fp32 — no D·x skip / gating — and h_final
    (B, D, N) fp32). Matches kernels/ref.selective_scan_ref.
    """
    bsz, s, d = dt.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq len {s} not divisible by chunk {chunk}")
    nc = s // chunk

    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)

    def to_chunks(t):
        return t.reshape((bsz, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    def per_chunk(h, xs):
        dt_c, x_c, b_c, c_c = xs            # (B,chunk,D) ×2, (B,chunk,N) ×2
        decay = jnp.exp(dt_c[..., None] * a)             # (B,chunk,D,N)
        drive = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        a_in, b_in = jax.lax.associative_scan(
            _assoc_combine, (decay, drive), axis=1)
        h_all = a_in * h[:, None] + b_in
        y_c = jnp.einsum("btdn,btn->btd", h_all, c_c)
        return h_all[:, -1], y_c

    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)
    h_final, y = jax.lax.scan(
        per_chunk, h0, (to_chunks(dt), to_chunks(x), to_chunks(b),
                        to_chunks(c)))
    return y.swapaxes(0, 1).reshape(bsz, s, d), h_final


# =================================================================== mamba1

def _mamba1_scan_inputs(params, xc):
    """Post-conv activations -> (dt, a, b, c) of the generic scan."""
    dt_raw = xc @ params["xp_dt"]                              # (B,S,r)
    b_ssm = xc @ params["xp_b"]                                # (B,S,N)
    c_ssm = xc @ params["xp_c"]                                # (B,S,N)
    dt = jax.nn.softplus((dt_raw @ params["dt_proj"]
                          + params["dt_bias"]).astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))          # (di, N)
    return dt, a, b_ssm, c_ssm


def mamba1_inner(params, cfg: ModelConfig, xc, z, h0=None,
                 chunk: int = DEFAULT_CHUNK, return_state: bool = False):
    """Selective scan after the conv. xc (B,S,di) post-conv+silu, z gate."""
    dt, a, b_ssm, c_ssm = _mamba1_scan_inputs(params, xc)
    y, h_final = _scan(dt, xc, a, b_ssm, c_ssm, h0, chunk)
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype)
    out = y @ params["out_proj"]
    return (out, h_final) if return_state else (out, None)


def mamba1_block(params, cfg: ModelConfig, x, chunk: int = DEFAULT_CHUNK):
    """Full block: norm -> in_proj -> conv -> selective scan -> out_proj."""
    res = x
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    xi = xn @ params["in_x"]                                   # (B,S,di)
    z = xn @ params["in_z"]
    xc = jax.nn.silu(causal_conv1d(xi, params["conv_w"], params["conv_b"]))
    out, _ = mamba1_inner(params, cfg, xc, z, chunk=chunk)
    return res + out


def mamba1_decode(params, cfg: ModelConfig, x_t, conv_state, ssm_state):
    """One-token recurrent step. x_t (B, d). Returns (y, conv', ssm')."""
    xn = rmsnorm(x_t, params["ln"], cfg.norm_eps)
    xi = xn @ params["in_x"]
    z = xn @ params["in_z"]
    conv_state, xc = conv1d_step(conv_state, xi, params["conv_w"],
                                 params["conv_b"])
    xc = jax.nn.silu(xc)
    dt_raw = xc @ params["xp_dt"]
    b_ssm = xc @ params["xp_b"]
    c_ssm = xc @ params["xp_c"]
    dt = jax.nn.softplus(dt_raw @ params["dt_proj"]
                         + params["dt_bias"]).astype(jnp.float32)  # (B,di)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * a)                         # (B,di,N)
    drive = (dt * xc.astype(jnp.float32))[..., None] \
        * b_ssm.astype(jnp.float32)[:, None, :]
    ssm_state = decay * ssm_state + drive
    y = jnp.einsum("bdn,bn->bd", ssm_state, c_ssm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return x_t + y @ params["out_proj"], conv_state, ssm_state


# =================================================================== mamba2

def _mamba2_split(params, cfg: ModelConfig, xn):
    """Separate projections (shard-friendly: each output dim is clean)."""
    return (xn @ params["in_z"], xn @ params["in_x"],
            xn @ params["in_b"], xn @ params["in_c"],
            xn @ params["in_dt"])               # z, x, B, C, dt


def _mamba2_scan_inputs(params, cfg: ModelConfig, dt_raw):
    """Per-head Δ/A repeated across head_dim onto the flat channel axis."""
    hd, n = cfg.mamba_headdim, cfg.ssm_state
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                  # (B,S,H)
    dt_e = jnp.repeat(dt, hd, axis=-1)                         # (B,S,di)
    a_h = -jnp.exp(params["A_log"].astype(jnp.float32))        # (H,)
    a_e = jnp.repeat(a_h, hd)[:, None] * jnp.ones((1, n), jnp.float32)
    return dt_e, a_e


def mamba2_inner(params, cfg: ModelConfig, xc, z, b_ssm, c_ssm, dt_raw,
                 h0=None, chunk: int = DEFAULT_CHUNK,
                 return_state: bool = False):
    """xc (B,S,di) post-conv+silu. h0/h_final: (B, H, hd, N)."""
    bsz, s, di = xc.shape
    hn, hd, n = cfg.ssm_heads, cfg.mamba_headdim, cfg.ssm_state
    dt_e, a_e = _mamba2_scan_inputs(params, cfg, dt_raw)
    h0_flat = None if h0 is None else h0.reshape(bsz, di, n)
    y, h_final = _scan(dt_e, xc, a_e, b_ssm, c_ssm, h0_flat, chunk)
    y = y + jnp.repeat(params["D"].astype(jnp.float32), hd) \
        * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, h_final.reshape(bsz, hn, hd, n)
    return out, None


def mamba2_block(params, cfg: ModelConfig, x, chunk: int = DEFAULT_CHUNK):
    res = x
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    z, xi, b_ssm, c_ssm, dt_raw = _mamba2_split(params, cfg, xn)
    xc = jax.nn.silu(causal_conv1d(xi, params["conv_w"], params["conv_b"]))
    out, _ = mamba2_inner(params, cfg, xc, z, b_ssm, c_ssm, dt_raw,
                          chunk=chunk)
    return res + out


def mamba2_decode(params, cfg: ModelConfig, x_t, conv_state, ssm_state):
    """x_t (B, d); ssm_state (B, H, hd, N)."""
    bsz = x_t.shape[0]
    hn, hd = cfg.ssm_heads, cfg.mamba_headdim
    xn = rmsnorm(x_t, params["ln"], cfg.norm_eps)
    z, xi, b_ssm, c_ssm, dt_raw = _mamba2_split(params, cfg, xn)
    conv_state, xc = conv1d_step(conv_state, xi, params["conv_w"],
                                 params["conv_b"])
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xc.reshape(bsz, hn, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a)[..., None, None]                   # (B,H,1,1)
    drive = (dt[..., None] * xh)[..., None] \
        * b_ssm.astype(jnp.float32)[:, None, None, :]
    ssm_state = decay * ssm_state + drive
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, c_ssm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(bsz, -1)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    return x_t + y @ params["out_proj"], conv_state, ssm_state
