"""Shared layer primitives: RMSNorm, FFNs (plain and expert-stacked),
causal depthwise conv (for Mamba)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


_GATE_ACT = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu}


def ffn(params, cfg: ModelConfig, x):
    """Dense FFN: gated (swiglu/geglu: w_gate,w_up,w_down) or plain gelu."""
    act = _GATE_ACT.get(cfg.mlp_act)
    if act is not None:
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


def ffn_stacked(params, cfg: ModelConfig, x):
    """Expert-stacked FFN: x (E, C, d) with weights (E, d, ff)/(E, ff, d)."""
    act = _GATE_ACT.get(cfg.mlp_act)
    up = jnp.einsum("ecd,edf->ecf", x, params["w_up"])
    if act is not None:
        h = act(jnp.einsum("ecd,edf->ecf", x, params["w_gate"])) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def causal_conv1d(x, w, b):
    """Depthwise causal conv: x (B, S, C), w (K, C), b (C,)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(k):  # K is tiny (4); unrolled shifts beat a real conv op
        out = out + pad[:, j:j + x.shape[1], :].astype(jnp.float32) * w[j]
    return (out + b).astype(x.dtype)


def conv1d_step(conv_state, x_t, w, b):
    """One decode step. conv_state (B, K-1, C) holds the last K-1 inputs;
    x_t (B, C). Returns (new_state, y_t)."""
    k = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32), w) + b
    return full[:, 1:, :], y.astype(x_t.dtype)
