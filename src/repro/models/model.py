"""Model assembly: parameter init, scan-over-layers forward, LM loss,
prefill, and single-token decode with KV/SSM caches.

Layout conventions
  * params["blocks"]: every per-layer tensor stacked with leading n_layers
    (hybrid reshapes to (stages, per_stage) at scan time);
  * one `lax.scan` over layers keeps the HLO small enough to compile
    126-layer configs on this CPU container and is the production idiom;
  * logits are produced in the model dtype; losses accumulate in fp32.

Decode caches
  * attention: roped K/V ring buffer (L, B, W, Hkv, hd) + shared slot->abs
    position table; sliding-window and full caches use the same mechanism;
  * mamba1/2: conv tail (L, B, K-1, di) + fp32 SSM state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import ffn, rmsnorm
from repro.models.moe import moe_ffn
from repro.models.ssm import (
    mamba1_block, mamba1_decode, mamba2_block, mamba2_decode,
)

INIT_STD = 0.02


# ================================================================== init

def _dense(rng, shape, dtype, std=INIT_STD):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def _attn_params(cfg: ModelConfig, rng, dtype):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": _dense(k1, (d, hq * hd), dtype),
        "wk": _dense(k2, (d, hkv * hd), dtype),
        "wv": _dense(k3, (d, hkv * hd), dtype),
        "wo": _dense(k4, (hq * hd, d), dtype),
    }


def _ffn_params(cfg: ModelConfig, rng, dtype, stacked_experts: int = 0):
    k1, k2, k3 = jax.random.split(rng, 3)
    d, ff = cfg.d_model, cfg.d_ff
    lead = (stacked_experts,) if stacked_experts else ()
    p = {
        "w_up": _dense(k1, lead + (d, ff), dtype),
        "w_down": _dense(k2, lead + (ff, d), dtype),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        p["w_gate"] = _dense(k3, lead + (d, ff), dtype)
    return p


def _block_params(cfg: ModelConfig, rng, dtype):
    d = cfg.d_model
    if cfg.block in ("dense", "moe"):
        k1, k2, k3 = jax.random.split(rng, 3)
        p = {
            "ln1": jnp.ones((d,), dtype),
            "ln2": jnp.ones((d,), dtype),
            "attn": _attn_params(cfg, k1, dtype),
        }
        if cfg.block == "moe":
            p["mlp"] = _ffn_params(cfg, k2, dtype,
                                   stacked_experts=cfg.n_experts)
            p["mlp"]["router"] = _dense(k3, (d, cfg.n_experts), jnp.float32)
        else:
            p["mlp"] = _ffn_params(cfg, k2, dtype)
        return p
    if cfg.block == "mamba1":
        di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
        ks = jax.random.split(rng, 8)
        return {
            "ln": jnp.ones((d,), dtype),
            "in_x": _dense(ks[0], (d, di), dtype),
            "in_z": _dense(ks[1], (d, di), dtype),
            "conv_w": _dense(ks[2], (cfg.ssm_conv, di), jnp.float32, 0.1),
            "conv_b": jnp.zeros((di,), jnp.float32),
            "xp_dt": _dense(ks[3], (di, r), dtype),
            "xp_b": _dense(ks[4], (di, n), dtype),
            "xp_c": _dense(ks[5], (di, n), dtype),
            "dt_proj": _dense(ks[6], (r, di), jnp.float32, 1.0 / r ** 0.5),
            "dt_bias": jnp.full((di,), -4.0, jnp.float32),
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": _dense(ks[7], (di, d), dtype),
        }
    if cfg.block == "mamba2":
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        ks = jax.random.split(rng, 7)
        return {
            "ln": jnp.ones((d,), dtype),
            "in_z": _dense(ks[0], (d, di), dtype),
            "in_x": _dense(ks[1], (d, di), dtype),
            "in_b": _dense(ks[2], (d, n), dtype),
            "in_c": _dense(ks[3], (d, n), dtype),
            "in_dt": _dense(ks[4], (d, h), dtype),
            "conv_w": _dense(ks[5], (cfg.ssm_conv, di), jnp.float32, 0.1),
            "conv_b": jnp.zeros((di,), jnp.float32),
            "dt_bias": jnp.full((h,), -4.0, jnp.float32),
            "A_log": jnp.zeros((h,), jnp.float32),
            "D": jnp.ones((h,), jnp.float32),
            "out_norm": jnp.ones((di,), dtype),
            "out_proj": _dense(ks[6], (di, d), dtype),
        }
    raise ValueError(cfg.block)


def init_params(cfg: ModelConfig, rng) -> dict:
    cfg.validate()
    dtype = cfg.jnp_dtype
    k_emb, k_blocks, k_head, k_shared = jax.random.split(rng, 4)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _block_params(cfg, k, dtype))(block_keys)
    params = {
        "embed": _dense(k_emb, (cfg.vocab, cfg.d_model), dtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(k_head, (cfg.d_model, cfg.vocab), dtype)
    if cfg.attn_every:  # zamba2-style single shared attention+MLP block
        ka, kf = jax.random.split(k_shared)
        params["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": _attn_params(cfg, ka, dtype),
            "mlp": _ffn_params(cfg, kf, dtype),
        }
    return params


def abstract_params(cfg: ModelConfig, rng=None):
    """ShapeDtypeStructs of init_params without allocating (dry-run path)."""
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


# ================================================================ forward

def _attn_mlp_block(params, cfg: ModelConfig, x, cos, sin, window):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv(params["attn"], cfg, h)
    q = attn.apply_rope(q, cos, sin, cfg.rotary_pct)
    k = attn.apply_rope(k, cos, sin, cfg.rotary_pct)
    a = attn.causal_attention(q, k, v, window=window, dtype=x.dtype)
    x = x + attn.out_proj(params["attn"], a)
    h2 = rmsnorm(x, params["ln2"], cfg.norm_eps)
    if cfg.block == "moe" and "router" in params["mlp"]:
        y, aux = moe_ffn(params["mlp"], cfg, h2)
    else:
        y, aux = ffn(params["mlp"], cfg, h2), jnp.zeros((), jnp.float32)
    return x + y, aux


def _rope_tables(cfg: ModelConfig, positions, batch, seq):
    if not cfg.has_attention:
        return None, None
    if cfg.mrope:
        if positions is None:
            base = jnp.arange(seq)[None].repeat(batch, 0)
            positions = jnp.stack([base] * 3)                  # (3,B,S)
        return attn.mrope_angles(positions, cfg.hd, cfg.rope_theta,
                                 cfg.mrope_sections_)
    if positions is None:
        positions = jnp.arange(seq)[None]                      # (1,S) bcast
    return attn.rope_angles(positions, int(cfg.hd * cfg.rotary_pct),
                            cfg.rope_theta)


def hidden_states(cfg: ModelConfig, params, tokens=None, embeds=None,
                  positions=None):
    """Token/embedding input -> final hidden states (B, S, d), aux loss."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(cfg.jnp_dtype)
    b, s, _ = x.shape
    cos, sin = _rope_tables(cfg, positions, b, s)
    window = cfg.sliding_window
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.block in ("dense", "moe"):
        def body(carry, lp):
            h, aux = carry
            h, a = _attn_mlp_block(lp, cfg, h, cos, sin, window)
            return (h, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    elif cfg.attn_every:  # hybrid: stages of SSM layers + shared attention
        stages = cfg.n_layers // cfg.attn_every
        staged = jax.tree.map(
            lambda p: p.reshape((stages, cfg.attn_every) + p.shape[1:]),
            params["blocks"])
        ssm_fn = mamba2_block if cfg.block == "mamba2" else mamba1_block

        def stage(carry, sp):
            h, aux = carry
            def inner(hh, lp):
                return ssm_fn(lp, cfg, hh), None
            if cfg.remat:
                inner = jax.checkpoint(inner)
            h, _ = jax.lax.scan(inner, h, sp)
            h, a = _attn_mlp_block(params["shared"], cfg, h, cos, sin,
                                   window)
            return (h, aux + a), None
        (x, aux), _ = jax.lax.scan(stage, (x, aux0), staged)
    else:  # pure SSM
        ssm_fn = mamba2_block if cfg.block == "mamba2" else mamba1_block

        def body(h, lp):
            return ssm_fn(lp, cfg, h), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        aux = aux0
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def logits_fn(cfg: ModelConfig, params, hidden):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return hidden @ head


def forward(cfg: ModelConfig, params, tokens=None, embeds=None,
            positions=None):
    hidden, aux = hidden_states(cfg, params, tokens, embeds, positions)
    return logits_fn(cfg, params, hidden), aux


# =================================================================== loss

def lm_loss(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray, dict]:
    """batch: {"tokens": (B, S+1)} or {"embeds": (B,S,d), "labels": (B,S)}
    (+ optional "positions"). Returns (scalar loss, metrics)."""
    if "tokens" in batch:
        inputs, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
        logits, aux = forward(cfg, params, tokens=inputs,
                              positions=batch.get("positions"))
    else:
        logits, aux = forward(cfg, params, embeds=batch["embeds"],
                              positions=batch.get("positions"))
        labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss + aux, {"nll": loss, "aux": aux}


# ================================================================= decode

class DecodeCache(NamedTuple):
    """Pytree cache for lock-step batched decode at absolute position
    ``index``. Attention K/V are stored ALREADY roped; ``slot_pos`` maps ring
    slots to absolute positions (-1 = empty)."""
    index: jnp.ndarray          # scalar int32: next absolute position
    slot_pos: jnp.ndarray       # (W,) int32
    k: Any = None               # (L_attn, B, W, Hkv, hd)
    v: Any = None
    conv: Any = None            # (L_ssm, B, K-1, di)
    ssm: Any = None             # (L_ssm, B, ...) fp32


def cache_width(cfg: ModelConfig, max_seq: int) -> int:
    if not cfg.has_attention:
        return 0
    return min(cfg.sliding_window or max_seq, max_seq)


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.block in ("dense", "moe"):
        return cfg.n_layers
    if cfg.attn_every:
        return cfg.n_layers // cfg.attn_every
    return 0


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int
               ) -> DecodeCache:
    dtype = cfg.jnp_dtype
    w = cache_width(cfg, max_seq)
    la = _n_attn_layers(cfg)
    k = v = conv = ssm = None
    if la:
        shape = (la, batch_size, w, cfg.n_kv_heads, cfg.hd)
        k, v = jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
    if cfg.block in ("mamba1", "mamba2"):
        conv = jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1,
                          cfg.d_inner), dtype)
        if cfg.block == "mamba1":
            sshape = (cfg.n_layers, batch_size, cfg.d_inner, cfg.ssm_state)
        else:
            sshape = (cfg.n_layers, batch_size, cfg.ssm_heads,
                      cfg.mamba_headdim, cfg.ssm_state)
        ssm = jnp.zeros(sshape, jnp.float32)
    return DecodeCache(
        index=jnp.zeros((), jnp.int32),
        slot_pos=jnp.full((max(w, 1),), -1, jnp.int32),
        k=k, v=v, conv=conv, ssm=ssm)


def _attn_decode_layer(lp, cfg: ModelConfig, x, k_c, v_c, slot, slot_pos,
                       cos, sin):
    """x (B,1,d); k_c/v_c (B,W,Hkv,hd). Returns (y, k_c', v_c')."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv(lp["attn"], cfg, h)
    q = attn.apply_rope(q, cos, sin, cfg.rotary_pct)
    k = attn.apply_rope(k, cos, sin, cfg.rotary_pct)
    k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k.astype(k_c.dtype), slot,
                                              axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v.astype(v_c.dtype), slot,
                                              axis=1)
    valid = (slot_pos >= 0)[None]                              # (1, W)
    a = attn.decode_attention(q, k_c, v_c, valid, dtype=x.dtype)
    x = x + attn.out_proj(lp["attn"], a)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.block == "moe" and "router" in lp["mlp"]:
        y, _ = moe_ffn(lp["mlp"], cfg, h2)
    else:
        y = ffn(lp["mlp"], cfg, h2)
    return x + y, k_c, v_c


def decode_step(cfg: ModelConfig, params, cache: DecodeCache, tokens=None,
                embeds=None):
    """One decode step for the whole batch. tokens (B,) or embeds (B,1,d).
    Returns (logits (B, V), new cache)."""
    if embeds is None:
        x = params["embed"][tokens][:, None, :]               # (B,1,d)
    else:
        x = embeds.astype(cfg.jnp_dtype)
    b = x.shape[0]
    idx = cache.index
    w = cache.slot_pos.shape[0]
    slot = (idx % w).astype(jnp.int32)
    pos = jnp.full((1, 1), idx, jnp.int32)                     # (B=1bc, 1)
    if cfg.mrope:
        cos, sin = attn.mrope_angles(
            jnp.broadcast_to(pos[None], (3, 1, 1)), cfg.hd, cfg.rope_theta,
            cfg.mrope_sections_)
    elif cfg.has_attention:
        cos, sin = attn.rope_angles(pos, int(cfg.hd * cfg.rotary_pct),
                                    cfg.rope_theta)
    slot_pos = cache.slot_pos.at[slot].set(idx) if w else cache.slot_pos

    k_cache, v_cache, conv_c, ssm_c = cache.k, cache.v, cache.conv, cache.ssm
    if cfg.block in ("dense", "moe"):
        def body(h, xs):
            lp, k_c, v_c = xs
            h, k_c, v_c = _attn_decode_layer(
                lp, cfg, h, k_c, v_c, slot, slot_pos, cos, sin)
            return h, (k_c, v_c)
        x, (k_cache, v_cache) = jax.lax.scan(
            body, x, (params["blocks"], k_cache, v_cache))
    elif cfg.attn_every:
        stages = cfg.n_layers // cfg.attn_every
        staged = jax.tree.map(
            lambda p: p.reshape((stages, cfg.attn_every) + p.shape[1:]),
            params["blocks"])
        conv_s = conv_c.reshape((stages, cfg.attn_every) + conv_c.shape[1:])
        ssm_s = ssm_c.reshape((stages, cfg.attn_every) + ssm_c.shape[1:])
        dec = mamba2_decode if cfg.block == "mamba2" else mamba1_decode

        def stage(h, xs):
            sp, cv, st, k_c, v_c = xs
            def inner(hh, ys):
                lp, c1, s1 = ys
                y, c1, s1 = dec(lp, cfg, hh[:, 0], c1, s1)
                return y[:, None], (c1, s1)
            h, (cv, st) = jax.lax.scan(inner, h, (sp, cv, st))
            h, k_c, v_c = _attn_decode_layer(
                params["shared"], cfg, h, k_c, v_c, slot, slot_pos, cos, sin)
            return h, (cv, st, k_c, v_c)
        x, (conv_s, ssm_s, k_cache, v_cache) = jax.lax.scan(
            stage, x, (staged, conv_s, ssm_s, k_cache, v_cache))
        conv_c = conv_s.reshape(conv_c.shape)
        ssm_c = ssm_s.reshape(ssm_c.shape)
    else:  # pure SSM
        dec = mamba2_decode if cfg.block == "mamba2" else mamba1_decode

        def body(h, xs):
            lp, c1, s1 = xs
            y, c1, s1 = dec(lp, cfg, h[:, 0], c1, s1)
            return y[:, None], (c1, s1)
        x, (conv_c, ssm_c) = jax.lax.scan(body, x, (params["blocks"],
                                                    conv_c, ssm_c))

    hidden = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, hidden)[:, 0]
    new_cache = DecodeCache(index=idx + 1, slot_pos=slot_pos,
                            k=k_cache, v=v_cache, conv=conv_c, ssm=ssm_c)
    return logits, new_cache


# ================================================================ prefill

def prefill(cfg: ModelConfig, params, tokens=None, embeds=None,
            positions=None, max_seq: int | None = None):
    """Full-sequence forward that also builds the decode cache.

    Returns (last-token logits (B, V), DecodeCache primed at index=S).
    Attention K/V are recomputed roped into the cache (one extra pass over
    the projections — negligible next to the S² attention itself).
    """
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(cfg.jnp_dtype)
    b, s, _ = x.shape
    max_seq = max_seq or s
    cache = init_cache(cfg, b, max_seq)
    w = cache.slot_pos.shape[0]
    cos, sin = _rope_tables(cfg, positions, b, s)
    window = cfg.sliding_window

    def attn_block_cached(lp, h):
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv(lp["attn"], cfg, hn)
        q = attn.apply_rope(q, cos, sin, cfg.rotary_pct)
        k = attn.apply_rope(k, cos, sin, cfg.rotary_pct)
        a = attn.causal_attention(q, k, v, window=window, dtype=h.dtype)
        h = h + attn.out_proj(lp["attn"], a)
        h2 = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if cfg.block == "moe" and "router" in lp["mlp"]:
            y, _ = moe_ffn(lp["mlp"], cfg, h2)
        else:
            y = ffn(lp["mlp"], cfg, h2)
        return h + y, k, v

    def to_ring(t):  # (B, S, Hkv, hd) -> last W entries in ring order
        tail = t[:, -w:]
        if s >= w:
            roll = s % w
            return jnp.roll(tail, roll, axis=1)
        return jnp.pad(tail, ((0, 0), (0, w - s), (0, 0), (0, 0)))

    k_c = v_c = conv_c = ssm_c = None
    n_l = cfg.n_layers
    if cfg.block in ("dense", "moe"):
        def body(h, lp):
            h, k, v = attn_block_cached(lp, h)
            return h, (to_ring(k), to_ring(v))
        if cfg.remat:
            body = jax.checkpoint(body)
        x, (k_c, v_c) = jax.lax.scan(body, x, params["blocks"])
    elif cfg.attn_every:
        stages = n_l // cfg.attn_every
        staged = jax.tree.map(
            lambda p: p.reshape((stages, cfg.attn_every) + p.shape[1:]),
            params["blocks"])

        def stage(h, sp):
            def inner(hh, lp):
                hh, cst, sst = _ssm_block_cached(lp, cfg, hh)
                return hh, (cst, sst)
            h, (cst, sst) = jax.lax.scan(inner, h, sp)
            h, k, v = attn_block_cached(params["shared"], h)
            return h, (cst, sst, to_ring(k), to_ring(v))
        if cfg.remat:
            stage = jax.checkpoint(stage)
        x, (conv_s, ssm_s, k_c, v_c) = jax.lax.scan(stage, x, staged)
        conv_c = conv_s.reshape((n_l,) + conv_s.shape[2:])
        ssm_c = ssm_s.reshape((n_l,) + ssm_s.shape[2:])
    else:
        def body(h, lp):
            h, cst, sst = _ssm_block_cached(lp, cfg, h)
            return h, (cst, sst)
        if cfg.remat:
            body = jax.checkpoint(body)
        x, (conv_c, ssm_c) = jax.lax.scan(body, x, params["blocks"])
    positions_all = jnp.arange(max(s - w, 0), s)
    slot_pos = jnp.full((w,), -1, jnp.int32)
    n_fill = min(s, w)
    slots = (positions_all % w) if s >= w else jnp.arange(n_fill)
    slot_pos = slot_pos.at[slots].set(positions_all.astype(jnp.int32))

    hidden = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, hidden)[:, 0]
    return logits, DecodeCache(
        index=jnp.asarray(s, jnp.int32), slot_pos=slot_pos,
        k=k_c, v=v_c, conv=conv_c, ssm=ssm_c)


def _ssm_block_cached(lp, cfg: ModelConfig, x):
    """Run an SSM block over the full sequence and emit its decode state."""
    from repro.models.layers import causal_conv1d
    import repro.models.ssm as ssm_mod
    res = x
    xn = rmsnorm(x, lp["ln"], cfg.norm_eps)
    if cfg.block == "mamba1":
        xi = xn @ lp["in_x"]
        z = xn @ lp["in_z"]
        xc = jax.nn.silu(causal_conv1d(xi, lp["conv_w"], lp["conv_b"]))
        out, h_final = ssm_mod.mamba1_inner(lp, cfg, xc, z,
                                            return_state=True)
    else:  # mamba2
        z, xi, b_ssm, c_ssm, dt_raw = ssm_mod._mamba2_split(lp, cfg, xn)
        xc = jax.nn.silu(causal_conv1d(xi, lp["conv_w"], lp["conv_b"]))
        out, h_final = ssm_mod.mamba2_inner(lp, cfg, xc, z, b_ssm, c_ssm,
                                            dt_raw, return_state=True)
    conv_tail = xi[:, -(cfg.ssm_conv - 1):, :]
    return res + out, conv_tail, h_final
