"""Attention: GQA with RoPE / partial-RoPE / M-RoPE, causal training path,
KV-cache decode path with optional sliding-window ring buffer.

Implementation notes (TPU-minded):
  * logits/softmax in fp32, values in the model dtype;
  * GQA is computed grouped (no KV head repetition in memory) via a
    (B, G, Hq/G, S, hd) reshape so the MXU contraction stays dense;
  * the sliding-window decode cache is a ring buffer of size W — position
    validity is reconstructed from absolute positions stored alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_NEG = -1e30


# ----------------------------------------------------------------- RoPE

def rope_angles(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions, dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): positions (3, B, S) for (t, h, w) axes; the head
    dim halves are split into per-axis sections."""
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang_per_axis = positions.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    chunks = []
    start = 0
    for axis, sec in enumerate(sections):
        chunks.append(ang_per_axis[axis, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(chunks, axis=-1)  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rotary_pct: float = 1.0):
    """x (..., S, H, hd); cos/sin (..., S, rot/2) broadcast over heads."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    c = cos[..., None, : rot // 2]
    s = sin[..., None, : rot // 2]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ------------------------------------------------------------- projections

def qkv(params, cfg: ModelConfig, x):
    """x (B,S,d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd)."""
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def out_proj(params, x):
    b, s = x.shape[:2]
    return x.reshape(b, s, -1) @ params["wo"]


# ------------------------------------------------------------ core attention

def _grouped_scores(q, k):
    """q (B,Sq,Hq,hd), k (B,Sk,Hkv,hd) -> scores (B,Hq,Sq,Sk) via GQA groups."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    return scores.reshape(b, hq, sq, k.shape[1]) / jnp.sqrt(hd).astype(
        jnp.float32)


def _grouped_values(probs, v):
    """probs (B,Hq,Sq,Sk), v (B,Sk,Hkv,hd) -> (B,Sq,Hq,hd)."""
    b, hq, sq, sk = probs.shape
    hkv = v.shape[2]
    g = hq // hkv
    pg = probs.reshape(b, hkv, g, sq, sk)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v.astype(probs.dtype))
    return out.reshape(b, sq, hq, v.shape[3])


FLASH_THRESHOLD = 2048   # use blockwise attention at/above this seq length
FLASH_Q_CHUNK = 1024
FLASH_KV_CHUNK = 1024


def naive_attention(q, k, v, positions_q=None, positions_k=None,
                    window: int = 0, dtype=jnp.bfloat16):
    """Reference O(S²)-memory attention (tests / short sequences)."""
    sq, sk = q.shape[1], k.shape[1]
    if positions_q is None:
        positions_q = jnp.arange(sq)
    if positions_k is None:
        positions_k = jnp.arange(sk)
    scores = _grouped_scores(q, k)
    rel = positions_q[:, None] - positions_k[None, :]        # (Sq, Sk)
    mask = rel >= 0
    if window:
        mask &= rel < window
    scores = jnp.where(mask[None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    return _grouped_values(probs, v).astype(dtype)


def flash_attention(q, k, v, window: int = 0, dtype=jnp.bfloat16,
                    q_chunk: int = FLASH_Q_CHUNK,
                    kv_chunk: int = FLASH_KV_CHUNK):
    """Blockwise (flash-style) causal attention — O(S·chunk) memory.

    Outer Python loop over Sq/q_chunk query blocks (static, so each block's
    KV extent is trimmed to the causal/window range: true FLOP savings, not
    just masking); inner `lax.scan` over KV blocks carrying the running
    (max, sum, acc) softmax state in fp32.

    Self-attention only (Sq == Sk, standard positions). GQA is computed
    grouped, matching `naive_attention` numerics to ~1e-3 (softmax order).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kv_chunk = min(kv_chunk, q_chunk)  # causal trim needs kv | q blocks
    assert s % q_chunk == 0 and q_chunk % kv_chunk == 0, (s, q_chunk,
                                                          kv_chunk)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(b, s, hkv, g, hd)

    outs = []
    for qi in range(s // q_chunk):
        q_lo = qi * q_chunk
        q_hi = q_lo + q_chunk
        # Static causal/window KV extent for this query block.
        kv_lo = 0 if not window else max(0, (q_lo - window) // kv_chunk
                                         * kv_chunk)
        kv_hi = q_hi  # causal: keys beyond the block's last query are dead
        qb = qg[:, q_lo:q_hi].astype(jnp.float32)           # (B,qc,Hkv,G,hd)
        pos_q = q_lo + jnp.arange(q_chunk)
        nkv = (kv_hi - kv_lo) // kv_chunk
        kb = k[:, kv_lo:kv_hi].reshape(b, nkv, kv_chunk, hkv, hd)
        vb = v[:, kv_lo:kv_hi].reshape(b, nkv, kv_chunk, hkv, hd)

        def kv_step(carry, xs, pos_q=pos_q, kv_lo=kv_lo):
            m, l, acc = carry
            kc, vc, ki = xs                                  # (B,kc,Hkv,hd)
            pos_k = kv_lo + ki * kv_chunk + jnp.arange(kv_chunk)
            sc = jnp.einsum("bqkgh,bskh->bkgqs", qb,
                            kc.astype(jnp.float32)) * scale  # (B,Hkv,G,qc,kc)
            rel = pos_q[:, None] - pos_k[None, :]
            mask = rel >= 0
            if window:
                mask &= rel < window
            sc = jnp.where(mask[None, None, None], sc, _NEG)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nkv)))
        ob = acc / l[..., None]                              # (B,Hkv,G,qc,hd)
        outs.append(ob.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, hd))
    return jnp.concatenate(outs, axis=1).astype(dtype)


def causal_attention(q, k, v, positions_q=None, positions_k=None,
                     window: int = 0, dtype=jnp.bfloat16):
    """Causal (optionally sliding-window) attention for train/prefill.

    Dispatches to the flash path for long self-attention (the memory-safe
    production path) and the naive reference otherwise.
    """
    sq, sk = q.shape[1], k.shape[1]
    flashable = (sq == sk and sq >= FLASH_THRESHOLD
                 and sq % FLASH_Q_CHUNK == 0 and sk % FLASH_KV_CHUNK == 0
                 and positions_q is None and positions_k is None)
    if flashable:
        return flash_attention(q, k, v, window=window, dtype=dtype)
    return naive_attention(q, k, v, positions_q, positions_k, window, dtype)


def decode_attention(q, k_cache, v_cache, valid, dtype=jnp.bfloat16):
    """One-token attention over a (possibly ring-buffered) cache.

    q (B,1,Hq,hd); k/v_cache (B,W,Hkv,hd); valid (B,W) bool.
    """
    scores = _grouped_scores(q, k_cache)                     # (B,Hq,1,W)
    scores = jnp.where(valid[:, None, None, :], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    return _grouped_values(probs, v_cache).astype(dtype)
