"""Small models for the paper's own experiments (§4): logistic regression,
an MLP, and a small conv net (the paper's two-conv + two-FC MNIST net).

Pure-jnp init/apply pairs (no flax): ``init(rng, example_x) -> params`` and
``apply(params, x) -> logits``. Losses are cross-entropy with the paper's
ℓ2 regularizer λ=1e-5.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

L2_COEFF = 1e-5  # paper §13.2.1


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _l2(params, coeff):
    return coeff * sum(jnp.sum(jnp.square(w))
                       for w in jax.tree.leaves(params))


# ---------------------------------------------------------------- logistic

def logreg_init(rng, dim: int, n_classes: int):
    return {
        "w": jnp.zeros((dim, n_classes), jnp.float32),
        "b": jnp.zeros((n_classes,), jnp.float32),
    }


def logreg_apply(params, x):
    return x @ params["w"] + params["b"]


def logreg_loss(params, batch, l2: float = L2_COEFF):
    x, y = batch
    return _xent(logreg_apply(params, x), y) + _l2(params, l2)


# ---------------------------------------------------------------- MLP

def mlp_init(rng, dim: int, hidden: int, n_classes: int):
    k1, k2 = jax.random.split(rng)
    s1 = 1.0 / jnp.sqrt(dim)
    s2 = 1.0 / jnp.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, n_classes)) * s2,
        "b2": jnp.zeros((n_classes,)),
    }


def mlp_apply(params, x):
    x = x.reshape(x.shape[0], -1)
    h = jax.nn.elu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch, l2: float = L2_COEFF):
    x, y = batch
    return _xent(mlp_apply(params, x), y) + _l2(params, l2)


# ---------------------------------------------------------------- small CNN
# Paper: two convolution-ELU-maxpooling layers followed by two FC layers.
# We keep the structure but shrink channels so CPU Monte-Carlo runs are fast.

def cnn_init(rng, n_classes: int = 10, c1: int = 8, c2: int = 16,
             fc: int = 64, hw: int = 28, in_ch: int = 1):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    hw4 = hw // 4
    flat = hw4 * hw4 * c2
    return {
        "conv1": jax.random.normal(k1, (5, 5, in_ch, c1)) * 0.1,
        "bc1": jnp.zeros((c1,)),
        "conv2": jax.random.normal(k2, (5, 5, c1, c2)) * 0.1,
        "bc2": jnp.zeros((c2,)),
        "w1": jax.random.normal(k3, (flat, fc)) / jnp.sqrt(flat),
        "b1": jnp.zeros((fc,)),
        "w2": jax.random.normal(k4, (fc, n_classes)) / jnp.sqrt(fc),
        "b2": jnp.zeros((n_classes,)),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def cnn_apply(params, x):
    h = jax.lax.conv_general_dilated(
        x, params["conv1"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["bc1"]
    h = _maxpool2(jax.nn.elu(h))
    h = jax.lax.conv_general_dilated(
        h, params["conv2"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["bc2"]
    h = _maxpool2(jax.nn.elu(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.elu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def cnn_loss(params, batch, l2: float = L2_COEFF):
    x, y = batch
    return _xent(cnn_apply(params, x), y) + _l2(params, l2)


def make_problem(kind: str, rng, example_x, n_classes: int):
    """Return (params, loss_fn(params, batch))."""
    if kind == "logreg":
        params = logreg_init(rng, example_x.shape[-1], n_classes)
        return params, partial(logreg_loss)
    if kind == "mlp":
        dim = int(jnp.prod(jnp.asarray(example_x.shape[1:])))
        params = mlp_init(rng, dim, 64, n_classes)
        return params, partial(mlp_loss)
    if kind == "cnn":
        params = cnn_init(rng, n_classes, hw=example_x.shape[1],
                          in_ch=example_x.shape[-1])
        return params, partial(cnn_loss)
    raise ValueError(f"unknown problem kind: {kind}")
