"""Worker data partitioning — i.i.d. and heterogeneous (non-i.i.d.) splits.

The paper stresses that CADA is tailored for *heterogeneous* workers: covtype
is split "randomly into M=20 workers with different number of samples per
worker". We provide:
  * ``uniform_partition``   — equal-size i.i.d. shards (ijcnn1 / MNIST setup);
  * ``dirichlet_partition`` — label-skewed shards via Dir(alpha) mixing, the
    standard federated-learning heterogeneity knob;
  * ``random_sizes_partition`` — i.i.d. labels, unequal sizes (covtype setup).

All return a list of index arrays (one per worker). For the jittable engine we
then right-pad each shard to a common length with wraparound so a (M, n_shard)
index matrix can be gathered on device.
"""
from __future__ import annotations

import numpy as np


def uniform_partition(n: int, m: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, m)]


def random_sizes_partition(n: int, m: int, seed: int = 0,
                           min_frac: float = 0.3) -> list[np.ndarray]:
    if m > n:
        raise ValueError(f"cannot split {n} examples into {m} non-empty "
                         "shards")
    rng = np.random.default_rng(seed)
    w = min_frac + rng.random(m)
    w = w / w.sum()
    # guarantee every shard >= 1 whatever the weights: give each worker one
    # example up front and share the remaining n-m by weight, handing the
    # rounding remainder to the largest fractional parts. (The previous
    # ``sizes[-1] = n - sizes[:-1].sum()`` underflowed to <= 0 when m was
    # close to n: every earlier shard is clamped to >= 1, so their sum
    # could reach n before the last worker was served.)
    frac = w * (n - m)
    sizes = 1 + np.floor(frac).astype(int)
    rem = n - sizes.sum()
    if rem:
        sizes[np.argsort(-(frac - np.floor(frac)), kind="stable")[:rem]] += 1
    idx = rng.permutation(n)
    out, s = [], 0
    for sz in sizes:
        out.append(np.sort(idx[s:s + sz]))
        s += sz
    return out


def dirichlet_partition(labels: np.ndarray, m: int, alpha: float = 0.3,
                        seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    shards: list[list[int]] = [[] for _ in range(m)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        probs = rng.dirichlet([alpha] * m)
        cuts = (np.cumsum(probs) * len(idx)).astype(int)[:-1]
        for w, part in enumerate(np.split(idx, cuts)):
            shards[w].extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]


def pad_to_matrix(shards: list[np.ndarray], seed: int = 0) -> np.ndarray:
    """(M, n_max) index matrix; short shards wrap around (with-replacement).

    The wrap fill is a seeded random subset of the shard, NOT its head:
    every example appears either ⌊n_max/len(s)⌋ or ⌊n_max/len(s)⌋+1 times,
    so per-example sampling probability within a worker is uniform to
    within one part in ``len(s)``. (A head-truncated ``np.tile`` gave the
    first ``n_max % len(s)`` examples a whole extra replica — on unequal
    shards, the paper's covtype setup, that systematically oversampled
    head-of-shard examples.)
    """
    n_max = max(len(s) for s in shards)
    rng = np.random.default_rng(seed)
    out = np.zeros((len(shards), n_max), dtype=np.int64)
    for i, s in enumerate(shards):
        if len(s) == 0:
            raise ValueError(f"worker {i} received an empty shard")
        reps, rem = divmod(n_max, len(s))
        fill = np.tile(s, reps)
        if rem:
            fill = np.concatenate([fill, rng.permutation(s)[:rem]])
        out[i] = fill
    return out
