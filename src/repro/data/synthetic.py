"""Synthetic datasets standing in for the paper's covtype / ijcnn1 / MNIST.

The container is offline, so we generate statistically similar workloads:
  * ``covtype_like``  — 7-class, 54-dim, heterogeneous worker partitions
    (paper: 581k samples, 20 workers, random unequal split).
  * ``ijcnn1_like``   — binary, 22-dim, uniform partitions (paper: 91.7k,
    10 workers).
  * ``mnist_like``    — 10-class, 28x28 images for the CNN/MLP experiments.
  * ``lm_tokens``     — zipfian token streams for the LM architectures.

Every generator is deterministic in (seed, sizes) and returns plain numpy on
host; per-worker minibatch sampling happens in `repro.core.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Dataset:
    x: np.ndarray  # features  (n, ...) float32
    y: np.ndarray  # labels    (n,)    int32
    n_classes: int

    @property
    def n(self) -> int:
        return self.x.shape[0]


def _cluster_classification(rng, n, dim, n_classes, noise=1.0, margin=2.0):
    """Gaussian class clusters + label noise — logistic-regression friendly."""
    centers = rng.normal(size=(n_classes, dim)) * margin
    y = rng.integers(0, n_classes, size=n)
    x = centers[y] + rng.normal(size=(n, dim)) * noise
    # sprinkle 1% label noise so the optimum has non-zero loss (stochastic
    # gradients keep non-vanishing variance, the regime the paper targets)
    flip = rng.random(n) < 0.01
    y = np.where(flip, rng.integers(0, n_classes, size=n), y)
    return x.astype(np.float32), y.astype(np.int32)


def covtype_like(n: int = 20000, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    x, y = _cluster_classification(rng, n, dim=54, n_classes=7, noise=1.5)
    return Dataset(x=x, y=y, n_classes=7)


def ijcnn1_like(n: int = 10000, seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    x, y = _cluster_classification(rng, n, dim=22, n_classes=2, noise=1.2)
    return Dataset(x=x, y=y, n_classes=2)


def mnist_like(n: int = 4096, seed: int = 2) -> Dataset:
    """28x28 'digit blobs': class-dependent low-rank images + pixel noise."""
    rng = np.random.default_rng(seed)
    n_classes = 10
    bases = rng.normal(size=(n_classes, 4, 28 * 28)).astype(np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    coef = rng.normal(size=(n, 4)).astype(np.float32)
    x = np.einsum("nk,nkd->nd", coef, bases[y]) / 4.0
    x += rng.normal(size=x.shape).astype(np.float32) * 0.3
    x = x.reshape(n, 28, 28, 1)
    return Dataset(x=x, y=y, n_classes=n_classes)


def lm_tokens(n_tokens: int, vocab: int, seed: int = 3,
              zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed token ids — realistic rank-frequency for LM smoke."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_a, size=n_tokens)
    return np.minimum(ranks - 1, vocab - 1).astype(np.int32)
