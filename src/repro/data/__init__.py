from repro.data import partition, synthetic
from repro.data.synthetic import (
    Dataset, covtype_like, ijcnn1_like, lm_tokens, mnist_like,
)
from repro.data.partition import (
    dirichlet_partition, pad_to_matrix, random_sizes_partition,
    uniform_partition,
)

__all__ = [
    "partition", "synthetic", "Dataset",
    "covtype_like", "ijcnn1_like", "lm_tokens", "mnist_like",
    "dirichlet_partition", "pad_to_matrix", "random_sizes_partition",
    "uniform_partition",
]
