"""Distributed runtime: sharding policies, the hierarchical CADA trainer,
and the serving (prefill/decode) step builders."""
from repro.distributed.sharding import (  # noqa: F401
    batch_pspecs, cache_pspecs, param_pspecs, to_named, wants_fsdp,
)
