"""Continuous-batching decode scheduler (serving substrate).

Lock-step decode wastes slots when sequences finish at different lengths.
This scheduler keeps a fixed-size slot pool over ONE jitted decode step
(static shapes — no recompiles): finished or empty slots are refilled from
the request queue each step by resetting that slot's cache columns and
feeding the new prompt through a per-slot prefill.

Slot state lives host-side (lengths, request ids); device state is the
(B-slotted) DecodeCache plus a per-slot "active" mask fed to the sampler.
This is the standard production pattern (vLLM-style, simplified to fixed
slots) adapted to the pure-functional cache: slot resets are
`cache.at[slot].set(fresh)` tree updates.

CPU-tested end to end in tests/test_scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import DecodeCache, decode_step, init_cache, prefill


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (len,) int32 token ids
    max_new: int = 32
    eos_id: int | None = None
    out: list = field(default_factory=list)
    done: bool = False


class DecodeScheduler:
    """Fixed-slot continuous batching over a single model.

    NOTE: per-slot position tracking requires per-slot RoPE positions; the
    current decode_step applies one global cache index, so the scheduler
    left-pads every slot to a common origin by restarting the POOL when a
    slot is refilled mid-flight would desync positions. We instead keep a
    per-slot prefill cache and merge: each refill prefixes its own prompt
    into the slot's cache columns at the CURRENT global index (absolute
    positions stay consistent because prefill() returns slot_pos metadata
    per column). For simplicity and exactness this implementation refills
    only BETWEEN rounds: a round runs until every slot finishes, new
    requests then fill all free slots at once (round-based continuous
    batching). Fully per-step refill needs per-slot index support in
    decode_step — tracked as future work.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_seq: int = 256,
                 sample_fn: Callable | None = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._sample = sample_fn or (lambda logits, key:
                                     jnp.argmax(logits, axis=-1))
        self._prefill = jax.jit(
            lambda p, t: prefill(cfg, p, tokens=t, max_seq=max_seq))
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, tokens=t))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------- rounds
    def _next_batch(self) -> list[Request]:
        batch = self.queue[:self.n_slots]
        self.queue = self.queue[self.n_slots:]
        return batch

    def run_round(self, key=None) -> list[Request]:
        """Serve one round: fill all slots, decode until every request in
        the round finishes (or hits max_new). Returns finished requests."""
        batch = self._next_batch()
        if not batch:
            return []
        key = key if key is not None else jax.random.PRNGKey(0)

        # right-pad prompts to a common length (shortest-prompt tokens are
        # repeats of the last token — masked out of the output)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((self.n_slots, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt
            toks[i, len(r.prompt):] = r.prompt[-1] if len(r.prompt) else 0
        logits, cache = self._prefill(self.params, jnp.asarray(toks))

        active = np.array([True] * len(batch)
                          + [False] * (self.n_slots - len(batch)))
        remaining = np.array([r.max_new for r in batch]
                             + [0] * (self.n_slots - len(batch)))
        key, sub = jax.random.split(key)
        nxt = self._sample(logits, sub)
        steps = 0
        while active.any() and steps < max(r.max_new for r in batch):
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(batch):
                if active[i]:
                    r.out.append(int(nxt_np[i]))
                    remaining[i] -= 1
                    if remaining[i] <= 0 or (r.eos_id is not None
                                             and nxt_np[i] == r.eos_id):
                        active[i] = False
                        r.done = True
            if not active.any():
                break
            logits, cache = self._decode(self.params, cache, nxt)
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
            steps += 1
        for r in batch:
            r.done = True
            self.finished.append(r)
        return batch

    def run(self, key=None) -> list[Request]:
        """Drain the whole queue."""
        while self.queue:
            self.run_round(key)
        return self.finished

    # ------------------------------------------------------------ metrics
    def utilization(self) -> float:
        """Fraction of decode-slot-steps that produced a kept token."""
        if not self.finished:
            return 0.0
        produced = sum(len(r.out) for r in self.finished)
        rounds = int(np.ceil(len(self.finished) / self.n_slots))
        worst = rounds * self.n_slots * max(
            (len(r.out) for r in self.finished), default=1)
        return produced / max(worst, 1)
