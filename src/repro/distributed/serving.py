"""Serving-side step builders: sharded prefill and lock-step batched decode.

CADA is a training-time technique; the inference shapes (prefill_32k,
decode_32k, long_500k) exercise the same distribution substrate — TP over
heads/d_inner, batch over the data axes, ring-buffer KV / SSM state caches —
so the framework serves every assigned architecture from the same configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (
    batch_pspecs, cache_pspecs, param_pspecs, to_named,
)
from repro.models.config import ModelConfig
from repro.models.model import decode_step, init_cache, prefill


def _serving_param_pspecs(cfg: ModelConfig, mesh):
    """Serving NEVER uses FSDP: a decode step would all-gather the weights
    for every generated token (measured: 64 GB/chip/token of all-gather on
    llama3-405b — §Perf). TP-only keeps weights resident; if the TP shard
    alone exceeds HBM the model needs a bigger model axis, not FSDP."""
    return param_pspecs(cfg, mesh, fsdp=False)


def jit_prefill_step(cfg: ModelConfig, mesh, batch_sds: dict):
    """jit'd prefill: (params, inputs) -> (last logits, primed cache)."""
    psp = to_named(mesh, _serving_param_pspecs(cfg, mesh))
    bsh = to_named(mesh, batch_pspecs(batch_sds, mesh))

    def step(params, inputs):
        return prefill(cfg, params,
                       tokens=inputs.get("tokens"),
                       embeds=inputs.get("embeds"),
                       positions=inputs.get("positions"))

    cache_sds = jax.eval_shape(step, _abstract_params(cfg), batch_sds)[1]
    csp = to_named(mesh, cache_pspecs(cfg, cache_sds, mesh))
    return jax.jit(step, in_shardings=(psp, bsh),
                   out_shardings=(None, csp))


def jit_decode_step(cfg: ModelConfig, mesh, batch: int, seq: int):
    """jit'd single-token decode against a cache primed at ``seq``.

    Returns (jitted step, cache shardings). Step signature:
      (params, cache, inputs) -> (logits (B, V), new cache).
    """
    psp = to_named(mesh, _serving_param_pspecs(cfg, mesh))
    cache_sds = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
    csp = to_named(mesh, cache_pspecs(cfg, cache_sds, mesh))

    if cfg.embed_input:
        inputs_sds = {"tokens": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    else:
        inputs_sds = {"embeds": jax.ShapeDtypeStruct(
            (batch, 1, cfg.d_model), cfg.jnp_dtype)}
    bsh = to_named(mesh, batch_pspecs(inputs_sds, mesh))

    def step(params, cache, inputs):
        return decode_step(cfg, params, cache,
                           tokens=inputs.get("tokens"),
                           embeds=inputs.get("embeds"))

    jitted = jax.jit(step, in_shardings=(psp, csp, bsh),
                     out_shardings=(None, csp))
    return jitted, cache_sds, inputs_sds


def _abstract_params(cfg: ModelConfig):
    from repro.models.model import abstract_params
    return abstract_params(cfg)
