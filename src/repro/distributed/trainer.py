"""Hierarchical CADA: the paper's server/worker protocol mapped onto TPU
pods (DESIGN.md §3).

The paper's "worker" becomes the unit that actually pays for communication:
  * multi-pod mesh (pod, data, model): worker = pod (M = n_pods). Within a
    pod gradients average over cheap ICI; ACROSS pods the all-reduce of the
    masked innovations (eq. 3) is what CADA gates — skipped rounds eliminate
    the DCN transfer of a full fp32 gradient.
  * single-pod mesh (data, model): worker = data-parallel group (M = 16),
    matching the paper's M ≈ 10-20; the gated collective rides ICI.

This module keeps ONLY the pod concerns: sharding specs, microbatch
accumulation, the pod-manual shard_map, and the fused AMSGrad stream. The
communication round itself — rule LHS/RHS, staleness cap, eq. 3 innovation
aggregation, quantize hook, accounting — is
:func:`repro.core.comm.comm_round`, the SAME core the reference engine
(core/engine.py) runs, so the two implementations of Algorithm 1 cannot
drift. Per-rule behaviour (eq. 5/7/10 and beyond-paper rules) lives in the
:mod:`repro.core.comm` strategy objects; there is no rule dispatch here.

Everything is a single pjit'd step: per-worker gradients are a `vmap` over
the M-leading axis (sharded over the worker axis of the mesh), per-worker
stale state is stored with that same M-leading sharding so each worker's
copy lives on its own slice of the machine, and the server's AMSGrad update
runs redundantly on every chip (standard SPMD "virtual server").

State-memory policy knobs (production necessities for the 314B/405B archs):
  * ``cada_dtype``   — storage dtype of {∇ (nabla), per-worker stale trees};
    comm_round casts the innovation to this dtype BEFORE the cross-worker
    mean, so it is the wire format of the gated collective (bf16 halves
    DCN bytes — LAQ-adjacent, beyond-paper)
  * ``microbatches`` — gradient accumulation inside the step (activation
    memory /= microbatches at fixed global batch)
  * ``moments_dtype`` — {h, v̂} storage on the flat plane (bf16 halves the
    8P-byte moment footprint; math stays fp32 — kernels/cada_update.py)
  * ``state_fsdp_axes`` / ``shard_cada_state`` / FSDP — ZeRO the FLAT
    state planes over those mesh axes (see ``flat_state_axes``): the
    (n_flat,) server planes split into equal contiguous shards, the
    (M, n_flat) worker planes shard worker axis × remaining state axes,
    and the fused kernels run shard-local with psum'd scalar reductions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import flat as F
from repro.core.comm import (CommState, comm_round, comm_state_specs,
                             init_comm_state, nabla_f32, record_progress,
                             strategy_for)
from repro.core.rules import CommRule
from repro.kernels import ops as kops
from repro.launch.mesh import DATA, POD, partial_auto_shard_map
from repro.models.config import ModelConfig
from repro.models.model import abstract_params, init_params, lm_loss
from repro.distributed.sharding import (FlatSharding, param_pspecs,
                                        to_named, wants_fsdp)


@dataclass(frozen=True)
class TrainHParams:
    rule: CommRule = field(default_factory=lambda: CommRule(kind="cada2"))
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    microbatches: int = 1
    cada_dtype: str = "float32"     # nabla / stale-tree storage
    moments_dtype: str = "float32"  # {h, v̂} storage (bf16 = beyond-paper;
    #   lives on the flat plane: the fused kernel is dtype-parametric)
    fused: bool = True              # flat-buffer state plane + fused
    #   AMSGrad/CADA server update (core/flat.py) — the ONLY state plane:
    #   every sharding policy (FSDP, ZeRO'd/data-sharded state, bf16
    #   moments) runs on sharded flat planes (see flat_state_axes).
    #   fused=False is an explicit DEBUG flag selecting the per-leaf
    #   pytree reference implementation (the readable oracle the parity
    #   gates pin the flat plane against).
    fsdp: bool | None = None        # None = auto (sharding.wants_fsdp)
    fsdp_axes: tuple = ("data",)    # params: gathered per layer per micro
    state_fsdp_axes: tuple = ()     # () = same as fsdp_axes. Set to
    #   ("data","pod") to ZeRO the OPTIMIZER state across pods while params
    #   stay pod-local: state is touched once per step, so the pod-spanning
    #   reshard rides DCN once — vs per-layer-per-microbatch param gathers
    #   (measured 1.9e3 s/step on llama3-405b — §Perf).
    shard_cada_state: bool = False  # shard nabla/stale trees over "data"
    #                                 even when params don't FSDP (§Perf)
    group_evals: bool = False       # second eval as ≤R broadcast-point
    #   evaluations grouped by stale-iterate ring slot (indexed rules on
    #   the flat plane). Weight traffic M× → R×, arithmetic × occupancy —
    #   opt in when the eval is weight-bandwidth-bound and R ≪ M.

    @property
    def cada_jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            self.cada_dtype]

    @property
    def moments_jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            self.moments_dtype]


class DistTrainState(NamedTuple):
    step: jnp.ndarray        # k
    params: Any              # θ^k
    h: Any                   # first moment (fp32)
    vhat: Any                # running max second moment (fp32)
    comm: Any                # CommState (None for stateless rules: the
    #                          'always' baseline keeps no innovation state)


# ------------------------------------------------------------------- specs

def worker_axis_name(mesh) -> str:
    return POD if POD in mesh.shape else DATA


def flat_state_axes(cfg: ModelConfig, mesh, hp: TrainHParams) -> tuple:
    """Mesh axes the (n_flat,) flat SERVER planes (θ̂/h/v̂/∇) shard over.

    Resolution order mirrors the reference plane's memory policy:
    explicit ``state_fsdp_axes`` (ZeRO the state wider than the params —
    e.g. ("data", "pod") on the 314B/405B archs), then
    ``shard_cada_state`` (("data",)), then the param FSDP axes when FSDP
    is on (explicitly or by ``sharding.wants_fsdp`` size auto-detection),
    else replicate. Axes absent from the mesh (or of size 1) are dropped,
    so the same hparams resolve sanely on every mesh.
    """
    if not hp.fused:
        return ()
    if hp.state_fsdp_axes:
        axes = hp.state_fsdp_axes
    elif hp.shard_cada_state:
        axes = (DATA,)
    elif hp.fsdp or (hp.fsdp is None and wants_fsdp(cfg, mesh)):
        axes = hp.fsdp_axes
    else:
        return ()
    return tuple(a for a in axes if a in mesh.shape and mesh.shape[a] > 1)


def flat_sharding(cfg: ModelConfig, mesh, hp: TrainHParams) -> FlatSharding:
    """The resolved :class:`sharding.FlatSharding` for (cfg, mesh, hp) —
    the ONE object the layout pad divisor (``.shards``), the plane specs
    (``.col_axes`` / ``.server_spec``), and the shard-local kernels all
    read, so they cannot disagree. ``axes`` is empty when no state
    sharding applies (every property then degrades to the unsharded
    form)."""
    return FlatSharding(mesh=mesh, waxis=worker_axis_name(mesh),
                        axes=flat_state_axes(cfg, mesh, hp))


def flat_state_shards(cfg: ModelConfig, mesh, hp: TrainHParams) -> int:
    """State-shard count of the flat plane on ``mesh`` — the divisor
    ``FlatLayout.n_flat`` is padded to. Pass this as ``shards=`` to
    ``init_train_state`` / ``abstract_train_state`` when pairing them with
    ``jit_train_step`` (which resolves it from the same mesh): the state
    structures must agree."""
    return flat_sharding(cfg, mesh, hp).shards


def flat_layout(cfg: ModelConfig, shards: int = 1) -> F.FlatLayout:
    """The trainer's flat layout for ``cfg`` at a given state-shard count
    (checkpoint tooling uses this to reshard across shard counts)."""
    return F.layout_of(abstract_params(cfg), shards=shards)


def _strip_axis(spec: P, axis: str) -> P:
    """Remove ``axis`` from every dim of a PartitionSpec."""
    dims = []
    for d in spec:
        if d == axis:
            dims.append(None)
        elif isinstance(d, tuple):
            kept = tuple(a for a in d if a != axis)
            dims.append(kept if kept else None)
        else:
            dims.append(d)
    return P(*dims)


def _prepend_worker(specs, axis: str):
    """(M, ...)-leading per-worker tree: worker axis leads; inner dims keep
    their param sharding minus the worker axis (no axis may repeat)."""
    return jax.tree.map(
        lambda s: P(axis, *_strip_axis(s, axis)), specs,
        is_leaf=lambda x: isinstance(x, P))


def train_state_specs(cfg: ModelConfig, mesh, hp: TrainHParams
                      ) -> DistTrainState:
    psp = param_pspecs(cfg, mesh, hp.fsdp, hp.fsdp_axes)
    waxis = worker_axis_name(mesh)
    strategy = strategy_for(hp.rule)
    if hp.fused:
        # flat plane: gradient-shaped state needs only two spec shapes —
        # (n_flat,) server planes sharded over the state axes (ZeRO) and
        # worker-leading (M, n_flat) planes sharded worker axis × the
        # remaining state axes; parameter-shaped extras keep param specs.
        fs = flat_sharding(cfg, mesh, hp)
        return DistTrainState(
            step=P(),
            params=psp,
            h=fs.server_spec(), vhat=fs.server_spec(),
            comm=(None if strategy.stateless else
                  F.flat_comm_state_specs(
                      strategy, psp, _prepend_worker(psp, waxis),
                      waxis, P, state_axes=fs.axes,
                      col_axes=fs.col_axes)),
        )
    wsp = _prepend_worker(psp, waxis)
    # optimizer moments may ZeRO over more axes than params (see hparams)
    msp = (param_pspecs(cfg, mesh, True, hp.state_fsdp_axes)
           if hp.state_fsdp_axes else psp)
    # gradient-shaped CADA state has no compute locality: shard it over
    # every axis available regardless of the params' FSDP choice (§Perf —
    # cuts the cross-pod innovation all-reduce per-chip volume).
    gsp = (param_pspecs(cfg, mesh, True, ("data",))
           if hp.shard_cada_state else psp)
    gwsp = _prepend_worker(gsp, waxis)
    return DistTrainState(
        step=P(),
        params=psp,
        h=msp, vhat=msp,
        comm=(None if strategy.stateless else
              comm_state_specs(strategy, psp, wsp, gsp, gwsp, P(None))),
    )


def train_batch_specs(mesh, local_steps: int = 1) -> dict:
    """Worker-split batch: leaves are (M, b_m, ...); M shards over the
    worker axis, b_m over 'data' on the multi-pod mesh (where the worker is
    a whole pod). M-RoPE positions are (M, 3, b_m, S). With
    ``local_steps`` H > 1 (delta-payload rules) every leaf gains a leading
    replicated local-step axis: (H, M, b_m, ...)."""
    waxis = worker_axis_name(mesh)
    inner = DATA if waxis == POD else None
    lead = (None,) if local_steps > 1 else ()

    def spec_for(key, ndim):
        ndim -= len(lead)
        if key == "positions":
            return P(*lead, waxis, None, inner, *(None,) * (ndim - 3))
        return P(*lead, waxis, inner, *(None,) * (ndim - 2))

    return spec_for


def worker_split(batch: dict, m: int, local_steps: int = 1) -> dict:
    """Global batch -> (M, b_m, ...) per-worker leading axis (positions:
    (3, B, S) -> (M, 3, b_m, S)). ``local_steps`` H > 1 (delta-payload
    rules) carves the global batch into H per-local-step slices FIRST:
    (H, M, b_m, ...) with b_m = B / (H · M) — one round consumes the same
    global sample count whatever the payload cadence."""
    hm = local_steps * m
    out = {}
    for key, leaf in batch.items():
        if key == "positions":
            three, b = leaf.shape[0], leaf.shape[1]
            rest = leaf.shape[2:]
            split = leaf.reshape((three, hm, b // hm) + rest).swapaxes(0, 1)
        else:
            b = leaf.shape[0]
            split = leaf.reshape((hm, b // hm) + leaf.shape[1:])
        out[key] = (split.reshape((local_steps, m) + split.shape[1:])
                    if local_steps > 1 else split)
    return out


def worker_split_abstract(batch: dict, m: int, local_steps: int = 1
                          ) -> dict:
    """ShapeDtypeStruct version of ``worker_split`` (dry-run path)."""
    lead = (local_steps,) if local_steps > 1 else ()
    hm = local_steps * m
    out = {}
    for key, leaf in batch.items():
        if key == "positions":
            three, b = leaf.shape[0], leaf.shape[1]
            shp = lead + (m, three, b // hm) + leaf.shape[2:]
        else:
            b = leaf.shape[0]
            shp = lead + (m, b // hm) + leaf.shape[1:]
        out[key] = jax.ShapeDtypeStruct(shp, leaf.dtype)
    return out


# ------------------------------------------------------------------- state

def init_train_state(cfg: ModelConfig, hp: TrainHParams, m: int, rng,
                     shards: int = 1) -> DistTrainState:
    """``shards`` is the flat-plane state-shard count (pad divisor of
    ``n_flat``). Mesh-free callers keep the default 1; when pairing with
    ``jit_train_step`` pass ``flat_state_shards(cfg, mesh, hp)`` so the
    state structure matches the compiled step's."""
    params = init_params(cfg, rng)
    strategy = strategy_for(hp.rule)
    # h and v̂ are allocated as DISTINCT buffers throughout: the jitted
    # step donates the state, and aliased leaves trip XLA's
    # donate-the-same-buffer-twice check.
    if hp.fused:
        layout = F.layout_of(params, shards=shards)
        return DistTrainState(
            step=jnp.zeros([], jnp.int32),
            params=params,
            h=jnp.zeros((layout.n_flat,), hp.moments_jnp_dtype),
            vhat=jnp.zeros((layout.n_flat,), hp.moments_jnp_dtype),
            comm=(None if strategy.stateless else
                  F.init_flat_comm_state(strategy, layout, params, m,
                                         grad_dtype=hp.cada_jnp_dtype)),
        )

    def zeros_m():
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, hp.moments_jnp_dtype), params)
    return DistTrainState(
        step=jnp.zeros([], jnp.int32),
        params=params,
        h=zeros_m(), vhat=zeros_m(),
        comm=(None if strategy.stateless else
              init_comm_state(strategy, params, m,
                              grad_dtype=hp.cada_jnp_dtype)),
    )


def abstract_train_state(cfg: ModelConfig, hp: TrainHParams, m: int,
                         shards: int = 1):
    return jax.eval_shape(
        partial(init_train_state, cfg, hp, m, shards=shards),
        jax.random.PRNGKey(0))


# -------------------------------------------------------------------- step

def _amsgrad_apply(params, h, vhat, grad, hp: TrainHParams):
    """The paper's (2a)-(2c) in sharded jnp (XLA fuses the stream); returns
    (params', h', vhat', ||Δθ||²). Math in fp32; storage dtype follows the
    incoming state (hp.moments_dtype)."""
    h_new = jax.tree.map(
        lambda m, g: (hp.b1 * m.astype(jnp.float32)
                      + (1 - hp.b1) * g.astype(jnp.float32)).astype(m.dtype),
        h, grad)
    vhat_new = jax.tree.map(
        lambda s, g: jnp.maximum(
            hp.b2 * s.astype(jnp.float32)
            + (1 - hp.b2) * jnp.square(g.astype(jnp.float32)),
            s.astype(jnp.float32)).astype(s.dtype),
        vhat, grad)
    upd = jax.tree.map(
        lambda m, s: (-hp.lr * m.astype(jnp.float32)
                      / jnp.sqrt(hp.eps + s.astype(jnp.float32))),
        h_new, vhat_new)
    new_params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, upd)
    dsq = sum(jnp.sum(jnp.square(u)) for u in jax.tree.leaves(upd))
    return new_params, h_new, vhat_new, dsq


def make_pod_vgrads(cfg: ModelConfig, hp: TrainHParams, mesh):
    """Per-worker gradients as a PARTIAL-AUTO shard_map: manual over the
    pod axis, auto (GSPMD) over data/model.

    A plain `vmap` over the worker axis lets the partitioner replicate the
    per-pod gradient computation across pods (measured: 2-4× total-flop
    inflation on the 2×16×16 mesh — §Perf). The manual pod axis makes the
    locality structural: each pod can only ever compute its own worker's
    gradient.
    """
    psp = param_pspecs(cfg, mesh, hp.fsdp, hp.fsdp_axes)

    def manual_only(spec):
        dims = []
        for d in spec:
            if d == POD:
                dims.append(POD)
            elif isinstance(d, tuple) and POD in d:
                dims.append(POD)
            else:
                dims.append(None)
        return P(*dims)

    params_in = jax.tree.map(manual_only, psp,
                             is_leaf=lambda x: isinstance(x, P))
    wparams_in = jax.tree.map(lambda s: P(POD, *s), params_in,
                              is_leaf=lambda x: isinstance(x, P))

    def _shardmapped(f, in_specs):
        return partial_auto_shard_map(f, mesh, in_specs,
                                      (P(POD), P(POD)), (POD,))

    def make(worker_grad):
        def body_bcast(params, batch):
            wb = jax.tree.map(lambda x: x[0], batch)
            loss, g = worker_grad(params, wb)
            return (jnp.asarray(loss)[None],
                    jax.tree.map(lambda x: x[None], g))

        def body_per(wparams, batch):
            wp = jax.tree.map(lambda x: x[0], wparams)
            wb = jax.tree.map(lambda x: x[0], batch)
            loss, g = worker_grad(wp, wb)
            return (jnp.asarray(loss)[None],
                    jax.tree.map(lambda x: x[None], g))

        vgrad = _shardmapped(body_bcast, (params_in, P(POD)))
        vgrad_per = _shardmapped(body_per, (wparams_in, P(POD)))
        return vgrad, vgrad_per

    return make


def make_worker_grad(cfg: ModelConfig, hp: TrainHParams,
                     micro_constrain=None):
    """One worker's mean LM gradient, with microbatch accumulation —
    shared by the dense mesh step and the federated cohort step, so the
    two planes compute identical per-worker gradients."""
    if micro_constrain is None:
        micro_constrain = lambda mb: mb  # noqa: E731

    def loss_fn(params, wbatch):
        return lm_loss(cfg, params, wbatch)[0]

    def worker_grad(params, wbatch):
        bm = jax.tree.leaves(wbatch)[0].shape[0]
        nm = min(hp.microbatches, bm)
        while bm % nm:  # largest feasible count <= requested (static)
            nm -= 1
        if nm == 1:
            return jax.value_and_grad(loss_fn)(params, wbatch)

        def split(leaf, batch_axis=0):
            b = leaf.shape[batch_axis]
            return leaf.reshape(leaf.shape[:batch_axis] + (nm, b // nm)
                                + leaf.shape[batch_axis + 1:])

        mb = micro_constrain(
            {k: (split(v, 1).swapaxes(0, 1) if k == "positions"
                 else split(v)) for k, v in wbatch.items()})

        def acc(carry, micro):
            loss_a, g_a = carry
            loss, g = jax.value_and_grad(loss_fn)(params, micro)
            return (loss_a + loss,
                    jax.tree.map(jnp.add, g_a, g)), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_s, g_s), _ = jax.lax.scan(acc, (0.0, zeros), mb)
        return loss_s / nm, jax.tree.map(lambda g: g / nm, g_s)

    return worker_grad


def make_train_step(cfg: ModelConfig, hp: TrainHParams, m: int,
                    wconstrain=None, vgrad_factory=None,
                    micro_constrain=None, shards: int = 1,
                    flat_shard=None):
    """Pure (state, batch) -> (state, metrics) hierarchical-CADA step.

    ``batch`` leaves carry an (M,)-leading worker axis. Shard with
    ``train_state_specs`` / ``train_batch_specs`` and wrap in jax.jit.
    ``wconstrain`` (optional) pins per-worker gradient trees via
    with_sharding_constraint; ``vgrad_factory`` (optional, from
    ``make_pod_vgrads``) replaces the worker vmap with a pod-manual
    shard_map; ``micro_constrain`` (optional) re-pins the data-axis
    sharding after the microbatch reshape — without it GSPMD partially
    replicates the per-pod batch (measured 4× flop inflation — §Perf).
    ``shards`` / ``flat_shard`` (a ``sharding.FlatSharding``) describe the
    flat state plane's sharding: the layout pads to ``shards`` equal
    slices and the fused kernels + LHS norms run shard-local with psum'd
    scalars. Mesh-free callers leave both at their defaults (unsharded
    plane, plain whole-plane ops).
    """
    strategy = strategy_for(hp.rule)
    if wconstrain is None:
        wconstrain = lambda t: t  # noqa: E731

    worker_grad = make_worker_grad(cfg, hp, micro_constrain)

    if vgrad_factory is not None:
        vgrad_raw, vgrad_per_raw = vgrad_factory(worker_grad)
    else:
        vgrad_raw = jax.vmap(worker_grad, in_axes=(None, 0))
        vgrad_per_raw = jax.vmap(worker_grad, in_axes=(0, 0))

    def vgrad(params, batch):
        losses, grads = vgrad_raw(params, batch)
        return losses, wconstrain(grads)

    def vgrad_per(wparams, batch):
        losses, grads = vgrad_per_raw(wparams, batch)
        return losses, wconstrain(grads)

    use_flat = hp.fused
    if use_flat:
        layout = F.layout_of(abstract_params(cfg), shards=shards)
        # the stacked two-point evaluation (fresh + second as a broadcast
        # 2-way eval axis, batch NOT copied — flat.stacked_two_point_eval)
        # applies only on the vmap route: the pod-manual shard_map pins
        # the M-leading axis in its in-specs. Since the broadcast-axis
        # rewrite it wins on CPU as well (see CADAEngine's fuse_evals
        # note), so it is on wherever it applies — matching the engine's
        # default keeps the parity contract bit-exact.
        fuse_evals = vgrad_factory is None

        def fused_update(pflat, h, vhat, grad_flat):
            """Fused AMSGrad/CADA server update on the packed plane —
            Pallas on TPU, fused flat jnp elsewhere (kernels/ops.py);
            shard-local with one psum'd ‖Δθ‖² when the plane is sharded."""
            theta, h2, vh2, dsq = kops.fused_amsgrad_flat(
                pflat, h, vhat, grad_flat, hp.lr,
                b1=hp.b1, b2=hp.b2, eps=hp.eps, shard=flat_shard)
            return layout.unpack(layout.cast_roundtrip(theta)), h2, vh2, dsq

        def pack_server(params):
            """θ^k packed onto the (possibly ZeRO-sharded) server plane."""
            pflat = layout.pack(params)
            if flat_shard is not None:
                pflat = flat_shard.constrain_server(pflat)
            return pflat

    # ------------- stateless rules (always ⇒ distributed Adam/AMSGrad):
    # no innovation state is materialized — the production path for the
    # 314B/405B single-pod fallback, where M stale gradient copies would
    # not fit in HBM.
    if strategy.stateless:
        def step_always(state: DistTrainState, batch):
            losses, fresh = vgrad(state.params, batch)
            if use_flat:
                grad_flat = jnp.mean(layout.pack_worker(fresh), axis=0)
                if flat_shard is not None:
                    grad_flat = flat_shard.constrain_server(grad_flat)
                params, h, vhat, dsq = fused_update(
                    pack_server(state.params), state.h, state.vhat,
                    grad_flat)
            else:
                grad = jax.tree.map(lambda g: jnp.mean(g, axis=0), fresh)
                params, h, vhat, dsq = _amsgrad_apply(
                    state.params, state.h, state.vhat, grad, hp)
            new_state = state._replace(step=state.step + 1, params=params,
                                       h=h, vhat=vhat)
            return new_state, {
                "loss": jnp.mean(losses),
                "uploads": jnp.asarray(m, jnp.int32),
                "skip_rate": jnp.zeros([], jnp.float32),
                "upload_mask": jnp.ones((m,), bool),
                "staleness": jnp.ones((m,), jnp.int32),
                "dtheta_sq": dsq,
            }
        return step_always

    # ------------- rules with innovation state: the shared Algorithm-1
    # core drives the round; this function only applies the server update.
    # Delta-payload rules (local_momentum / fedadam) ride the SAME path:
    # the round returns the mean accumulated model delta as nabla, and the
    # trainer's fused AMSGrad server consumes it — the "FedAMSGrad"
    # variant (server momentum over deltas; the engine/sim planes run the
    # rules' prescribed sgd(1.0)/Adam servers — parity oracles live
    # there, not here). Batches then carry a leading (H,) local-step axis
    # (``worker_split(..., local_steps=H)``).
    if use_flat:
        def step_flat(state: DistTrainState, batch):
            k = state.step
            pflat = pack_server(state.params)
            out = F.flat_comm_round(
                strategy, layout, state.comm, state.params, pflat, batch,
                k, vgrad=vgrad, vgrad_per=vgrad_per, fuse_evals=fuse_evals,
                group_evals=hp.group_evals, shard=flat_shard)
            params, h, vhat, dsq = fused_update(
                pflat, state.h, state.vhat, F.nabla_f32(out.comm))
            comm = F.record_progress(out.comm, dsq, k)
            new_state = DistTrainState(step=k + 1, params=params, h=h,
                                       vhat=vhat, comm=comm)
            metrics = {"loss": jnp.mean(out.losses), "dtheta_sq": dsq,
                       **out.metrics}
            return new_state, metrics

        return step_flat

    def step(state: DistTrainState, batch):
        k = state.step
        out = comm_round(strategy, state.comm, state.params, batch, k,
                         vgrad=vgrad, vgrad_per=vgrad_per)
        params, h, vhat, dsq = _amsgrad_apply(
            state.params, state.h, state.vhat, nabla_f32(out.comm), hp)
        comm = record_progress(out.comm, dsq, k)
        new_state = DistTrainState(step=k + 1, params=params, h=h,
                                   vhat=vhat, comm=comm)
        metrics = {"loss": jnp.mean(out.losses), "dtheta_sq": dsq,
                   **out.metrics}
        return new_state, metrics

    return step


# ------------------------------------------------------- federated cohort

class CohortTrainState(NamedTuple):
    """Trainer state on the cohort-virtualized plane: the (M, n_flat)
    per-worker planes live in a host :class:`repro.core.flat.WorkerPool`;
    this holds only O(n) server planes + O(M) scalar vectors."""
    step: jnp.ndarray
    params: Any
    h: jnp.ndarray           # (n_flat,) first moment
    vhat: jnp.ndarray        # (n_flat,) running max second moment
    server: Any              # flat.CohortServerState
    params_flat: jnp.ndarray


def init_cohort_train_state(cfg: ModelConfig, hp: TrainHParams, m: int,
                            rng, *, pool_storage: str = "ram",
                            pool_path: str | None = None):
    """(CohortTrainState, WorkerPool) for M federated workers — device
    memory O(n), host pool O(M·n) (``pool_storage="memmap"`` +
    ``pool_path`` spill it past RAM). Requires the fused plane (the
    cohort round is a flat-plane op; there is no per-leaf cohort oracle
    at the trainer layer — core/flat.py's dense plane is the parity
    oracle)."""
    if not hp.fused:
        raise ValueError("the cohort plane requires fused=True")
    params = init_params(cfg, rng)
    layout = F.layout_of(params)
    params_flat = layout.pack(params)
    strategy = strategy_for(hp.rule)
    server, pool = F.init_cohort_state(
        strategy, layout, params, m, grad_dtype=hp.cada_jnp_dtype,
        params_flat=params_flat, pool_storage=pool_storage,
        pool_path=pool_path)
    state = CohortTrainState(
        step=jnp.zeros([], jnp.int32), params=params,
        h=jnp.zeros((layout.n_flat,), hp.moments_jnp_dtype),
        vhat=jnp.zeros((layout.n_flat,), hp.moments_jnp_dtype),
        server=server, params_flat=params_flat)
    return state, pool


def make_cohort_train_step(cfg: ModelConfig, hp: TrainHParams, m: int):
    """Mesh-free federated LM step: (state, pool, batch, cohort) ->
    (state, metrics).

    Per round only the C sampled workers' rows move: gather from the host
    pool, one :func:`repro.core.flat.flat_comm_round`-equivalent cohort
    round (bit-exact to the dense plane with the cohort's participation
    mask), the fused AMSGrad server update, scatter back. ``batch`` holds
    ONLY cohort rows ((C, b, ...) leaves — at federated M a dense
    (M, b, ·) batch is itself the memory wall). The jitted step donates
    state and rows, so the device never holds two cohort planes.
    Gradients come from the same ``make_worker_grad`` as the mesh step
    (microbatch accumulation included)."""
    if not hp.fused:
        raise ValueError("the cohort plane requires fused=True")
    strategy = strategy_for(hp.rule)
    layout = F.layout_of(abstract_params(cfg))
    worker_grad = make_worker_grad(cfg, hp)
    vgrad = jax.vmap(worker_grad, in_axes=(None, 0))
    vgrad_per = jax.vmap(worker_grad, in_axes=(0, 0))

    built = {}

    def fused_step_for(pool):
        """The jitted fused-block step bound to ``pool``'s plane layout
        (stacking order + storage dtype) — built once per layout. Shared
        by the eager ``train_step`` and the pipelined driver."""
        if pool.plane_dtype is None:
            raise ValueError("the cohort step needs a uniform-dtype pool")
        order, dtype = pool.plane_order, pool.plane_dtype
        key = (order, np.dtype(dtype).str)
        if built.get("key") == key:
            return built["step"]

        def step(state: CohortTrainState, fused, batch, cohort):
            k = state.step
            rows = F.split_fused_rows(fused, order)
            out = F.flat_cohort_round(
                strategy, layout, state.server, rows, state.params,
                state.params_flat, batch, k, cohort, m_total=m,
                vgrad=vgrad, vgrad_per=vgrad_per, fuse_evals=True)
            theta, h, vhat, dsq = kops.fused_amsgrad_flat(
                state.params_flat, state.h, state.vhat,
                out.server.nabla.astype(jnp.float32), hp.lr,
                b1=hp.b1, b2=hp.b2, eps=hp.eps)
            theta = layout.cast_roundtrip(theta)
            server = F.record_progress(out.server, dsq, k)
            new_state = CohortTrainState(
                step=k + 1, params=layout.unpack(theta), h=h, vhat=vhat,
                server=server, params_flat=theta)
            metrics = {"loss": jnp.mean(out.losses), "dtheta_sq": dsq,
                       **out.metrics}
            return new_state, F.stack_fused_rows(out.rows, order,
                                                 dtype), metrics

        built["key"] = key
        built["step"] = jax.jit(step, donate_argnums=(0, 1))
        return built["step"]

    def train_step(state: CohortTrainState, pool, batch, cohort):
        cohort = np.sort(np.asarray(cohort).astype(np.int32))
        jitted = fused_step_for(pool)
        fused = pool.gather_fused(cohort)
        state, out, metrics = jitted(state, fused, batch,
                                     jnp.asarray(cohort))
        pool.scatter_fused(cohort, out)
        return state, metrics

    train_step.fused_step_for = fused_step_for
    return train_step


def run_cohort_train(train_step, state: CohortTrainState, pool, batches,
                     cohorts, *, pipeline: bool = True,
                     metrics_every: int = 8, trace=None,
                     metrics_out: list | None = None):
    """Multi-round cohort driver for the trainer — the federated analogue
    of ``CADAEngine.run_cohort``. ``train_step`` is the callable from
    :func:`make_cohort_train_step`; ``batches`` is a list/tuple of
    per-round cohort batches or a callable ``batches(i, cohort)``.
    ``pipeline=True`` double-buffers transfers (bit-exact to the serial
    ``pipeline=False`` oracle); metrics are fetched every
    ``metrics_every`` rounds. ``trace`` (an ``obs.trace.Tracer`` or
    None) records per-round pipeline spans; ``metrics_out`` (a list)
    receives fetched metrics incrementally, surviving mid-run
    exceptions. Returns (state, list-of-metric-dicts)."""
    cohorts = np.asarray(cohorts, np.int32)
    if callable(batches):
        batch_fn = batches
    else:
        batch_fn = lambda i, _c: batches[i]                 # noqa: E731
    return F.run_cohort_rounds(
        train_step.fused_step_for(pool), state, pool, batch_fn, cohorts,
        pipeline=pipeline, metrics_every=metrics_every, trace=trace,
        metrics_out=metrics_out)


def jit_train_step(cfg: ModelConfig, mesh, hp: TrainHParams):
    """jit the step with explicit in/out shardings for ``mesh``.

    Returns (jitted_step, state_specs, m). Metrics are replicated.
    """
    waxis = worker_axis_name(mesh)
    m = mesh.shape[waxis]
    sspecs = train_state_specs(cfg, mesh, hp)
    # flat-plane sharding: resolved ONCE here, threaded through the layout
    # (pad divisor), the specs above, and the shard-local kernel forms.
    fs = flat_sharding(cfg, mesh, hp)
    shards = fs.shards
    flat_shard = fs if (hp.fused and fs.axes) else None

    # NOTE: constraining the vmapped gradient trees directly
    # (with_sharding_constraint to the worker_grads specs) was measured to
    # be a no-op for locality AND trips an XLA SPMD-partitioner CHECK when
    # combined with data-sharded CADA state — micro_constrain below is the
    # effective (and stable) mechanism. The pod-manual shard_map is opt-in:
    # it crashes the XLA partitioner when combined with FSDP param specs
    # (spmd_partitioner_util.cc:504 CHECK), so it is enabled only for
    # non-FSDP configs. Env switches for §Perf ablations.
    import os as _os
    use_podmap = (waxis == POD
                  and not _os.environ.get("REPRO_NO_PODMAP")
                  and not (hp.fsdp
                           or (hp.fsdp is None and wants_fsdp(cfg, mesh))))
    vgrad_factory = make_pod_vgrads(cfg, hp, mesh) if use_podmap else None

    def micro_constrain(mb):
        if waxis != POD or _os.environ.get("REPRO_NO_MICROCONSTRAIN"):
            return mb  # single-pod: the worker IS the data group

        def spec_for(key, ndim):
            if key == "positions":
                return P(None, None, DATA, *(None,) * (ndim - 3))
            return P(None, DATA, *(None,) * (ndim - 2))

        return {k: jax.lax.with_sharding_constraint(
                    v, to_named(mesh, spec_for(k, v.ndim)))
                for k, v in mb.items()}

    step = make_train_step(
        cfg, hp, m,
        vgrad_factory=vgrad_factory, micro_constrain=micro_constrain,
        shards=shards, flat_shard=flat_shard)
    sshard = jax.tree.map(lambda s: to_named(mesh, s), sspecs,
                          is_leaf=lambda x: isinstance(x, P))
    # delta-payload rules feed (H, M, b_m, ...) batches (worker_split with
    # local_steps) — the local-step axis is a replicated leading dim
    spec_for = train_batch_specs(
        mesh, hp.rule.local_steps
        if strategy_for(hp.rule).delta_payload else 1)

    def batch_shardings(batch_sds):
        return {k: to_named(mesh, spec_for(k, v.ndim))
                for k, v in batch_sds.items()}

    def make(batch_sds):
        # the state argument is donated: launch/train.py threads it
        # linearly, so the (potentially huge) buffers alias in place
        return jax.jit(step,
                       in_shardings=(sshard, batch_shardings(batch_sds)),
                       out_shardings=(sshard, None),
                       donate_argnums=(0,))

    return make, sspecs, m
