"""Sharding policy: parameter/batch/cache PartitionSpecs per (config, mesh).

Policy (GSPMD does the propagation; we pin the state):
  * tensor-parallel ("model" axis): attention heads, FFN hidden, MoE experts
    (expert-parallel when n_experts divides the axis, else TP inside each
    expert), Mamba d_inner / SSM heads, and the vocab dim of embed/lm_head;
  * FSDP ("data" axis): the non-TP dim of every large 2D+ weight, enabled
    when the per-device replicated footprint would exceed ``fsdp_threshold``
    bytes (big archs: grok-1, yi-34b, llama3-405b);
  * every sharding falls back to replication when the dim is not divisible
    by the mesh axis (e.g. qwen2-vl's 12 heads on a 16-way model axis);
  * the "pod" axis is never used for parameters — pods replicate the model
    and are CADA's communication-adaptive workers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.flat import spec_dim
from repro.models.config import ModelConfig, param_count
from repro.models.model import abstract_params

FSDP_THRESHOLD = 6e9  # bytes of bf16 params per model-shard before FSDP


# ------------------------------------------------------- flat state plane

@dataclass(frozen=True)
class FlatSharding:
    """Static description of how the flat state plane shards over a mesh.

    ``axes`` are the mesh axes the (n_flat,) SERVER planes (θ̂/h/v̂/∇) shard
    over (ZeRO-style, from ``TrainHParams.state_fsdp_axes`` /
    ``shard_cada_state`` / the FSDP axes); ``waxis`` is the worker axis
    leading the (M, n_flat) planes. Hashable, so the kernel wrappers in
    kernels/ops.py can take it as a static argument and build the
    shard_map'd, psum-reduced forms around the Pallas/jnp kernels.
    """
    mesh: Any
    waxis: str
    axes: tuple

    @property
    def col_axes(self) -> tuple:
        """State-shard axes of the FLAT dim of worker planes: the server
        axes minus the worker axis (one spec may not repeat an axis)."""
        return tuple(a for a in self.axes if a != self.waxis)

    @property
    def plane_axes(self) -> tuple:
        """Every mesh axis a worker plane touches (rows + columns)."""
        return tuple(dict.fromkeys((self.waxis,) + self.col_axes))

    @property
    def shards(self) -> int:
        """State-shard count = required divisor of ``FlatLayout.n_flat``."""
        s = 1
        for a in self.axes:
            s *= int(self.mesh.shape[a])
        return s

    def server_spec(self) -> P:
        """(n_flat,) server-plane PartitionSpec."""
        return P(spec_dim(self.axes))

    def worker_spec(self) -> P:
        """(M, n_flat) worker-plane PartitionSpec."""
        return P(self.waxis, spec_dim(self.col_axes))

    def constrain_server(self, x):
        # STAGED pin: the pinned jax 0.4.37's SPMD partitioner MISCOMPILES
        # the direct reshard of a freshly packed (concatenate + pad) 1-D
        # buffer to a sharded layout on meshes with more than one
        # non-trivial axis — the values come back permuted (norms are
        # permutation-invariant, so only position-sensitive consumers like
        # unpack see it; pinned by the pod-mesh trainer test). Pinning the
        # pack product to an explicit replicated layout FIRST and then to
        # the shard spec compiles correctly on every mesh we can force.
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*(None,) * x.ndim)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.server_spec()))

    def constrain_worker(self, x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.worker_spec()))


def _axsize(mesh, name):
    return mesh.shape[name] if name in mesh.shape else 1


def wants_fsdp(cfg: ModelConfig, mesh) -> bool:
    per_shard = 2 * param_count(cfg) / _axsize(mesh, "model")
    return per_shard > FSDP_THRESHOLD


def param_pspecs(cfg: ModelConfig, mesh, fsdp: bool | None = None,
                 fsdp_axes: tuple = ("data",)) -> Any:
    """Pytree of PartitionSpec matching init_params(cfg).

    ``fsdp_axes`` — mesh axes the FSDP dim shards over. The default shards
    over "data" only (params replicate across pods: CADA's workers); passing
    ("data", "pod") extends FSDP/ZeRO across pods for the 314B/405B archs
    whose optimizer state cannot replicate per pod.
    """
    if fsdp is None:
        fsdp = wants_fsdp(cfg, mesh)
    msize = _axsize(mesh, "model")

    def m_if(n):  # "model" when divisible, else replicate
        return "model" if (msize > 1 and n % msize == 0) else None

    def f_if(n):  # fsdp axes (largest divisible prefix), else replicate
        if not fsdp:
            return None
        kept, prod = [], 1
        for a in fsdp_axes:
            sz = _axsize(mesh, a)
            if sz > 1 and n % (prod * sz) == 0:
                kept.append(a)
                prod *= sz
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else tuple(kept)

    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd
    di, e = cfg.d_inner, cfg.n_experts
    heads_shardable = cfg.n_heads and (cfg.n_heads * hd) % msize == 0 \
        and cfg.n_heads % msize == 0
    kv_shardable = cfg.n_kv_heads and cfg.n_kv_heads % msize == 0
    expert_parallel = e > 0 and e % msize == 0

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = names[-1]
        stacked = "blocks" in names           # leading n_layers axis
        expert = (len(leaf.shape) - (1 if stacked else 0)) == 3  # (E, a, b)

        def wrap(spec):
            if stacked:
                return P(*((None,) + tuple(spec)))
            return P(*spec)

        if name == "embed":
            return P(m_if(cfg.vocab), f_if(d))
        if name == "lm_head":
            return P(f_if(d), m_if(cfg.vocab))
        if name in ("final_norm", "ln", "ln1", "ln2"):
            return wrap((None,))
        if name == "wq":
            return wrap((f_if(d), "model" if heads_shardable else None))
        if name in ("wk", "wv"):
            return wrap((f_if(d), "model" if kv_shardable else None))
        if name == "wo":
            return wrap(("model" if heads_shardable else None, f_if(d)))
        if name == "router":
            return wrap((f_if(d), None))
        if name in ("w_gate", "w_up"):
            if expert:
                if expert_parallel:
                    # expert-parallel + FSDP on the d dim (314B experts
                    # cannot replicate within an expert shard)
                    return wrap(("model", f_if(d), None))
                return wrap((None, f_if(d), m_if(ff)))
            return wrap((f_if(d), m_if(ff)))
        if name == "w_down":
            if expert:
                if expert_parallel:
                    return wrap(("model", None, f_if(d)))
                return wrap((None, m_if(ff), f_if(d)))
            return wrap((m_if(ff), f_if(d)))
        # ----- mamba -----
        if name in ("in_x", "in_z"):
            return wrap((f_if(d), m_if(di)))
        if name in ("in_b", "in_c", "in_dt"):
            return wrap((f_if(d), None))
        if name == "conv_w":
            return wrap((None, m_if(di)))
        if name in ("conv_b", "out_norm"):
            return wrap((m_if(di),))
        if name in ("xp_dt", "xp_b", "xp_c"):
            return wrap((m_if(di), None))
        if name == "dt_proj":
            return wrap((None, m_if(di)))
        if name == "dt_bias":
            n0 = leaf.shape[1 if stacked else 0]
            return wrap((m_if(n0),))
        if name in ("A_log", "D"):
            dims = leaf.shape[(1 if stacked else 0):]
            spec = [m_if(dims[0])] + [None] * (len(dims) - 1)
            return wrap(tuple(spec))
        if name == "out_proj":
            return wrap((m_if(di), f_if(d)))
        # default: replicate
        return P(*(None,) * leaf.ndim)

    aps = abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(rule, aps)


def _data_axes(mesh):
    """All batch-shardable axes, biggest meshes first: ('pod','data')."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _axes_if(mesh, axes, n):
    """Largest prefix of ``axes`` whose product divides n (else None)."""
    kept = []
    prod = 1
    for a in axes:
        if n % (prod * _axsize(mesh, a)) == 0:
            kept.append(a)
            prod *= _axsize(mesh, a)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def batch_pspecs(batch_specs: Any, mesh) -> Any:
    """Shard the leading (batch) dim of every batch leaf over the data axes
    (('pod','data') on the multi-pod mesh), guarded by divisibility; M-RoPE
    "positions" (3, B, S) shards its second dim."""
    axes = _data_axes(mesh)

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        if "positions" in names:
            return P(None, _axes_if(mesh, axes, leaf.shape[1]),
                     *(None,) * (leaf.ndim - 2))
        return P(_axes_if(mesh, axes, leaf.shape[0]),
                 *(None,) * (leaf.ndim - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_specs)


def cache_pspecs(cfg: ModelConfig, cache_specs: Any, mesh) -> Any:
    """Decode-cache sharding.

    k/v: (L, B, W, Hkv, hd); conv: (L, B, K-1, di); ssm: (L, B, ...).
    Batch shards over the data axes (divisibility-guarded); KV heads /
    d_inner / SSM heads over "model". When KV heads don't divide the model
    axis (GQA kv=8 on a 16-way axis) the ring dim W picks up the model axis
    instead so the 32k-context caches still fit per chip.
    """
    msize = _axsize(mesh, "model")
    daxes = _data_axes(mesh)

    def m_if(n):
        return "model" if (msize > 1 and n % msize == 0) else None

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = names[-1]
        if name in ("index", "slot_pos"):
            return P(*(None,) * leaf.ndim)
        if name in ("k", "v"):
            b_ax = _axes_if(mesh, daxes, leaf.shape[1])
            h_ax = m_if(leaf.shape[3])
            w_ax = None
            if h_ax is None:
                w_ax = m_if(leaf.shape[2])
            return P(None, b_ax, w_ax, h_ax, None)
        if name == "conv":
            return P(None, _axes_if(mesh, daxes, leaf.shape[1]), None,
                     m_if(leaf.shape[3]))
        if name == "ssm":
            spec = [None, _axes_if(mesh, daxes, leaf.shape[1]),
                    m_if(leaf.shape[2])]
            spec += [None] * (leaf.ndim - 3)
            return P(*spec)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def to_named(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
