"""Lightweight span/event recorder with a strict no-op fast path.

A :class:`Tracer` records *complete spans* (name, track, start, duration),
*instant events*, and *counter samples* into plain Python lists — no JAX,
no I/O, no threads. Timestamps are plain float seconds on whichever clock
the caller uses:

- real runs open spans with :meth:`Tracer.span` (``time.perf_counter``);
- the discrete-event sim records spans on the *simulated* clock with
  :meth:`Tracer.add_span` — the export layer treats both identically, so
  a real pipelined run and a simulated WAN run open in the same timeline
  viewer (chrome://tracing / Perfetto via :mod:`repro.obs.export`).

Tracks
------
A *track* is a named horizontal lane in the timeline (one per simulated
worker, one for the server, one for the cohort pipeline, ...). Tracks are
created on first use and keep insertion order in the exported view.

Disabled path
-------------
``NULL`` is a module-level :class:`NullTracer` singleton: every method is
a no-op, ``bool(NULL)`` is ``False`` (so ``if tracer:`` guards skip
argument construction entirely), and ``NULL.span(...)`` returns one
reusable null context manager — no allocation, no clock read. Hot loops
take ``trace=None`` and normalize via :func:`as_tracer`; the overhead
contract (<2% steps/sec disabled) is pinned by the ``obs_overhead``
arm in ``BENCH_cada.json``.
"""

from __future__ import annotations

import time

__all__ = ["Tracer", "NullTracer", "NULL", "as_tracer"]


class _NullSpan:
    """Reusable no-op context manager returned by ``NULL.span(...)``."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every method is a no-op, truthiness is False."""

    __slots__ = ()
    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name, track="main", cat="", args=None):
        return _NULL_SPAN

    def add_span(self, name, start_s, dur_s, *, track="main", cat="", args=None):
        pass

    def instant(self, name, t_s=None, *, track="main", args=None):
        pass

    def counter(self, name, t_s, value, *, track="counters"):
        pass

    def aggregate(self, track=None):
        return {}


NULL = NullTracer()


def as_tracer(trace) -> "Tracer | NullTracer":
    """Normalize a ``trace=`` argument: None -> the NULL singleton."""
    return NULL if trace is None else trace


class _Span:
    """Context manager recording one wall-clock span on exit."""

    __slots__ = ("_tr", "name", "track", "cat", "args", "_t0")

    def __init__(self, tr, name, track, cat, args):
        self._tr = tr
        self.name = name
        self.track = track
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tr
        tr._events.append(("X", self.name, self.track, self.cat,
                           self._t0 - tr._epoch, t1 - self._t0, self.args))
        return False


class Tracer:
    """Records spans/instants/counters into memory; export later.

    Events are stored as tuples ``(ph, name, track, cat, t_s, dur_s, args)``
    with ``ph`` one of ``"X"`` (complete span), ``"i"`` (instant),
    ``"C"`` (counter sample, ``args`` is a ``{series: value}`` dict).
    All times are float seconds relative to the tracer's epoch (for
    wall-clock spans) or the caller's clock (for :meth:`add_span`).
    """

    enabled = True

    def __init__(self) -> None:
        self._epoch = time.perf_counter()
        self._events: list[tuple] = []
        self._tracks: list[str] = []
        self._track_set: set[str] = set()

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self._events)

    # -- recording ---------------------------------------------------------

    def span(self, name: str, track: str = "main", cat: str = "",
             args: dict | None = None) -> _Span:
        """Open a wall-clock span (``with tracer.span("step"): ...``)."""
        self._touch(track)
        return _Span(self, name, track, cat, args)

    def add_span(self, name: str, start_s: float, dur_s: float, *,
                 track: str = "main", cat: str = "",
                 args: dict | None = None) -> None:
        """Record a complete span with explicit times (e.g. sim clock)."""
        self._touch(track)
        self._events.append(("X", name, track, cat, float(start_s),
                             float(dur_s), args))

    def instant(self, name: str, t_s: float | None = None, *,
                track: str = "main", args: dict | None = None) -> None:
        """Record a zero-duration marker (gate decisions, errors, ...)."""
        if t_s is None:
            t_s = time.perf_counter() - self._epoch
        self._touch(track)
        self._events.append(("i", name, track, "", float(t_s), 0.0, args))

    def counter(self, name: str, t_s: float, value: float, *,
                track: str = "counters") -> None:
        """Record one sample of a counter series (pool bytes, queue depth)."""
        self._touch(track)
        self._events.append(("C", name, track, "", float(t_s), 0.0,
                             {name: float(value)}))

    # -- reading -----------------------------------------------------------

    @property
    def tracks(self) -> list[str]:
        return list(self._tracks)

    @property
    def events(self) -> list[tuple]:
        return self._events

    def spans(self, track: str | None = None) -> list[tuple]:
        """All complete spans, optionally restricted to one track."""
        return [e for e in self._events
                if e[0] == "X" and (track is None or e[2] == track)]

    def aggregate(self, track: str | None = None) -> dict[str, dict]:
        """Per-name span aggregates: ``{name: {count, total_s, max_s}}``.

        This is the one home for per-round phase timing — the benchmark
        harness derives ``gather_ms/step_ms/scatter_ms`` from these
        aggregates instead of keeping its own clock arithmetic.
        """
        out: dict[str, dict] = {}
        for e in self._events:
            if e[0] != "X" or (track is not None and e[2] != track):
                continue
            agg = out.setdefault(e[1], {"count": 0, "total_s": 0.0, "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += e[5]
            if e[5] > agg["max_s"]:
                agg["max_s"] = e[5]
        return out

    def _touch(self, track: str) -> None:
        if track not in self._track_set:
            self._track_set.add(track)
            self._tracks.append(track)
