"""Chrome-trace/Perfetto JSON export for :class:`repro.obs.trace.Tracer`.

Emits the Trace Event Format's *JSON Object Format*::

    {"traceEvents": [...], "displayTimeUnit": "ms"}

Every tracer track becomes one thread (``tid``) under a single process,
named via ``"M"`` metadata events and ordered by first use
(``thread_sort_index``). Complete spans become ``"X"`` events with
``ts``/``dur`` in microseconds — simulated seconds map directly onto the
timeline's microsecond axis, so a 30 s simulated WAN round and a 30 ms
real pipelined round both render with correct relative proportions.

:func:`validate_chrome_trace` is a dependency-free structural validator
(the CI ``obs-smoke`` leg runs it via ``python -m repro.obs.export
--validate out.json``); it checks exactly the invariants the viewer
relies on, and is itself pinned by tests/test_obs.py.
"""

from __future__ import annotations

import json
from typing import Any

__all__ = ["to_chrome_trace", "write_chrome_trace", "validate_chrome_trace"]

_PID = 1
# Phases this exporter emits (+ those a hand-edited trace may contain).
_KNOWN_PHASES = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def to_chrome_trace(tracer, *, meta: dict | None = None) -> dict:
    """Convert a Tracer's events into a Chrome-trace JSON object."""
    events: list[dict] = []
    tids = {name: i for i, name in enumerate(tracer.tracks)}

    events.append({"ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
                   "args": {"name": "repro"}})
    for name, tid in tids.items():
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name", "args": {"name": name}})
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_sort_index", "args": {"sort_index": tid}})

    for ph, name, track, cat, t_s, dur_s, args in tracer.events:
        ev: dict[str, Any] = {
            "ph": ph,
            "name": name,
            "pid": _PID,
            "tid": tids[track],
            "ts": round(t_s * 1e6, 3),
        }
        if cat:
            ev["cat"] = cat
        if ph == "X":
            ev["dur"] = round(dur_s * 1e6, 3)
        elif ph == "i":
            ev["s"] = "t"  # thread-scoped instant
        if args is not None:
            ev["args"] = args
        events.append(ev)

    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta:
        out["otherData"] = meta
    return out


def write_chrome_trace(tracer, path: str, *, meta: dict | None = None) -> dict:
    """Export ``tracer`` to ``path`` as Chrome-trace JSON; returns the dict."""
    obj = to_chrome_trace(tracer, meta=meta)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def _fail(path: str, msg: str) -> None:
    raise ValueError(f"invalid chrome trace at {path}: {msg}")


def validate_chrome_trace(obj: Any) -> int:
    """Structurally validate a Chrome-trace JSON object.

    Checks the JSON Object Format invariants the trace viewer depends on:
    a ``traceEvents`` list of dicts; every event has a known ``ph``, a
    string ``name``, integer ``pid``/``tid``, and a finite numeric ``ts``;
    ``"X"`` events carry a non-negative numeric ``dur``; ``"M"`` and
    ``"C"`` events carry a dict ``args``. Returns the event count;
    raises ``ValueError`` (with a JSON-path-ish locator) on violation.
    """
    if not isinstance(obj, dict):
        _fail("$", f"top level must be an object, got {type(obj).__name__}")
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        _fail("$.traceEvents", "missing or not a list")
    for i, ev in enumerate(evs):
        loc = f"$.traceEvents[{i}]"
        if not isinstance(ev, dict):
            _fail(loc, "event is not an object")
        ph = ev.get("ph")
        if ph not in _KNOWN_PHASES:
            _fail(loc + ".ph", f"unknown phase {ph!r}")
        if not isinstance(ev.get("name"), str):
            _fail(loc + ".name", "missing or not a string")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                _fail(loc + f".{k}", "missing or not an integer")
        if ph == "M":
            if not isinstance(ev.get("args"), dict):
                _fail(loc + ".args", "metadata event needs an args object")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts != ts:
            _fail(loc + ".ts", f"missing or non-finite: {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not dur >= 0:
                _fail(loc + ".dur", f"missing or negative: {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                _fail(loc + ".args", "counter event needs a non-empty args object")
            for k, v in args.items():
                if not isinstance(v, (int, float)):
                    _fail(loc + f".args.{k}", "counter value not numeric")
    return len(evs)


def main(argv: list[str] | None = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Validate a Chrome-trace JSON file against the schema.")
    p.add_argument("--validate", metavar="FILE", required=True,
                   help="path to a Chrome-trace JSON file")
    args = p.parse_args(argv)
    with open(args.validate) as f:
        obj = json.load(f)
    try:
        n = validate_chrome_trace(obj)
    except ValueError as e:
        import sys
        print(e, file=sys.stderr)
        return 1
    tracks = sum(1 for e in obj["traceEvents"]
                 if e.get("ph") == "M" and e.get("name") == "thread_name")
    spans = sum(1 for e in obj["traceEvents"] if e.get("ph") == "X")
    print(f"{args.validate}: OK ({n} events, {spans} spans, {tracks} tracks)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
