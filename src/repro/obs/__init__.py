"""Unified telemetry plane: tracing, metrics, and timeline export.

Three pieces, importable without JAX:

- :mod:`repro.obs.trace`   — span/event recorder (``Tracer``) with a strict
  no-op fast path (``NULL``) when tracing is disabled.
- :mod:`repro.obs.metrics` — counter/gauge/histogram registry plus the
  per-rule communication ledger (``CommLedger``) with JSONL and
  Prometheus-textfile sinks.
- :mod:`repro.obs.export`  — Chrome-trace/Perfetto JSON export and a
  dependency-free schema validator.

See ``src/repro/obs/README.md`` for the span taxonomy, sink formats, and
the overhead contract (disabled <2%, enabled <10% steps/sec — pinned by
the ``obs_overhead`` arm of ``BENCH_cada.json``).
"""

from .trace import NULL, NullTracer, Tracer, as_tracer
from .metrics import CommLedger, MetricsRegistry, write_jsonl
from .export import to_chrome_trace, validate_chrome_trace, write_chrome_trace

__all__ = [
    "NULL",
    "NullTracer",
    "Tracer",
    "as_tracer",
    "CommLedger",
    "MetricsRegistry",
    "write_jsonl",
    "to_chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
]
