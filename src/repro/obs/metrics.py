"""Counter/gauge/histogram registry and per-rule communication ledgers.

Two layers:

- :class:`MetricsRegistry` — a small named-metric registry (counter,
  gauge, histogram) with JSONL and Prometheus-textfile sinks. Pure
  host-side Python/numpy; callers accumulate *device-side* (the engine
  buffers round metrics on device and fetches every ``metrics_every``
  rounds — see ``flat.run_cohort_rounds``) and feed the fetched host
  values here.
- :class:`CommLedger` — the per-rule communication ledger: uploads,
  bytes up/down split by wire format (dense/quantized/sparse), LHS-vs-RHS
  gate margins, staleness histogram, stale-ring occupancy, ``WorkerPool``
  resident-vs-mapped bytes, and async pending-writeback depth. Byte
  accounting reuses the strategy's property-pinned ``bytes_per_upload``
  numbers verbatim (it sums the round metrics' ``bytes_up`` values in
  order), so ledger totals are bit-equal to the engine's own accounting —
  pinned per rule in tests/test_obs.py.
"""

from __future__ import annotations

import json
from typing import Any

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "CommLedger", "write_jsonl"]


# --------------------------------------------------------------- registry

class Counter:
    """Monotonically increasing value."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-set value (pool residency, queue depth, ...)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram (Prometheus-style cumulative ``le`` export).

    ``bounds`` are the inclusive upper bin edges; one overflow bucket
    (``+Inf``) is implicit. ``observe`` takes scalars or arrays.
    """

    __slots__ = ("bounds", "counts", "total", "count")
    kind = "histogram"

    def __init__(self, bounds) -> None:
        self.bounds = np.asarray(sorted(bounds), dtype=np.float64)
        self.counts = np.zeros(len(self.bounds) + 1, dtype=np.int64)
        self.total = 0.0
        self.count = 0

    def observe(self, values) -> None:
        x = np.atleast_1d(np.asarray(values, dtype=np.float64)).ravel()
        if x.size == 0:
            return
        idx = np.searchsorted(self.bounds, x, side="left")
        self.counts += np.bincount(idx, minlength=self.counts.size)
        self.total += float(x.sum())
        self.count += int(x.size)

    def snapshot(self):
        return {
            "bounds": self.bounds.tolist(),
            "counts": self.counts.tolist(),
            "sum": self.total,
            "count": self.count,
        }


class MetricsRegistry:
    """Create-or-get named metrics; snapshot to JSON / Prometheus text."""

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as {m.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds=(1, 2, 4, 8, 16, 32, 64)) -> Histogram:
        return self._get(name, Histogram, bounds)

    def snapshot(self) -> dict:
        return {name: m.snapshot() for name, m in self._metrics.items()}

    # -- sinks -------------------------------------------------------------

    def write_jsonl(self, path: str, extra: dict | None = None) -> None:
        """Append one JSON line with every metric's snapshot."""
        row = dict(extra or {})
        row.update(self.snapshot())
        write_jsonl(path, row)

    def write_prom(self, path: str, *, prefix: str = "repro") -> None:
        """Write a Prometheus textfile-collector snapshot (overwrites)."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            full = f"{prefix}_{name}".replace(".", "_").replace("-", "_")
            lines.append(f"# TYPE {full} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += int(c)
                    lines.append(f'{full}_bucket{{le="{bound:g}"}} {cum}')
                lines.append(f'{full}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{full}_sum {m.total:g}")
                lines.append(f"{full}_count {m.count}")
            else:
                lines.append(f"{full} {m.snapshot():g}")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")


def write_jsonl(path: str, row: dict) -> None:
    """Append one JSON object as a line to ``path``."""
    with open(path, "a") as f:
        f.write(json.dumps(row, default=_json_default) + "\n")


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


# ----------------------------------------------------------------- ledger

_WIRE_FORMATS = ("dense", "quantized", "sparse")


class CommLedger:
    """Per-rule communication ledger fed from fetched round metrics.

    Construct with :meth:`for_strategy` (reads the strategy's
    ``wire_format``) or directly. Feed per-round host metric dicts via
    :meth:`observe_round` — or a whole stacked run (leading steps axis,
    as returned by ``CADAEngine.run``) via :meth:`observe_run`. Bytes are
    taken from the metrics' ``bytes_up`` entry (itself
    ``uploads * strategy.bytes_per_upload(n)``), summed in round order,
    so totals stay bit-equal to the engine's accounting.
    """

    def __init__(self, rule: str = "", wire_format: str = "dense") -> None:
        if wire_format not in _WIRE_FORMATS:
            raise ValueError(f"wire_format must be one of {_WIRE_FORMATS}, "
                             f"got {wire_format!r}")
        self.rule = rule
        self.wire_format = wire_format
        self.rounds = 0
        self.uploads = 0
        self.grad_evals = 0
        self.bytes_up = 0.0
        self.bytes_down = 0.0
        self._stale_counts = np.zeros(1, dtype=np.int64)
        self._margins: list[np.ndarray] = []
        self.ring_occupancy: int | None = None
        self.ring_capacity: int | None = None
        self.pool_nbytes: int | None = None
        self.pool_resident_nbytes: int | None = None
        self.pool_mapped_nbytes: int | None = None
        self.async_pending_max: int | None = None

    @classmethod
    def for_strategy(cls, strategy) -> "CommLedger":
        return cls(rule=strategy.kind, wire_format=strategy.wire_format)

    # -- feeding -----------------------------------------------------------

    def observe_round(self, met: dict, participation=None) -> None:
        """Fold one round's (host-fetched) metric dict into the ledger."""
        self.rounds += 1
        self.uploads += int(met["uploads"])
        self.bytes_up += float(met["bytes_up"])
        if "grad_evals" in met:
            self.grad_evals += int(met["grad_evals"])
        if "staleness" in met:
            self.observe_staleness(met["staleness"])
        if "lhs" in met and "rhs" in met:
            self.observe_margin(met["lhs"], met["rhs"], mask=participation)

    def observe_run(self, mets: dict, participation=None) -> None:
        """Fold a stacked run (leading steps axis on every entry)."""
        host = {k: np.asarray(v) for k, v in mets.items()}
        part = None if participation is None else np.asarray(participation)
        steps = int(host["uploads"].shape[0])
        for i in range(steps):
            row = {k: v[i] for k, v in host.items()}
            p = None if part is None else part[i]
            self.observe_round(row, participation=p)

    def observe_margin(self, lhs, rhs, mask=None) -> None:
        """Record finite LHS−RHS gate margins (>0 ⇒ the gate said upload)."""
        lhs = np.atleast_1d(np.asarray(lhs, dtype=np.float64)).ravel()
        rhs = float(np.asarray(rhs).ravel()[0]) if np.ndim(rhs) else float(rhs)
        margin = lhs - rhs
        keep = np.isfinite(margin)
        if mask is not None:
            keep &= np.atleast_1d(np.asarray(mask, dtype=bool)).ravel()
        if keep.any():
            self._margins.append(margin[keep])

    def observe_staleness(self, values) -> None:
        x = np.atleast_1d(np.asarray(values, dtype=np.int64)).ravel()
        if x.size == 0:
            return
        hi = int(x.max()) + 1
        if hi > self._stale_counts.size:
            grown = np.zeros(hi, dtype=np.int64)
            grown[: self._stale_counts.size] = self._stale_counts
            self._stale_counts = grown
        self._stale_counts += np.bincount(
            np.clip(x, 0, None), minlength=self._stale_counts.size)

    def observe_ring(self, slot, capacity: int | None = None) -> None:
        """Record stale-ring occupancy from the (M,) slot-assignment map."""
        slot = np.asarray(slot).ravel()
        self.ring_occupancy = int(np.unique(slot).size)
        if capacity is not None:
            self.ring_capacity = int(capacity)

    def observe_pool(self, pool) -> None:
        """Record WorkerPool residency gauges (nbytes/resident/mapped)."""
        self.pool_nbytes = int(pool.nbytes)
        self.pool_resident_nbytes = int(pool.resident_nbytes)
        self.pool_mapped_nbytes = int(pool.mapped_nbytes)

    def observe_pending(self, depth: int) -> None:
        """Track the max async pending-writeback depth seen."""
        d = int(depth)
        if self.async_pending_max is None or d > self.async_pending_max:
            self.async_pending_max = d

    def add_bytes_down(self, nbytes: float) -> None:
        self.bytes_down += float(nbytes)

    # -- reading -----------------------------------------------------------

    @property
    def staleness_hist(self) -> dict[int, int]:
        return {int(k): int(c) for k, c in enumerate(self._stale_counts) if c}

    def margin_quantiles(self, qs=(0.1, 0.5, 0.9)) -> dict[str, float] | None:
        if not self._margins:
            return None
        m = np.concatenate(self._margins)
        return {f"q{int(q * 100)}": float(np.quantile(m, q)) for q in qs}

    def summary(self) -> dict:
        """JSON-ready ledger summary; bytes split lands in the bucket
        matching this rule's wire format, other buckets stay 0."""
        split = {f"mbytes_up_{wf}": 0.0 for wf in _WIRE_FORMATS}
        split[f"mbytes_up_{self.wire_format}"] = self.bytes_up / 1e6
        out = {
            "rule": self.rule,
            "wire_format": self.wire_format,
            "rounds": self.rounds,
            "uploads": self.uploads,
            "bytes_up": self.bytes_up,
            "mbytes_up": self.bytes_up / 1e6,
            **split,
            "staleness_hist": {str(k): v for k, v in self.staleness_hist.items()},
        }
        if self.grad_evals:
            out["grad_evals"] = self.grad_evals
        if self.bytes_down:
            out["mbytes_down"] = self.bytes_down / 1e6
        mq = self.margin_quantiles()
        if mq is not None:
            out["gate_margin"] = mq
        if self.ring_occupancy is not None:
            out["ring_occupancy"] = self.ring_occupancy
            if self.ring_capacity is not None:
                out["ring_capacity"] = self.ring_capacity
        if self.pool_nbytes is not None:
            out["pool_nbytes"] = self.pool_nbytes
            out["pool_resident_nbytes"] = self.pool_resident_nbytes
            out["pool_mapped_nbytes"] = self.pool_mapped_nbytes
        if self.async_pending_max is not None:
            out["async_pending_max"] = self.async_pending_max
        return out
