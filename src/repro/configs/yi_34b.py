"""yi-34b — llama-arch dense GQA.
[arXiv:2403.04652] 60L, d_model=7168, 56 heads (GQA kv=8, hd=128),
d_ff=20480 SwiGLU, vocab=64000, rope_theta=5e6.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", arch_type="dense", block="dense",
        n_layers=60, d_model=7168, vocab=64000,
        n_heads=56, n_kv_heads=8, d_ff=20480, mlp_act="swiglu",
        rope_theta=5e6,
        source="arXiv:2403.04652",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="yi-34b-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=8, n_kv_heads=2, d_ff=384, dtype="float32", remat=False)


register("yi-34b", config, smoke_config)
