"""stablelm-1.6b — dense MHA LM with partial rotary embeddings.
[hf:stabilityai/stablelm-2-1_6b] 24L, d_model=2048, 32 heads (MHA, hd=64),
d_ff=5632 SwiGLU, vocab=100352, rotary_pct=0.25.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", arch_type="dense", block="dense",
        n_layers=24, d_model=2048, vocab=100352,
        n_heads=32, n_kv_heads=32, d_ff=5632, mlp_act="swiglu",
        rope_theta=1e4, rotary_pct=0.25,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="stablelm-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=256, dtype="float32", remat=False)


register("stablelm-1.6b", config, smoke_config)
