"""grok-1-314b — 314B MoE, 8 experts top-2.
[hf:xai-org/grok-1] 64L, d_model=6144, 48 heads (GQA kv=8, hd=128),
d_ff=32768 per expert, vocab=131072, gated-GeLU experts.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", arch_type="moe", block="moe",
        n_layers=64, d_model=6144, vocab=131072,
        n_heads=48, n_kv_heads=8, d_ff=32768,
        n_experts=8, top_k=2, mlp_act="geglu",
        rope_theta=1e4,
        source="hf:xai-org/grok-1",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="grok-1-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=256, n_experts=4, top_k=2,
        dtype="float32", remat=False)


register("grok-1-314b", config, smoke_config)
