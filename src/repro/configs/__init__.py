"""Architecture registry: the 10 assigned architectures (+ paper-native
problems live in repro.models.small / benchmarks). Importing this package
registers every config.
"""
from repro.configs import (  # noqa: F401  (registration side effects)
    falcon_mamba_7b, granite_moe_1b, grok_1_314b, internlm2_1_8b,
    llama3_405b, musicgen_medium, qwen2_vl_2b, stablelm_1_6b, yi_34b,
    zamba2_2_7b,
)
from repro.configs.base import (
    SHAPES, InputShape, adapt_for_shape, get_config, get_smoke_config,
    input_specs, list_archs,
)

__all__ = [
    "SHAPES", "InputShape", "adapt_for_shape", "get_config",
    "get_smoke_config", "input_specs", "list_archs",
]
