"""zamba2-2.7b — Mamba2 backbone + one SHARED attention block.
[arXiv:2411.15242] 54 Mamba2 layers, d_model=2560, d_inner=5120,
ssm_state=64, mamba head_dim=64 (80 SSM heads); a single shared
attention+MLP block (32 heads, MHA) applied every 6 SSM layers;
d_ff=10240, vocab=32000.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", arch_type="hybrid", block="mamba2",
        n_layers=54, d_model=2560, vocab=32000,
        ssm_state=64, ssm_conv=4, ssm_expand=2, mamba_headdim=64,
        attn_every=6, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, mlp_act="swiglu",
        tie_embeddings=True,
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="zamba2-smoke", n_layers=2, d_model=128, vocab=256,
        ssm_state=16, mamba_headdim=32, attn_every=2,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
        dtype="float32", remat=False)


register("zamba2-2.7b", config, smoke_config)
