"""granite-moe-1b-a400m — fine-grained MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L, d_model=1024, 16 heads
(GQA kv=8, hd=64), d_ff=512 per expert, vocab=49155, SwiGLU experts.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", arch_type="moe", block="moe",
        n_layers=24, d_model=1024, vocab=49155,
        n_heads=16, n_kv_heads=8, d_ff=512,
        n_experts=32, top_k=8, mlp_act="swiglu",
        rope_theta=1e4, tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="granite-moe-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=64, n_experts=4, top_k=2,
        dtype="float32", remat=False)


register("granite-moe-1b-a400m", config, smoke_config)
