"""llama3-405b — frontier-scale dense GQA.
[arXiv:2407.21783] 126L, d_model=16384, 128 heads (GQA kv=8, hd=128),
d_ff=53248 SwiGLU, vocab=128256, rope_theta=5e5.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", arch_type="dense", block="dense",
        n_layers=126, d_model=16384, vocab=128256,
        n_heads=128, n_kv_heads=8, d_ff=53248, mlp_act="swiglu",
        rope_theta=5e5,
        source="arXiv:2407.21783",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="llama3-smoke", n_layers=2, d_model=256, vocab=512,
        n_heads=8, n_kv_heads=2, d_ff=512, dtype="float32", remat=False)


register("llama3-405b", config, smoke_config)
