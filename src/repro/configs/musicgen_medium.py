"""musicgen-medium — decoder-only LM over EnCodec audio tokens.
[arXiv:2306.05284] 48L, d_model=1536, 24 heads (MHA, hd=64), d_ff=6144
GeLU, codebook vocab=2048. The EnCodec/conditioning frontend is a stub:
``input_specs`` provides precomputed frame embeddings (B, S, d).
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", arch_type="audio", block="dense",
        n_layers=48, d_model=1536, vocab=2048,
        n_heads=24, n_kv_heads=24, d_ff=6144, mlp_act="gelu",
        rope_theta=1e4, embed_input=False,
        source="arXiv:2306.05284",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="musicgen-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=4, d_ff=256, dtype="float32", remat=False)


register("musicgen-medium", config, smoke_config)
