"""qwen2-vl-2b — VLM decoder backbone with M-RoPE.
[arXiv:2409.12191] 28L, d_model=1536, 12 heads (GQA kv=2, hd=128),
d_ff=8960 SwiGLU, vocab=151936, M-RoPE sections (16,24,24), dynamic
resolution. The ViT+projector frontend is a stub: ``input_specs`` provides
precomputed patch/text embeddings (B, S, d) plus (3, B, S) t/h/w positions.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", arch_type="vlm", block="dense",
        n_layers=28, d_model=1536, vocab=151936,
        n_heads=12, n_kv_heads=2, d_ff=8960, mlp_act="swiglu",
        rope_theta=1e6, mrope=True, mrope_sections=(16, 24, 24),
        embed_input=False, tie_embeddings=True,
        source="arXiv:2409.12191",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="qwen2-vl-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=256, dtype="float32", remat=False)


register("qwen2-vl-2b", config, smoke_config)
