"""internlm2-1.8b — dense GQA LM.
[arXiv:2403.17297] 24L, d_model=2048, 16 heads (GQA kv=8, hd=128),
d_ff=8192 SwiGLU, vocab=92544.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", arch_type="dense", block="dense",
        n_layers=24, d_model=2048, vocab=92544,
        n_heads=16, n_kv_heads=8, d_ff=8192, mlp_act="swiglu",
        rope_theta=1e6,
        source="arXiv:2403.17297",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="internlm2-smoke", n_layers=2, d_model=128, vocab=256,
        n_heads=4, n_kv_heads=2, d_ff=256, dtype="float32", remat=False)


register("internlm2-1.8b", config, smoke_config)
