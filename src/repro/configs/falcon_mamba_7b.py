"""falcon-mamba-7b — pure Mamba1 (attention-free) 7B LM.
[arXiv:2410.05355] Falcon Mamba: 64L, d_model=4096, d_inner=8192 (expand 2),
ssm_state=16, conv 4, dt_rank=d_model/16=256, vocab=65024.
"""
from repro.configs.base import register
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", arch_type="ssm", block="mamba1",
        n_layers=64, d_model=4096, vocab=65024,
        ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
        tie_embeddings=True,
        source="arXiv:2410.05355",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="falcon-mamba-smoke", n_layers=2, d_model=128, vocab=256,
        dt_rank=8, ssm_state=8, dtype="float32", remat=False)


register("falcon-mamba-7b", config, smoke_config)
