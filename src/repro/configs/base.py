"""Input shapes, spec builders, and the architecture registry.

The four assigned input shapes (fixed by the task):
    train_4k     seq=4,096    global_batch=256   (training)
    prefill_32k  seq=32,768   global_batch=32    (inference-prefill)
    decode_32k   seq=32,768   global_batch=128   (inference-decode: 1 token,
                                                  KV/SSM state of length seq)
    long_500k    seq=524,288  global_batch=1     (long-context decode —
                                                  requires sub-quadratic
                                                  attention or SSM state)

``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input of a (config, shape) pair — shardable, no device allocation — which is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


@dataclass(frozen=True)
class InputShape:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, TRAIN),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, PREFILL),
    "decode_32k": InputShape("decode_32k", 32768, 128, DECODE),
    "long_500k": InputShape("long_500k", 524288, 1, DECODE),
}

LONG_CONTEXT_WINDOW = 8192  # sliding window used by full-attention archs
#                             for long_500k (DESIGN.md §Arch-applicability)


def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config tweaks: full-attention archs switch to a sliding
    window for 500k-context decode (the sub-quadratic requirement)."""
    if shape.kind == DECODE and shape.seq > 65536 and not cfg.subquadratic:
        return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's *data* arguments.

    train  -> {"tokens"} or {"embeds","labels"} (+ "positions" for M-RoPE)
    prefill-> same minus labels
    decode -> {"tokens"/"embeds"} one token + {"cache"} primed at seq.
    """
    cfg = adapt_for_shape(cfg, shape)
    b, s = shape.batch, shape.seq
    emb_dtype = cfg.jnp_dtype

    def positions(seq):
        return _sds((3, b, seq), jnp.int32) if cfg.mrope else None

    if shape.kind == TRAIN:
        if cfg.embed_input:
            specs = {"tokens": _sds((b, s + 1), jnp.int32)}
        else:
            specs = {"embeds": _sds((b, s, cfg.d_model), emb_dtype),
                     "labels": _sds((b, s), jnp.int32)}
        if cfg.mrope:
            specs["positions"] = positions(s)
        return {"batch": specs}

    if shape.kind == PREFILL:
        if cfg.embed_input:
            specs = {"tokens": _sds((b, s), jnp.int32)}
        else:
            specs = {"embeds": _sds((b, s, cfg.d_model), emb_dtype)}
        if cfg.mrope:
            specs["positions"] = positions(s)
        return specs

    # decode: one new token against a cache primed at `seq`.
    cache = jax.eval_shape(partial(init_cache, cfg, b, s))
    if cfg.embed_input:
        return {"tokens": _sds((b,), jnp.int32), "cache": cache}
    return {"embeds": _sds((b, 1, cfg.d_model), emb_dtype), "cache": cache}


# ------------------------------------------------------------- registry

_REGISTRY: dict[str, dict] = {}


def register(name: str, config_fn, smoke_fn):
    _REGISTRY[name] = {"config": config_fn, "smoke": smoke_fn}


def get_config(name: str) -> ModelConfig:
    return _REGISTRY[name]["config"]()


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    cfg = _REGISTRY[name]["smoke"]()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    return cfg


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
