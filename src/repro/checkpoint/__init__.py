from repro.checkpoint.io import latest_step_dir, restore, save

__all__ = ["save", "restore", "latest_step_dir"]
