"""Pytree checkpointing: .npz payload + json manifest (treedef + shapes).

Deliberately dependency-free (no orbax). Arrays are gathered to host before
save; restore reproduces the exact treedef and dtypes, and can re-shard via a
``device_put_fn`` hook (used by the launcher to put leaves back on the mesh).
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str, tree: Any, step: int | None = None,
         flat_meta: Any = None) -> None:
    """``flat_meta`` (a ``core.flat.FlatLayout`` or a ``{"n", "n_flat"}``
    dict) records the flat state plane's layout so :func:`restore` can
    RESHARD flat leaves into a target built with a different state-shard
    count (``n_flat`` is padded to the shard count, so it changes when
    the mesh does; ``n``, the true entry count, does not)."""
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    # npz can't round-trip ml_dtypes (bfloat16/fp8) — store widened fp32
    # bits and record the logical dtype in the manifest.
    stored = [a.astype(np.float32)
              if a.dtype not in (np.float32, np.float64, np.float16,
                                 np.int8, np.int16, np.int32, np.int64,
                                 np.uint8, np.uint16, np.uint32, np.uint64,
                                 np.bool_)
              else a for a in host_leaves]
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(stored)})
    manifest = {
        "version": 1,
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in host_leaves],
        "dtypes": [str(a.dtype) for a in host_leaves],
    }
    if flat_meta is not None:
        get = (flat_meta.get if isinstance(flat_meta, dict)
               else lambda k: getattr(flat_meta, k))
        manifest["flat"] = {"n": int(get("n")), "n_flat": int(get("n_flat"))}
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))


def _reshard_flat(a: np.ndarray, ref, flat: dict | None, path: str
                  ) -> np.ndarray:
    """Re-pad a flat-plane leaf saved at one state-shard count into the
    target layout's ``n_flat`` (the last dim): the true ``n`` entries are
    kept, the zero padding tail is re-cut. Raises a clean error NAMING the
    offending plane when the mismatch is not a pure padding change."""
    ref_shape = tuple(np.shape(ref))
    if (flat and a.ndim >= 1 and a.shape[:-1] == ref_shape[:-1]
            and a.shape[-1] == flat["n_flat"]):
        n = int(flat["n"])
        new_flat = int(ref_shape[-1])
        if new_flat < n:
            raise ValueError(
                f"flat-plane layout mismatch at {path}: checkpoint holds "
                f"n={n} true entries (n_flat={flat['n_flat']}), restore "
                f"target plane has only {new_flat} lanes")
        tail = a[..., n:]
        if tail.size and np.any(tail != 0):
            raise ValueError(
                f"flat-plane layout mismatch at {path}: padding tail "
                f"beyond n={n} is not zero — the leaf is not a plane of "
                f"the recorded flat layout")
        pad = [(0, 0)] * (a.ndim - 1) + [(0, new_flat - n)]
        return np.pad(a[..., :n], pad)
    raise ValueError(f"shape mismatch at {path}: {a.shape} vs {ref_shape}")


def restore(path: str, like: Any,
            device_put_fn: Callable[[str, np.ndarray], Any] | None = None
            ) -> tuple[Any, int | None]:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    The manifest records each leaf's LOGICAL dtype (bf16/fp8 payloads are
    stored widened to fp32 — npz can't round-trip ml_dtypes); restoring
    into a ``like`` whose leaf dtype differs from the manifest's is an
    error, not a silent cast: a checkpoint saved under one dtype policy
    (fp32 moments) must not quietly narrow into another (bf16).

    Flat state planes saved with ``flat_meta`` reshard across state-shard
    counts: a leaf whose trailing dim is the recorded ``n_flat`` restores
    into a target plane with a DIFFERENT padded length by keeping the true
    ``n`` entries and re-cutting the zero tail (shard-count changes only
    ever move the padding). Any other mismatch raises, naming the plane.
    The stale-iterate ring extras (cada2's (R,)+param-shaped ``ring``
    rows, (M,) ``slot``, (R,) ``ring_version``) are param/index-shaped,
    not flat planes — they take the exact-shape path and round-trip
    verbatim under any state-shard count (pinned in
    tests/test_stale_ring.py).

    The cohort plane's host :class:`repro.core.flat.WorkerPool` rides
    this path unchanged: its ``state_dict()`` is a dict of (M, n_flat)
    numpy planes — ordinary flat worker planes to ``_reshard_flat`` —
    so a pool saved at one state-shard count restores into a template
    cut for another, true entries bit-exact, padding re-cut (pinned in
    tests/test_cohort_plane.py).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, leaves, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        raise ValueError(
            "checkpoint tree mismatch:\n saved: "
            f"{manifest['paths'][:5]}...\n expected: {paths[:5]}...")
    saved_dtypes = manifest.get("dtypes")
    out = []
    for i, (p, ref) in enumerate(zip(paths, leaves)):
        a = data[f"leaf_{i}"]
        if list(a.shape) != list(np.shape(ref)):
            a = _reshard_flat(a, ref, manifest.get("flat"), p)
        ref_dtype = str(np.dtype(getattr(ref, "dtype", a.dtype)))
        if saved_dtypes is not None and saved_dtypes[i] != ref_dtype:
            raise ValueError(
                f"dtype mismatch at {p}: checkpoint holds "
                f"{saved_dtypes[i]}, restore target expects {ref_dtype}")
        if str(a.dtype) != ref_dtype:
            # the intentional widened round-trip: the leaf was SAVED as
            # this logical dtype (validated above) and stored as fp32
            # bits; cast back via jnp (ml_dtypes)
            import jax.numpy as jnp
            a = np.asarray(jnp.asarray(a).astype(ref.dtype))
        out.append(device_put_fn(p, a) if device_put_fn else a)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step_dir(root: str) -> str | None:
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda s: int(s.split("_")[1])))
