"""Unit tests for the strategy layer (core/comm.py): registry, hand-computed
LHS values, post-upload state transitions, wire format, and accounting —
per strategy, including the beyond-paper compressed-innovation rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm import (CommContext, CommState, broadcast_to_workers,
                             comm_round, init_comm_state, per_worker_sq_norm,
                             record_progress, select_rows, strategy_for,
                             strategy_kinds)
from repro.core.quantize import per_worker_quantize_dequantize
from repro.core.rules import LOCAL_RULES, RULES, CommRule

M = 2
PARAMS = {"w": jnp.array([1.0, -1.0]), "b": jnp.array([0.5])}


def _state(rule, **over):
    s = init_comm_state(strategy_for(rule), PARAMS, M)
    return s._replace(**over) if over else s


def _ctx(rule, fresh, comm, *, k=0, vgrad=None, vgrad_per=None):
    return CommContext(params=PARAMS, batch=None, fresh=fresh, comm=comm,
                       step=jnp.asarray(k), m=M, vgrad=vgrad,
                       vgrad_per=vgrad_per)


def _wtree(w0, w1):
    """Per-worker tree with hand-set rows."""
    return {"w": jnp.array(w0), "b": jnp.array(w1)}


# ------------------------------------------------------------------ registry

def test_registry_covers_all_rule_kinds():
    assert set(strategy_kinds()) == set(RULES) | set(LOCAL_RULES)
    for kind in RULES + LOCAL_RULES:
        s = strategy_for(CommRule(kind=kind))
        assert s.kind == kind
        assert s.rule.kind == kind


def test_unknown_kind_raises():
    rule = CommRule(kind="cada2")
    object.__setattr__(rule, "kind", "bogus")  # bypass __post_init__
    with pytest.raises(ValueError, match="bogus"):
        strategy_for(rule)


def test_grad_evals_delegate_to_strategy():
    """CommRule.grad_evals_per_iter is the strategy's accounting (§2.2)."""
    for kind in RULES:
        expect = 2 if kind in ("cada1", "cada2") else 1
        assert CommRule(kind=kind).grad_evals_per_iter == expect
        assert strategy_for(CommRule(kind=kind)).grad_evals_per_iter == expect


# ------------------------------------------------------- hand-computed LHS

def test_lag_lhs_hand_computed():
    """eq. (5): LHS_m = ||∇ℓ(θ^k;ξ^k) − last contributed ∇||²."""
    rule = CommRule(kind="lag")
    strat = strategy_for(rule)
    comm = _state(rule, worker_grads=_wtree([[0.0, 0.0], [1.0, 0.0]],
                                            [[0.0], [2.0]]))
    fresh = _wtree([[1.0, 1.0], [2.0, 0.0]], [[0.0], [2.0]])
    lhs, cache = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    # worker 0: (1² + 1²) + 0² = 2 ; worker 1: 1² + 0 = 1
    np.testing.assert_allclose(np.asarray(lhs), [2.0, 1.0])
    assert cache is None


def test_cada2_lhs_hand_computed():
    """eq. (10): LHS_m = ||∇ℓ(θ^k;ξ) − ∇ℓ(θ^{k−τ_m};ξ)||², stale gradient
    re-evaluated at the SAME sample via vgrad_per."""
    rule = CommRule(kind="cada2")
    strat = strategy_for(rule)
    comm = _state(rule)
    stale = _wtree([[0.5, 0.0], [0.0, 0.0]], [[0.0], [1.0]])

    def vgrad_per(wparams, batch):
        return jnp.zeros((M,)), stale

    fresh = _wtree([[1.5, 0.0], [0.0, 2.0]], [[0.0], [1.0]])
    lhs, _ = strat.lhs(_ctx(rule, fresh, comm, vgrad_per=vgrad_per),
                       comm.extras)
    # worker 0: 1² ; worker 1: 2²
    np.testing.assert_allclose(np.asarray(lhs), [1.0, 4.0])


def test_cada1_lhs_and_snapshot_refresh():
    """eq. (7): LHS_m = ||δ̃_m^k − δ̃_m^{k−τ}||² with δ̃ = fresh − snap;
    the snapshot refreshes every D iterations (pre_step)."""
    rule = CommRule(kind="cada1", max_delay=10)
    strat = strategy_for(rule)
    comm = _state(rule)
    # stored innovation δ̃^{k−τ} = 1 everywhere for worker 0, 0 for worker 1
    stored = _wtree([[1.0, 1.0], [0.0, 0.0]], [[1.0], [0.0]])
    extras = {**comm.extras, "worker_delta": stored}

    snap_grads = _wtree([[0.0, 0.0], [0.0, 0.0]], [[0.0], [0.0]])

    def vgrad(params, batch):
        return jnp.zeros((M,)), snap_grads

    fresh = _wtree([[1.0, 1.0], [2.0, 0.0]], [[1.0], [0.0]])
    lhs, delta_fresh = strat.lhs(
        _ctx(rule, fresh, comm, vgrad=vgrad), extras)
    # δ̃^k = fresh − 0 = fresh; worker 0 diff = 0, worker 1 diff = 2²
    np.testing.assert_allclose(np.asarray(lhs), [0.0, 4.0])
    np.testing.assert_allclose(np.asarray(delta_fresh["w"]),
                               np.asarray(fresh["w"]))

    # pre_step: k % D == 0 refreshes θ̃ to current params, else keeps it
    stale_snap = jax.tree.map(lambda p: p + 7.0, PARAMS)
    ex = strat.pre_step({**extras, "snapshot": stale_snap}, PARAMS,
                        jnp.asarray(10))
    np.testing.assert_allclose(np.asarray(ex["snapshot"]["w"]),
                               np.asarray(PARAMS["w"]))
    ex = strat.pre_step({**extras, "snapshot": stale_snap}, PARAMS,
                        jnp.asarray(3))
    np.testing.assert_allclose(np.asarray(ex["snapshot"]["w"]),
                               np.asarray(stale_snap["w"]))


def test_always_lhs_is_infinite():
    rule = CommRule(kind="always")
    strat = strategy_for(rule)
    assert strat.stateless
    lhs, _ = strat.lhs(_ctx(rule, None, _state(rule)), {})
    assert np.all(np.isinf(np.asarray(lhs)))


def test_cinn_lhs_is_quantized_innovation_energy():
    """Beyond-paper rule: LHS is the energy of the b-bit quantized
    innovation — what WOULD ride the wire — not of the raw innovation."""
    rule = CommRule(kind="cinn", quantize_bits=2)
    strat = strategy_for(rule)
    comm = _state(rule)  # worker_grads = 0 ⇒ innovation = fresh
    fresh = _wtree([[1.0, 0.4], [0.0, 0.0]], [[0.0], [0.0]])
    lhs, _ = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    expect = per_worker_sq_norm(per_worker_quantize_dequantize(fresh, 2))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(expect))
    # 2-bit levels = {−1, 0, 1}·scale: 0.4/1.0 rounds to 0 ⇒ lhs = 1, not
    # the raw 1.16 — the gate sees exactly the compressed signal
    np.testing.assert_allclose(np.asarray(lhs), [1.0, 0.0])


def test_laq_lhs_and_residual_transition():
    """Full LAQ: the wire is Q_b(δ + e), the gate is its energy, and the
    uploader's residual absorbs exactly the quantization error."""
    rule = CommRule(kind="laq", quantize_bits=2)
    strat = strategy_for(rule)
    comm = _state(rule)  # worker_grads = 0, residual = 0 ⇒ corrected = fresh
    fresh = _wtree([[1.0, 0.4], [0.0, 0.0]], [[0.0], [0.0]])
    lhs, cache = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    # 2-bit: 0.4/1.0 rounds to 0 ⇒ wire row0 = [1, 0], energy 1
    np.testing.assert_allclose(np.asarray(lhs), [1.0, 0.0])
    q, corrected = cache
    np.testing.assert_allclose(np.asarray(q["w"]), [[1.0, 0.0], [0.0, 0.0]])
    wire = strat.wire_delta(_ctx(rule, fresh, comm), comm.extras, cache,
                            None)
    assert wire is q  # the gate's plane IS the wire — no recompute
    ex = strat.post_upload(comm.extras, cache, jnp.array([True, False]),
                           _ctx(rule, fresh, comm))
    # uploader keeps the rounding error; skipper's residual untouched
    np.testing.assert_allclose(np.asarray(ex["residual"]["w"]),
                               [[0.0, 0.4], [0.0, 0.0]])

    # residual feeds the NEXT wire: e=[0,0.4] + fresh ⇒ corrected=[1,0.8],
    # which now rounds to [1, 1]·scale
    comm2 = comm._replace(extras=ex)
    lhs2, cache2 = strat.lhs(_ctx(rule, fresh, comm2), ex)
    np.testing.assert_allclose(np.asarray(cache2[0]["w"][0]), [1.0, 1.0])

    # error_feedback=False pins e ≡ 0
    rule_no = CommRule(kind="laq", quantize_bits=2, error_feedback=False)
    strat_no = strategy_for(rule_no)
    ex_no = strat_no.post_upload(comm.extras, cache,
                                 jnp.array([True, True]),
                                 _ctx(rule_no, fresh, comm))
    np.testing.assert_array_equal(np.asarray(ex_no["residual"]["w"]), 0.0)


def test_laq_error_feedback_bounded_vs_memory_free_exact():
    """Error-retention semantics, pinned (found in review): the lazy
    INNOVATION δ = fresh − stale already re-injects compression error once
    (the stale copy absorbs only the quantized wire), so the textbook
    residual injects it twice — on a stationary gradient the
    error_feedback=True stale copies oscillate INSIDE the quantization
    band (bounded, EF-SGD-grade) and never lock on, while the memory-free
    error_feedback=False variant locks on exactly within a few rounds."""
    params4 = {"w": jnp.zeros(4)}
    g = jnp.array([[1.0, 0.37, -0.8, 0.05]])  # one worker, constant grad

    def vgrad(params, batch):
        return jnp.zeros((1,)), {"w": g}

    def errs(error_feedback):
        rule = CommRule(kind="laq", c=0.0, d_max=4, max_delay=3,
                        quantize_bits=2, error_feedback=error_feedback)
        strat = strategy_for(rule)
        comm = init_comm_state(strat, params4, 1)
        out = []
        for k in range(12):
            res = comm_round(strat, comm, params4, None, jnp.asarray(k),
                             vgrad=vgrad)
            comm = res.comm
            out.append(float(jnp.max(jnp.abs(
                comm.worker_grads["w"] - g))))
        return out

    exact = errs(False)
    assert all(e == 0.0 for e in exact[4:]), exact   # locks on exactly
    textbook = errs(True)
    band = float(jnp.max(jnp.abs(g)))                # 2-bit scale ≈ max|g|
    assert all(e <= band for e in textbook), textbook  # bounded (EF-SGD)
    assert all(e > 0.0 for e in textbook), textbook    # never locks on


def test_topk_sparsifies_and_carries_dropped_mass():
    """topk keeps the ⌈frac·size⌉ largest-|·| entries per (worker, leaf);
    the dropped entries land in the residual on upload."""
    from repro.core.quantize import per_worker_topk_sparsify
    rule = CommRule(kind="topk", topk_frac=0.5)
    strat = strategy_for(rule)
    comm = _state(rule)
    fresh = _wtree([[3.0, 1.0], [-2.0, 5.0]], [[0.5], [-0.25]])
    lhs, cache = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    wire, corrected = cache
    # w: k=1 per row keeps the largest-|·| entry; b: size-1 leaf keeps all
    np.testing.assert_allclose(np.asarray(wire["w"]),
                               [[3.0, 0.0], [0.0, 5.0]])
    np.testing.assert_allclose(np.asarray(wire["b"]), [[0.5], [-0.25]])
    np.testing.assert_allclose(np.asarray(lhs),
                               [9.0 + 0.25, 25.0 + 0.0625])
    ex = strat.post_upload(comm.extras, cache, jnp.array([True, False]),
                           _ctx(rule, fresh, comm))
    np.testing.assert_allclose(np.asarray(ex["residual"]["w"]),
                               [[0.0, 1.0], [0.0, 0.0]])
    # the standalone op agrees with the strategy's wire
    sp = per_worker_topk_sparsify(fresh, 0.5)
    np.testing.assert_array_equal(np.asarray(sp["w"]),
                                  np.asarray(wire["w"]))


def test_avp_period_gate_and_adaptation():
    """avp uploads exactly when staleness reaches the per-worker period,
    and the period walks down (up) while the innovation energy is above
    (below) the shared RHS, clipped to the configured bounds."""
    rule = CommRule(kind="avp", c=4.0, d_max=4, max_delay=10,
                    period_min=1, period_max=5)
    strat = strategy_for(rule)
    comm = _state(rule)._replace(staleness=jnp.array([2, 3], jnp.int32))
    assert int(comm.extras["period"][0]) == 1  # starts at period_min
    extras = {"period": jnp.array([3, 3], jnp.int32)}
    fresh = _wtree([[4.0, 0.0], [0.0, 0.0]], [[0.0], [0.0]])
    lhs, energy = strat.lhs(_ctx(rule, fresh, comm), extras)
    # worker 0: staleness 2 < period 3 ⇒ −inf; worker 1: 3 ≥ 3 ⇒ +inf
    assert np.asarray(lhs)[0] == -np.inf and np.asarray(lhs)[1] == np.inf
    np.testing.assert_allclose(np.asarray(energy), [16.0, 0.0])
    # rhs = (c/d_max)·Σ diff_hist = 1: worker 0 (16 > 1) shrinks, worker 1
    # (0 ≤ 1) grows
    comm_rhs = comm._replace(diff_hist=jnp.full((4,), 0.25, jnp.float32))
    ex = strat.post_upload(extras, energy, jnp.array([False, True]),
                           _ctx(rule, fresh, comm_rhs))
    np.testing.assert_array_equal(np.asarray(ex["period"]), [2, 4])
    # clipping at both bounds
    ex_lo = strat.post_upload({"period": jnp.array([1, 5], jnp.int32)},
                              energy, jnp.array([True, True]),
                              _ctx(rule, fresh, comm_rhs))
    np.testing.assert_array_equal(np.asarray(ex_lo["period"]), [1, 5])


def test_new_rule_bytes_accounting():
    """laq = b-bit dense; topk = sparse k·(value+index) bits; avp = full
    fp32 — and the compressed rules undercut 'always' per upload."""
    import math
    n = 46
    full = strategy_for(CommRule(kind="always")).bytes_per_upload(n)
    laq = strategy_for(CommRule(kind="laq")).bytes_per_upload(n)
    assert laq == n * 1.0 < full  # 8-bit default
    assert strategy_for(
        CommRule(kind="laq", quantize_bits=4)).bytes_per_upload(n) == n / 2
    topk = strategy_for(
        CommRule(kind="topk", topk_frac=0.1)).bytes_per_upload(n)
    k = math.ceil(0.1 * n)
    assert topk == k * (32 + math.ceil(math.log2(n))) / 8.0 < full
    assert strategy_for(CommRule(kind="avp")).bytes_per_upload(n) == full


def test_cinn_single_quantize_per_round_bit_equal(monkeypatch):
    """Satellite regression: the round quantizes the innovation ONCE (the
    gate's plane is reused for the wire) and the trajectory is bit-equal
    to the old quantize-twice path, on both state planes."""
    import repro.core.comm as comm_mod
    from repro.core.comm import CommStrategy, CompressedInnovationStrategy
    from repro.core.engine import CADAEngine, make_sampler
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.models.small import logreg_init, logreg_loss
    from repro.optim.fused import FusedAMSGrad

    m, steps = 3, 6
    ds = ijcnn1_like(n=300)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 16)
    params = logreg_init(None, 22, 2)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(2),
                                                steps))
    rule = CommRule(kind="cinn", c=5.0, d_max=4, max_delay=6)

    def run(fused):
        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, m,
                         fused=fused)
        return jax.jit(eng.run)(eng.init(params), batches)

    results = {}
    for fused in (False, True):
        results[("new", fused)] = run(fused)
    # old behaviour: the wire recomputes transform_delta instead of
    # reusing the gate's cache
    monkeypatch.setattr(CompressedInnovationStrategy, "wire_delta",
                        CommStrategy.wire_delta)
    monkeypatch.setattr(CompressedInnovationStrategy, "flat_wire_delta",
                        CommStrategy.flat_wire_delta)
    for fused in (False, True):
        results[("old", fused)] = run(fused)
    monkeypatch.undo()
    for fused in (False, True):
        (st_n, mets_n), (st_o, mets_o) = (results[("new", fused)],
                                          results[("old", fused)])
        np.testing.assert_array_equal(np.asarray(mets_n["upload_mask"]),
                                      np.asarray(mets_o["upload_mask"]))
        for a, b in zip(jax.tree.leaves(st_n.params),
                        jax.tree.leaves(st_o.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # ...and the new path emits exactly ONE quantization per round: count
    # quantizer invocations while tracing a single step on each plane
    calls = {"n": 0}
    real_q = comm_mod.per_worker_quantize_dequantize
    real_qf = comm_mod.per_worker_quantize_dequantize_flat

    def counting_q(tree, bits):
        calls["n"] += 1
        return real_q(tree, bits)

    def counting_qf(layout, buf, bits):
        calls["n"] += 1
        return real_qf(layout, buf, bits)

    monkeypatch.setattr(comm_mod, "per_worker_quantize_dequantize",
                        counting_q)
    monkeypatch.setattr(comm_mod, "per_worker_quantize_dequantize_flat",
                        counting_qf)
    batch = jax.tree.map(lambda x: x[0], batches)
    for fused in (False, True):
        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, m,
                         fused=fused)
        calls["n"] = 0
        jax.jit(eng.step).lower(eng.init(params), batch)
        assert calls["n"] == 1, (fused, calls["n"])


# ------------------------------------------------------ state transitions

def test_cada2_post_upload_updates_only_uploaders():
    rule = CommRule(kind="cada2")
    strat = strategy_for(rule)
    comm = _state(rule)
    old = jax.tree.map(lambda x: x - 5.0, comm.extras["worker_params"])
    upload = jnp.array([True, False])
    ex = strat.post_upload({"worker_params": old}, None, upload,
                           _ctx(rule, None, comm))
    got = np.asarray(ex["worker_params"]["w"])
    np.testing.assert_allclose(got[0], np.asarray(PARAMS["w"]))      # θ^k
    np.testing.assert_allclose(got[1], np.asarray(old["w"][1]))      # kept


def test_cada1_post_upload_updates_only_uploaders():
    rule = CommRule(kind="cada1")
    strat = strategy_for(rule)
    comm = _state(rule)
    delta_fresh = _wtree([[1.0, 2.0], [3.0, 4.0]], [[5.0], [6.0]])
    upload = jnp.array([False, True])
    ex = strat.post_upload(comm.extras, delta_fresh, upload,
                           _ctx(rule, None, comm))
    got = np.asarray(ex["worker_delta"]["w"])
    np.testing.assert_allclose(got[0], [0.0, 0.0])                   # kept
    np.testing.assert_allclose(got[1], [3.0, 4.0])                   # δ̃^k


# ------------------------------------------------------ shared comm_round

def _quad_vgrads():
    """Per-worker gradients of ½||w − t_m||² with per-worker targets."""
    targets = jnp.array([[2.0, 0.0], [0.0, -2.0]])

    def loss(params, t):
        return 0.5 * jnp.sum((params["w"] - t) ** 2)

    vgrad = jax.vmap(jax.value_and_grad(loss), in_axes=(None, 0))
    vgrad_per = jax.vmap(jax.value_and_grad(loss), in_axes=(0, 0))
    return targets, vgrad, vgrad_per


def test_comm_round_first_iteration_uploads_everywhere():
    """τ_m is initialized to D, so iteration 0 force-uploads; afterwards
    staleness resets to 1 for uploaders."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e9, d_max=4, max_delay=10)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)
    out = comm_round(strat, comm, params, targets, jnp.asarray(0),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    assert np.asarray(out.upload).all()
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [1, 1])
    # eq. (3): ∇ = mean of uploaded fresh gradients (innovation from zero)
    np.testing.assert_allclose(np.asarray(out.comm.nabla["w"]),
                               np.asarray(jnp.mean(-targets, axis=0)))
    # server copies of worker contributions match what was uploaded
    np.testing.assert_allclose(np.asarray(out.comm.worker_grads["w"]),
                               np.asarray(-targets))


def test_comm_round_skip_increments_staleness_and_keeps_state():
    """With a huge RHS (c→∞ via diff_hist) nobody uploads: staleness +1,
    ∇ and stale trees untouched, accounting reports zero."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e12, d_max=4, max_delay=10)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)._replace(
        staleness=jnp.array([1, 3], jnp.int32),
        diff_hist=jnp.full((4,), 1.0, jnp.float32))
    out = comm_round(strat, comm, params, targets, jnp.asarray(5),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    assert not np.asarray(out.upload).any()
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [2, 4])
    np.testing.assert_allclose(np.asarray(out.comm.nabla["w"]),
                               np.asarray(comm.nabla["w"]))
    assert int(out.metrics["uploads"]) == 0
    assert float(out.metrics["bytes_up"]) == 0.0
    assert float(out.metrics["skip_rate"]) == 1.0


def test_comm_round_staleness_cap_forces_upload():
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e12, d_max=4, max_delay=5)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)._replace(
        staleness=jnp.array([2, 5], jnp.int32),
        diff_hist=jnp.full((4,), 1.0, jnp.float32))
    out = comm_round(strat, comm, params, targets, jnp.asarray(7),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    np.testing.assert_array_equal(np.asarray(out.upload), [False, True])
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [3, 1])


def test_comm_round_quantized_wire_keeps_sides_in_sync():
    """With a quantized wire format the server's worker copy equals the
    round-tripped innovation, not the raw gradient (LAQ sync property)."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="cinn", c=0.0, d_max=4, max_delay=10,
                    quantize_bits=2)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)
    out = comm_round(strat, comm, params, targets, jnp.asarray(0),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    fresh = -targets  # gradient of the quadratic at w=0
    q = per_worker_quantize_dequantize({"w": fresh}, 2)["w"]
    np.testing.assert_allclose(np.asarray(out.comm.worker_grads["w"]),
                               np.asarray(q))


def test_bytes_accounting_per_strategy():
    """32-bit uploads for unquantized paper rules; b-bit when quantized;
    the compressed-innovation rule defaults to 8-bit."""
    n = 3  # params entries in PARAMS
    assert strategy_for(CommRule(kind="cada2")).bytes_per_upload(n) == 4 * n
    assert strategy_for(
        CommRule(kind="cada2", quantize_bits=4)).bytes_per_upload(n) \
        == 0.5 * n
    assert strategy_for(CommRule(kind="cinn")).bytes_per_upload(n) == n
    assert strategy_for(
        CommRule(kind="cinn", quantize_bits=16)).bytes_per_upload(n) \
        == 2 * n


def test_record_progress_ring_buffer():
    rule = CommRule(kind="lag", d_max=3)
    comm = init_comm_state(strategy_for(rule), PARAMS, M)
    for k, v in enumerate([1.0, 2.0, 3.0, 4.0]):
        comm = record_progress(comm, jnp.asarray(v), jnp.asarray(k))
    # k=3 wrapped onto slot 0: [4, 2, 3]
    np.testing.assert_allclose(np.asarray(comm.diff_hist), [4.0, 2.0, 3.0])


# ------------------------------------------------------------ tree helpers

def test_select_rows_keeps_storage_dtype():
    old = {"w": jnp.zeros((2, 2), jnp.bfloat16)}
    new = {"w": jnp.ones((2, 2), jnp.float32)}
    out = select_rows(jnp.array([True, False]), new, old)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               [[1, 1], [0, 0]])


def test_broadcast_and_sq_norm():
    t = broadcast_to_workers({"w": jnp.array([3.0, 4.0])}, 2)
    assert t["w"].shape == (2, 2)
    np.testing.assert_allclose(np.asarray(per_worker_sq_norm(t)), [25, 25])


# ------------------------------------------------- wire payload accounting

def _quantized_codes_fit(wire, delta, layout, bits):
    """Every quantized wire entry must be a b-bit code times its
    (worker, segment) scale: code = wire·levels/scale is an integer with
    |code| ≤ 2^(b-1)−1. (The per-segment scales themselves are the
    accounting's O(#leaves) overhead, deliberately excluded — the
    contract ``bytes_per_upload`` charges is n·b bits of codes.)"""
    levels = float(2 ** (bits - 1) - 1)
    w = np.asarray(wire, np.float64)
    d = np.asarray(delta, np.float64)
    for o, s in zip(layout.offsets, layout.sizes):
        seg_w, seg_d = w[:, o:o + s], d[:, o:o + s]
        scale = np.maximum(np.abs(seg_d).max(axis=1, keepdims=True), 1e-12)
        codes = seg_w * levels / scale
        assert np.abs(codes).max() <= levels + 1e-3
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(sizes=st.lists(st.integers(2, 40), min_size=1, max_size=4),
       bits=st.sampled_from([0, 4, 8]),
       frac=st.floats(0.05, 0.9))
def test_bytes_per_upload_equals_actual_wire_payload(sizes, bits, frac):
    """Satellite property gate: for EVERY registered rule, the
    ``bytes_per_upload`` the sim's link model trusts equals the payload
    the strategy's wire actually carries — dense fp32 entries, b-bit
    quantized codes, or sparse (value, index) pairs."""
    from repro.core.flat import (layout_of, per_worker_topk_extract_flat,
                                 sparse_rows_to_dense)
    from repro.core.quantize import topk_count

    m = 3
    tree = {f"l{i}": jnp.zeros((s,), jnp.float32)
            for i, s in enumerate(sizes)}
    layout = layout_of(tree)
    n = layout.n
    rng = np.random.default_rng(42)
    delta = jnp.asarray(rng.normal(size=(m, layout.n_flat)), jnp.float32)
    if layout.n_flat > n:
        delta = delta.at[:, n:].set(0.0)

    for kind in strategy_kinds():
        if kind == "topk":
            rule = CommRule(kind=kind, topk_frac=frac)
        elif kind in ("cinn", "laq"):
            rule = CommRule(kind=kind, quantize_bits=bits or 0)
        else:
            rule = CommRule(kind=kind, quantize_bits=bits)
        strat = strategy_for(rule)
        accounted = strat.bytes_per_upload(n)

        if kind == "topk":
            wire = strat._compress_flat(layout, delta)
            vals, idx = per_worker_topk_extract_flat(layout, wire, frac)
            k_leaf = sum(topk_count(s, frac) for s in layout.sizes)
            k_acc = topk_count(n, frac)
            # the payload is K (value, index) pairs; the global-k
            # accounting may undercharge by at most one per leaf
            assert vals.shape == idx.shape == (m, k_leaf)
            assert k_acc <= k_leaf <= k_acc + len(layout.sizes)
            index_bits = max(1, int(np.ceil(np.log2(n))))
            assert accounted == k_acc * (32 + index_bits) / 8.0
            # ... and the pairs really carry the whole support
            np.testing.assert_array_equal(
                np.asarray(sparse_rows_to_dense(idx, vals, layout.n_flat)),
                np.asarray(wire))
            assert int((np.asarray(wire)[:, :n] != 0).sum(axis=1).max()) \
                <= k_leaf
        elif kind in ("cinn", "laq") or bits:
            b = bits or 8      # cinn/laq default to 8-bit wires
            # laq's wire is its error-feedback compressor (which applies
            # the 8-bit default even when quantize_bits is unset);
            # everyone else's is transform_delta_flat
            wire = (strat._compress_flat(layout, delta) if kind == "laq"
                    else strat.transform_delta_flat(layout, delta))
            _quantized_codes_fit(np.asarray(wire)[:, :n],
                                 np.asarray(delta)[:, :n], layout, b)
            assert accounted == n * b / 8.0
        else:
            # dense fp32: the wire IS the innovation, n entries at 32 bits
            wire = strat.transform_delta_flat(layout, delta)
            np.testing.assert_array_equal(np.asarray(wire),
                                          np.asarray(delta))
            assert accounted == n * 4.0
