"""Unit tests for the strategy layer (core/comm.py): registry, hand-computed
LHS values, post-upload state transitions, wire format, and accounting —
per strategy, including the beyond-paper compressed-innovation rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import (CommContext, CommState, broadcast_to_workers,
                             comm_round, init_comm_state, per_worker_sq_norm,
                             record_progress, select_rows, strategy_for,
                             strategy_kinds)
from repro.core.quantize import per_worker_quantize_dequantize
from repro.core.rules import RULES, CommRule

M = 2
PARAMS = {"w": jnp.array([1.0, -1.0]), "b": jnp.array([0.5])}


def _state(rule, **over):
    s = init_comm_state(strategy_for(rule), PARAMS, M)
    return s._replace(**over) if over else s


def _ctx(rule, fresh, comm, *, k=0, vgrad=None, vgrad_per=None):
    return CommContext(params=PARAMS, batch=None, fresh=fresh, comm=comm,
                       step=jnp.asarray(k), m=M, vgrad=vgrad,
                       vgrad_per=vgrad_per)


def _wtree(w0, w1):
    """Per-worker tree with hand-set rows."""
    return {"w": jnp.array(w0), "b": jnp.array(w1)}


# ------------------------------------------------------------------ registry

def test_registry_covers_all_rule_kinds():
    assert set(strategy_kinds()) == set(RULES)
    for kind in RULES:
        s = strategy_for(CommRule(kind=kind))
        assert s.kind == kind
        assert s.rule.kind == kind


def test_unknown_kind_raises():
    rule = CommRule(kind="cada2")
    object.__setattr__(rule, "kind", "bogus")  # bypass __post_init__
    with pytest.raises(ValueError, match="bogus"):
        strategy_for(rule)


def test_grad_evals_delegate_to_strategy():
    """CommRule.grad_evals_per_iter is the strategy's accounting (§2.2)."""
    for kind in RULES:
        expect = 2 if kind in ("cada1", "cada2") else 1
        assert CommRule(kind=kind).grad_evals_per_iter == expect
        assert strategy_for(CommRule(kind=kind)).grad_evals_per_iter == expect


# ------------------------------------------------------- hand-computed LHS

def test_lag_lhs_hand_computed():
    """eq. (5): LHS_m = ||∇ℓ(θ^k;ξ^k) − last contributed ∇||²."""
    rule = CommRule(kind="lag")
    strat = strategy_for(rule)
    comm = _state(rule, worker_grads=_wtree([[0.0, 0.0], [1.0, 0.0]],
                                            [[0.0], [2.0]]))
    fresh = _wtree([[1.0, 1.0], [2.0, 0.0]], [[0.0], [2.0]])
    lhs, cache = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    # worker 0: (1² + 1²) + 0² = 2 ; worker 1: 1² + 0 = 1
    np.testing.assert_allclose(np.asarray(lhs), [2.0, 1.0])
    assert cache is None


def test_cada2_lhs_hand_computed():
    """eq. (10): LHS_m = ||∇ℓ(θ^k;ξ) − ∇ℓ(θ^{k−τ_m};ξ)||², stale gradient
    re-evaluated at the SAME sample via vgrad_per."""
    rule = CommRule(kind="cada2")
    strat = strategy_for(rule)
    comm = _state(rule)
    stale = _wtree([[0.5, 0.0], [0.0, 0.0]], [[0.0], [1.0]])

    def vgrad_per(wparams, batch):
        return jnp.zeros((M,)), stale

    fresh = _wtree([[1.5, 0.0], [0.0, 2.0]], [[0.0], [1.0]])
    lhs, _ = strat.lhs(_ctx(rule, fresh, comm, vgrad_per=vgrad_per),
                       comm.extras)
    # worker 0: 1² ; worker 1: 2²
    np.testing.assert_allclose(np.asarray(lhs), [1.0, 4.0])


def test_cada1_lhs_and_snapshot_refresh():
    """eq. (7): LHS_m = ||δ̃_m^k − δ̃_m^{k−τ}||² with δ̃ = fresh − snap;
    the snapshot refreshes every D iterations (pre_step)."""
    rule = CommRule(kind="cada1", max_delay=10)
    strat = strategy_for(rule)
    comm = _state(rule)
    # stored innovation δ̃^{k−τ} = 1 everywhere for worker 0, 0 for worker 1
    stored = _wtree([[1.0, 1.0], [0.0, 0.0]], [[1.0], [0.0]])
    extras = {**comm.extras, "worker_delta": stored}

    snap_grads = _wtree([[0.0, 0.0], [0.0, 0.0]], [[0.0], [0.0]])

    def vgrad(params, batch):
        return jnp.zeros((M,)), snap_grads

    fresh = _wtree([[1.0, 1.0], [2.0, 0.0]], [[1.0], [0.0]])
    lhs, delta_fresh = strat.lhs(
        _ctx(rule, fresh, comm, vgrad=vgrad), extras)
    # δ̃^k = fresh − 0 = fresh; worker 0 diff = 0, worker 1 diff = 2²
    np.testing.assert_allclose(np.asarray(lhs), [0.0, 4.0])
    np.testing.assert_allclose(np.asarray(delta_fresh["w"]),
                               np.asarray(fresh["w"]))

    # pre_step: k % D == 0 refreshes θ̃ to current params, else keeps it
    stale_snap = jax.tree.map(lambda p: p + 7.0, PARAMS)
    ex = strat.pre_step({**extras, "snapshot": stale_snap}, PARAMS,
                        jnp.asarray(10))
    np.testing.assert_allclose(np.asarray(ex["snapshot"]["w"]),
                               np.asarray(PARAMS["w"]))
    ex = strat.pre_step({**extras, "snapshot": stale_snap}, PARAMS,
                        jnp.asarray(3))
    np.testing.assert_allclose(np.asarray(ex["snapshot"]["w"]),
                               np.asarray(stale_snap["w"]))


def test_always_lhs_is_infinite():
    rule = CommRule(kind="always")
    strat = strategy_for(rule)
    assert strat.stateless
    lhs, _ = strat.lhs(_ctx(rule, None, _state(rule)), {})
    assert np.all(np.isinf(np.asarray(lhs)))


def test_cinn_lhs_is_quantized_innovation_energy():
    """Beyond-paper rule: LHS is the energy of the b-bit quantized
    innovation — what WOULD ride the wire — not of the raw innovation."""
    rule = CommRule(kind="cinn", quantize_bits=2)
    strat = strategy_for(rule)
    comm = _state(rule)  # worker_grads = 0 ⇒ innovation = fresh
    fresh = _wtree([[1.0, 0.4], [0.0, 0.0]], [[0.0], [0.0]])
    lhs, _ = strat.lhs(_ctx(rule, fresh, comm), comm.extras)
    expect = per_worker_sq_norm(per_worker_quantize_dequantize(fresh, 2))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(expect))
    # 2-bit levels = {−1, 0, 1}·scale: 0.4/1.0 rounds to 0 ⇒ lhs = 1, not
    # the raw 1.16 — the gate sees exactly the compressed signal
    np.testing.assert_allclose(np.asarray(lhs), [1.0, 0.0])


# ------------------------------------------------------ state transitions

def test_cada2_post_upload_updates_only_uploaders():
    rule = CommRule(kind="cada2")
    strat = strategy_for(rule)
    comm = _state(rule)
    old = jax.tree.map(lambda x: x - 5.0, comm.extras["worker_params"])
    upload = jnp.array([True, False])
    ex = strat.post_upload({"worker_params": old}, None, upload,
                           _ctx(rule, None, comm))
    got = np.asarray(ex["worker_params"]["w"])
    np.testing.assert_allclose(got[0], np.asarray(PARAMS["w"]))      # θ^k
    np.testing.assert_allclose(got[1], np.asarray(old["w"][1]))      # kept


def test_cada1_post_upload_updates_only_uploaders():
    rule = CommRule(kind="cada1")
    strat = strategy_for(rule)
    comm = _state(rule)
    delta_fresh = _wtree([[1.0, 2.0], [3.0, 4.0]], [[5.0], [6.0]])
    upload = jnp.array([False, True])
    ex = strat.post_upload(comm.extras, delta_fresh, upload,
                           _ctx(rule, None, comm))
    got = np.asarray(ex["worker_delta"]["w"])
    np.testing.assert_allclose(got[0], [0.0, 0.0])                   # kept
    np.testing.assert_allclose(got[1], [3.0, 4.0])                   # δ̃^k


# ------------------------------------------------------ shared comm_round

def _quad_vgrads():
    """Per-worker gradients of ½||w − t_m||² with per-worker targets."""
    targets = jnp.array([[2.0, 0.0], [0.0, -2.0]])

    def loss(params, t):
        return 0.5 * jnp.sum((params["w"] - t) ** 2)

    vgrad = jax.vmap(jax.value_and_grad(loss), in_axes=(None, 0))
    vgrad_per = jax.vmap(jax.value_and_grad(loss), in_axes=(0, 0))
    return targets, vgrad, vgrad_per


def test_comm_round_first_iteration_uploads_everywhere():
    """τ_m is initialized to D, so iteration 0 force-uploads; afterwards
    staleness resets to 1 for uploaders."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e9, d_max=4, max_delay=10)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)
    out = comm_round(strat, comm, params, targets, jnp.asarray(0),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    assert np.asarray(out.upload).all()
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [1, 1])
    # eq. (3): ∇ = mean of uploaded fresh gradients (innovation from zero)
    np.testing.assert_allclose(np.asarray(out.comm.nabla["w"]),
                               np.asarray(jnp.mean(-targets, axis=0)))
    # server copies of worker contributions match what was uploaded
    np.testing.assert_allclose(np.asarray(out.comm.worker_grads["w"]),
                               np.asarray(-targets))


def test_comm_round_skip_increments_staleness_and_keeps_state():
    """With a huge RHS (c→∞ via diff_hist) nobody uploads: staleness +1,
    ∇ and stale trees untouched, accounting reports zero."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e12, d_max=4, max_delay=10)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)._replace(
        staleness=jnp.array([1, 3], jnp.int32),
        diff_hist=jnp.full((4,), 1.0, jnp.float32))
    out = comm_round(strat, comm, params, targets, jnp.asarray(5),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    assert not np.asarray(out.upload).any()
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [2, 4])
    np.testing.assert_allclose(np.asarray(out.comm.nabla["w"]),
                               np.asarray(comm.nabla["w"]))
    assert int(out.metrics["uploads"]) == 0
    assert float(out.metrics["bytes_up"]) == 0.0
    assert float(out.metrics["skip_rate"]) == 1.0


def test_comm_round_staleness_cap_forces_upload():
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="lag", c=1e12, d_max=4, max_delay=5)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)._replace(
        staleness=jnp.array([2, 5], jnp.int32),
        diff_hist=jnp.full((4,), 1.0, jnp.float32))
    out = comm_round(strat, comm, params, targets, jnp.asarray(7),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    np.testing.assert_array_equal(np.asarray(out.upload), [False, True])
    np.testing.assert_array_equal(np.asarray(out.comm.staleness), [3, 1])


def test_comm_round_quantized_wire_keeps_sides_in_sync():
    """With a quantized wire format the server's worker copy equals the
    round-tripped innovation, not the raw gradient (LAQ sync property)."""
    targets, vgrad, vgrad_per = _quad_vgrads()
    params = {"w": jnp.zeros(2)}
    rule = CommRule(kind="cinn", c=0.0, d_max=4, max_delay=10,
                    quantize_bits=2)
    strat = strategy_for(rule)
    comm = init_comm_state(strat, params, M)
    out = comm_round(strat, comm, params, targets, jnp.asarray(0),
                     vgrad=vgrad, vgrad_per=vgrad_per)
    fresh = -targets  # gradient of the quadratic at w=0
    q = per_worker_quantize_dequantize({"w": fresh}, 2)["w"]
    np.testing.assert_allclose(np.asarray(out.comm.worker_grads["w"]),
                               np.asarray(q))


def test_bytes_accounting_per_strategy():
    """32-bit uploads for unquantized paper rules; b-bit when quantized;
    the compressed-innovation rule defaults to 8-bit."""
    n = 3  # params entries in PARAMS
    assert strategy_for(CommRule(kind="cada2")).bytes_per_upload(n) == 4 * n
    assert strategy_for(
        CommRule(kind="cada2", quantize_bits=4)).bytes_per_upload(n) \
        == 0.5 * n
    assert strategy_for(CommRule(kind="cinn")).bytes_per_upload(n) == n
    assert strategy_for(
        CommRule(kind="cinn", quantize_bits=16)).bytes_per_upload(n) \
        == 2 * n


def test_record_progress_ring_buffer():
    rule = CommRule(kind="lag", d_max=3)
    comm = init_comm_state(strategy_for(rule), PARAMS, M)
    for k, v in enumerate([1.0, 2.0, 3.0, 4.0]):
        comm = record_progress(comm, jnp.asarray(v), jnp.asarray(k))
    # k=3 wrapped onto slot 0: [4, 2, 3]
    np.testing.assert_allclose(np.asarray(comm.diff_hist), [4.0, 2.0, 3.0])


# ------------------------------------------------------------ tree helpers

def test_select_rows_keeps_storage_dtype():
    old = {"w": jnp.zeros((2, 2), jnp.bfloat16)}
    new = {"w": jnp.ones((2, 2), jnp.float32)}
    out = select_rows(jnp.array([True, False]), new, old)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               [[1, 1], [0, 0]])


def test_broadcast_and_sq_norm():
    t = broadcast_to_workers({"w": jnp.array([3.0, 4.0])}, 2)
    assert t["w"].shape == (2, 2)
    np.testing.assert_allclose(np.asarray(per_worker_sq_norm(t)), [25, 25])
