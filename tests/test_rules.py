"""CommRule unit + hypothesis property tests."""
import pytest
from hypothesis import given, strategies as st

from repro.core.rules import RULES, CommRule


def test_defaults_match_paper():
    r = CommRule()
    assert r.kind == "cada2"
    assert r.d_max == 10      # paper: logreg d_max=10
    assert r.max_delay == 50  # paper: NN D=50


@pytest.mark.parametrize("kind", RULES)
def test_valid_kinds(kind):
    CommRule(kind=kind)


def test_invalid_kind_raises():
    with pytest.raises(ValueError):
        CommRule(kind="bogus")


@pytest.mark.parametrize("bad", [dict(c=-1.0), dict(d_max=0),
                                 dict(max_delay=0)])
def test_invalid_params_raise(bad):
    with pytest.raises(ValueError):
        CommRule(**bad)


@given(kind=st.sampled_from(RULES),
       c=st.floats(0.0, 100.0, allow_nan=False),
       d_max=st.integers(1, 1000),
       max_delay=st.integers(1, 1000))
def test_rule_construction_total(kind, c, d_max, max_delay):
    """Any in-domain hyper-parameter combination constructs, and the
    grad-eval accounting matches §2.2 (2 evals for CADA, 1 otherwise)."""
    r = CommRule(kind=kind, c=c, d_max=d_max, max_delay=max_delay)
    assert r.grad_evals_per_iter == (2 if kind in ("cada1", "cada2") else 1)
