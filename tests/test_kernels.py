"""Per-kernel allclose vs the ref.py pure-jnp oracles, swept over shapes and
dtypes (interpret=True executes the kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cada_update import BLOCK


def _rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale).astype(dtype)


# ------------------------------------------------------- fused AMSGrad/CADA

@pytest.mark.parametrize("nblocks", [1, 2, 3])
@pytest.mark.parametrize("theta_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_amsgrad_matches_ref(rng, nblocks, theta_dtype):
    n = nblocks * BLOCK
    theta = _rand(rng, n).astype(theta_dtype)
    h = _rand(rng, n, scale=0.1)
    vhat = jnp.abs(_rand(rng, n, scale=0.01))
    g = _rand(rng, n)
    out_k = ops.fused_amsgrad_flat(theta, h, vhat, g, 0.01, interpret=True)
    out_r = ref.amsgrad_ref(theta, h, vhat, g, 0.01)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_fused_amsgrad_respects_hparams(rng):
    n = BLOCK
    theta, h = _rand(rng, n), _rand(rng, n, scale=0.1)
    vhat, g = jnp.abs(_rand(rng, n, scale=0.01)), _rand(rng, n)
    for b1, b2, eps, lr in [(0.8, 0.99, 1e-6, 0.1), (0.0, 0.999, 1e-8, 1.0)]:
        out_k = ops.fused_amsgrad_flat(theta, h, vhat, g, lr, b1=b1, b2=b2,
                                       eps=eps, interpret=True)
        out_r = ref.amsgrad_ref(theta, h, vhat, g, lr, b1=b1, b2=b2, eps=eps)
        for a, b in zip(out_k, out_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


def test_fused_amsgrad_vhat_monotone(rng):
    """AMSGrad invariant: v̂ never decreases."""
    n = BLOCK
    theta = _rand(rng, n)
    h = jnp.zeros(n)
    vhat = jnp.abs(_rand(rng, n, scale=0.01))
    for step in range(3):
        g = _rand(rng, n, scale=10.0 ** -step)
        theta, h, vhat_new, _ = ops.fused_amsgrad_flat(
            theta, h, vhat, g, 0.01, interpret=True)
        assert bool(jnp.all(vhat_new >= vhat - 1e-7))
        vhat = vhat_new


def test_diff_sq_norm_matches_ref(rng):
    for nblocks in (1, 4):
        n = nblocks * BLOCK
        a, b = _rand(rng, n), _rand(rng, n)
        d = ops.diff_sq_norm_flat(a, b, interpret=True)
        np.testing.assert_allclose(float(d), float(ref.diff_sq_norm_ref(a, b)),
                                   rtol=1e-5)


def test_pytree_fused_update_roundtrip(rng):
    """Mixed-dtype pytree: shapes/dtypes survive; padding is inert."""
    tree = {"w": _rand(rng, (300, 77), jnp.bfloat16),
            "b": _rand(rng, (33,), jnp.float32)}
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
    g = jax.tree.map(lambda x: jnp.ones(x.shape, jnp.float32) * 0.5, tree)
    p, h, vhat, sq = ops.fused_cada_update(tree, zeros, zeros, g, 0.1,
                                           interpret=True)
    assert p["w"].dtype == jnp.bfloat16 and p["b"].dtype == jnp.float32
    assert p["w"].shape == (300, 77)
    # fp32 oracle over the same tree
    _, _, _, sq_ref = ref.amsgrad_ref(
        jnp.zeros(300 * 77 + 33), jnp.zeros(300 * 77 + 33),
        jnp.zeros(300 * 77 + 33), jnp.full(300 * 77 + 33, 0.5), 0.1)
    np.testing.assert_allclose(float(sq), float(sq_ref), rtol=1e-5)


# ----------------------------------------------------------- selective scan

@pytest.mark.parametrize("shape", [(1, 64, 128, 16), (2, 128, 256, 16),
                                   (3, 64, 128, 64)])
def test_selective_scan_matches_ref(rng, shape):
    g, s, d, n = shape
    dt = jnp.abs(_rand(rng, (g, s, d), scale=0.1))
    x = _rand(rng, (g, s, d))
    a = -jnp.abs(_rand(rng, (g, d, n)))
    b = _rand(rng, (g, s, n))
    c = _rand(rng, (g, s, n))
    y_k, hf_k = ops.selective_scan(dt, x, a, b, c, chunk=32, dblk=128,
                                   interpret=True)
    y_r, hf_r = ref.selective_scan_ref(dt, x, a, b, c)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf_k), np.asarray(hf_r),
                               rtol=2e-4, atol=2e-4)


def test_selective_scan_bf16_inputs(rng):
    g, s, d, n = 1, 64, 128, 16
    dt = jnp.abs(_rand(rng, (g, s, d), scale=0.1))
    x = _rand(rng, (g, s, d), jnp.bfloat16)
    a = -jnp.abs(_rand(rng, (g, d, n)))
    b = _rand(rng, (g, s, n), jnp.bfloat16)
    c = _rand(rng, (g, s, n), jnp.bfloat16)
    y_k, hf_k = ops.selective_scan(dt, x, a, b, c, chunk=32, interpret=True)
    y_r, hf_r = ref.selective_scan_ref(dt, x, a, b, c)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-2, atol=2e-2)


def test_jnp_chunked_scan_matches_kernel_contract(rng):
    """models/ssm.selective_scan_jnp shares the kernel contract exactly."""
    from repro.models.ssm import selective_scan_jnp
    g, s, d, n = 2, 128, 64, 16
    dt = jnp.abs(_rand(rng, (g, s, d), scale=0.1))
    x = _rand(rng, (g, s, d))
    a2 = -jnp.abs(_rand(rng, (d, n)))
    b = _rand(rng, (g, s, n))
    c = _rand(rng, (g, s, n))
    y1, h1 = selective_scan_jnp(dt, x, a2, b, c, chunk=32)
    y2, h2 = ref.selective_scan_ref(dt, x,
                                    jnp.broadcast_to(a2[None], (g, d, n)),
                                    b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("window", [0, 100])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])
def test_pallas_flash_attention_matches_naive(rng, window, hq, hkv):
    from repro.models import attention as A
    b, s, hd = 2, 256, 128
    q = _rand(rng, (b, s, hq, hd))
    k = _rand(rng, (b, s, hkv, hd))
    v = _rand(rng, (b, s, hkv, hd))
    ref = A.naive_attention(q, k, v, window=window, dtype=jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, interpret=True,
                              q_blk=64, kv_blk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_pallas_flash_attention_block_invariance(rng):
    b, s, h, hd = 1, 256, 2, 128
    q, k, v = (_rand(rng, (b, s, h, hd)) for _ in range(3))
    base = ops.flash_attention(q, k, v, interpret=True, q_blk=256,
                               kv_blk=256)
    for qb, kb in ((64, 64), (128, 64), (64, 128)):
        out = ops.flash_attention(q, k, v, interpret=True, q_blk=qb,
                                  kv_blk=kb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-3, atol=2e-3)
