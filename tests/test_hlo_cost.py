"""Trip-count-aware HLO cost model: validated against XLA's own analysis on
scan-free modules, and against analytic expectations on scanned/sharded
ones."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo_cost import HloCostModel, analyze


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


def _xla_costs(comp):
    """compiled.cost_analysis() returns a dict on new jax, [dict] on 0.4.x."""
    c = comp.cost_analysis()
    return c[0] if isinstance(c, list) else c


def test_matches_xla_on_scan_free():
    def g(x, w):
        return jax.nn.relu(x @ w)

    comp = _compile(g, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                    jax.ShapeDtypeStruct((256, 512), jnp.float32))
    mine = analyze(comp.as_text())
    xla = _xla_costs(comp)
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.01
    assert abs(mine.bytes_accessed - xla["bytes accessed"]) \
        / xla["bytes accessed"] < 0.05


def test_scales_scan_by_trip_count():
    def f(x):
        def body(c, _):
            return c @ x, None
        c, _ = jax.lax.scan(body, jnp.ones((64, 64)), None, length=10)
        return c

    comp = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = analyze(comp.as_text())
    expected = 2 * 64 ** 3 * 10
    assert abs(mine.flops - expected) / expected < 0.01
    # XLA's flat analysis undercounts by ~10x here
    assert _xla_costs(comp)["flops"] < expected / 5


def test_nested_scans_multiply():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        c, _ = jax.lax.scan(outer, jnp.ones((32, 32)), None, length=3)
        return c

    comp = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    mine = analyze(comp.as_text())
    expected = 2 * 32 ** 3 * 12
    assert abs(mine.flops - expected) / expected < 0.02


def test_inplace_dus_not_charged_full_buffer():
    """Scan stacking into a (100, 1024, 64) buffer must charge per-slice
    bytes, not 100× the full buffer."""
    def f(x):
        def body(c, _):
            c = c @ x
            return c, c
        _, ys = jax.lax.scan(body, jnp.ones((1024, 64)), None, length=100)
        return ys

    comp = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = analyze(comp.as_text())
    buffer_bytes = 100 * 1024 * 64 * 4
    # full-buffer-per-iteration would be >= 100 × buffer ≈ 2.6e9
    assert mine.bytes_accessed < 20 * buffer_bytes


def test_parses_entry_and_computations():
    def g(x):
        return x * 2.0

    comp = _compile(g, jax.ShapeDtypeStruct((8,), jnp.float32))
    model = HloCostModel(comp.as_text())
    assert model.entry is not None
    assert model.entry in model.computations
