"""The unified telemetry plane (repro.obs).

Three contracts under test:

  * **no-op fast path** — ``NULL`` is falsy, allocation-free, and every
    method is a no-op (the <2% disabled-overhead contract's code path);
  * **timeline validity** — the Chrome-trace export of a traced run (the
    discrete-event sim on its simulated clock, the cohort pipeline on
    wall clock) passes the schema validator: per-worker + server tracks
    for the sim, per-round gather/step/scatter(/patch) spans for the
    pipeline;
  * **ledger parity** — for every registered grad rule and both
    delta-payload rules, :class:`repro.obs.metrics.CommLedger` totals
    are bit-equal to the engine's own property-pinned
    ``bytes_per_upload`` accounting (it sums the same fp32 round values
    in the same order).

Plus: the ``metrics_out`` drain-on-error contract (an interrupted cohort
run keeps every completed round's metrics), the registry sinks, and the
traced M=10⁴ cohort smoke the CI ``obs-smoke`` leg runs under the 6 GiB
cap.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import strategy_for
from repro.core.engine import CADAEngine, make_cohort_sampler, sample_cohorts
from repro.core.rules import RULES, CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss, mlp_init, mlp_loss
from repro.obs import (NULL, CommLedger, MetricsRegistry, NullTracer, Tracer,
                       as_tracer, to_chrome_trace, validate_chrome_trace,
                       write_chrome_trace)
from repro.optim.fused import FusedAMSGrad
from repro.sim import simulate

from tests.test_cohort_pipeline import (ARMS, C, M, STEPS, _cohort_run,
                                        _problem)


# ------------------------------------------------------- no-op fast path

def test_null_tracer_is_falsy_noop():
    """``if tracer:`` guards must skip work; every NULL method no-ops and
    the span context manager is one reusable object (no allocation)."""
    assert not NULL
    assert NULL.enabled is False
    assert isinstance(NULL, NullTracer)
    s1 = NULL.span("a", track="t", args={"k": 1})
    s2 = NULL.span("b")
    assert s1 is s2                       # reusable singleton, no alloc
    with s1:
        pass
    NULL.add_span("x", 0.0, 1.0, track="t")
    NULL.instant("x", 0.5)
    NULL.counter("x", 0.5, 3.0)
    assert NULL.aggregate() == {}
    assert NULL.aggregate("t") == {}


def test_as_tracer_normalizes():
    assert as_tracer(None) is NULL
    tr = Tracer()
    assert as_tracer(tr) is tr
    assert bool(tr) and tr.enabled


def test_null_span_swallows_nothing():
    """The null span must not suppress exceptions."""
    with pytest.raises(RuntimeError):
        with NULL.span("boom"):
            raise RuntimeError


# ------------------------------------------------------ tracer recording

def test_tracer_records_spans_instants_counters():
    tr = Tracer()
    with tr.span("work", track="main", cat="compute", args={"i": 0}):
        pass
    tr.add_span("transfer", 1.0, 0.25, track="worker 0", cat="transfer")
    tr.instant("gate", 1.25, track="worker 0", args={"upload": True})
    tr.counter("pool_bytes", 2.0, 123.0)
    assert len(tr) == 4
    assert tr.tracks == ["main", "worker 0", "counters"]  # insertion order
    phs = [e[0] for e in tr.events]
    assert phs == ["X", "X", "i", "C"]
    (ph, name, track, cat, t0, dur, args) = tr.events[1]
    assert (name, track, cat, t0, dur) == ("transfer", "worker 0",
                                           "transfer", 1.0, 0.25)
    spans = tr.spans("worker 0")
    assert [s[1] for s in spans] == ["transfer"]


def test_tracer_aggregate_per_track():
    """aggregate() is the one home for phase timing — count/total/max per
    span name, restricted to a track (what the bench reads)."""
    tr = Tracer()
    for dur in (0.1, 0.3, 0.2):
        tr.add_span("step", 0.0, dur, track="pipeline")
    tr.add_span("step", 0.0, 9.0, track="other")
    agg = tr.aggregate("pipeline")
    assert agg["step"]["count"] == 3
    np.testing.assert_allclose(agg["step"]["total_s"], 0.6)
    np.testing.assert_allclose(agg["step"]["max_s"], 0.3)
    assert tr.aggregate()["step"]["count"] == 4


# -------------------------------------------------- chrome-trace export

def test_chrome_trace_export_shape():
    tr = Tracer()
    tr.add_span("compute", 0.5, 1.5, track="worker 0", cat="compute",
                args={"round": 0})
    tr.instant("gate", 2.0, track="worker 0")
    tr.counter("depth", 2.5, 4.0)
    obj = to_chrome_trace(tr, meta={"rule": "cada2"})
    assert obj["otherData"] == {"rule": "cada2"}
    evs = obj["traceEvents"]
    # process name + 2 metadata records per track (name + sort index)
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in metas} == {"process_name", "thread_name",
                                          "thread_sort_index"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == 0.5e6 and x["dur"] == 1.5e6     # seconds -> µs
    assert x["cat"] == "compute" and x["args"] == {"round": 0}
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t"
    assert validate_chrome_trace(obj) == len(evs)


def test_chrome_trace_validator_rejects_garbage():
    with pytest.raises(ValueError):
        validate_chrome_trace({"notTraceEvents": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})  # no name/ts
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"ph": "?", "name": "a", "ts": 0.0,
                              "pid": 1, "tid": 1}]})


def test_export_cli_roundtrip(tmp_path):
    from repro.obs.export import main
    tr = Tracer()
    tr.add_span("round", 0.0, 1.0, track="server")
    path = tmp_path / "trace.json"
    write_chrome_trace(tr, str(path), meta={"runtime": "sim"})
    assert main(["--validate", str(path)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert main(["--validate", str(bad)]) != 0


# -------------------------------------------------------- registry sinks

def test_metrics_registry_sinks(tmp_path):
    reg = MetricsRegistry()
    reg.counter("uploads").inc(3)
    reg.gauge("pool.resident-bytes").set(512)
    reg.histogram("staleness", bounds=(1, 2, 4)).observe([0, 1, 3, 9])
    with pytest.raises(TypeError):
        reg.gauge("uploads")              # kind mismatch
    jl = tmp_path / "metrics.jsonl"
    reg.write_jsonl(str(jl), extra={"step": 7})
    reg.write_jsonl(str(jl), extra={"step": 8})
    rows = [json.loads(l) for l in jl.read_text().splitlines()]
    assert [r["step"] for r in rows] == [7, 8]
    assert rows[0]["uploads"] == 3.0
    assert rows[0]["staleness"]["count"] == 4
    prom = tmp_path / "metrics.prom"
    reg.write_prom(str(prom))
    text = prom.read_text()
    assert "repro_uploads 3" in text
    assert "repro_pool_resident_bytes 512" in text
    assert 'repro_staleness_bucket{le="+Inf"} 4' in text
    assert "repro_staleness_count 4" in text


# --------------------------------------------------------- ledger parity

@pytest.mark.parametrize("kind", ARMS)
def test_ledger_parity_all_rules(kind):
    """Acceptance gate: for every grad rule and both delta rules, the
    ledger's uploads/bytes totals are BIT-EQUAL to summing the engine's
    own round metrics (which are property-pinned ``bytes_per_upload``
    numbers) — the ledger introduces no second accounting."""
    cohorts = sample_cohorts(M, C, STEPS, seed=3)
    st, pool, mets, eng = _cohort_run(kind, cohorts, pipeline=True)

    led = CommLedger.for_strategy(eng.strategy)
    for met in mets:
        led.observe_round(jax.device_get(met))

    exp_uploads, exp_bytes = 0, 0.0
    for met in mets:
        exp_uploads += int(np.asarray(met["uploads"]))
        exp_bytes += float(np.asarray(met["bytes_up"]))
    assert led.rounds == STEPS
    assert led.uploads == exp_uploads
    assert led.bytes_up == exp_bytes      # bit-equal: same values, same order
    # and the metrics themselves are uploads × the pinned per-upload bytes
    # (priced on the UNPADDED flat length — padding never hits the wire)
    spb = eng.strategy.bytes_per_upload(eng._layout.n)
    assert led.bytes_up == exp_uploads * spb
    # wire-format split: everything lands in this rule's bucket
    s = led.summary()
    wf = eng.strategy.wire_format
    assert s["wire_format"] == wf
    np.testing.assert_allclose(s[f"mbytes_up_{wf}"], led.bytes_up / 1e6)
    for other in {"dense", "quantized", "sparse"} - {wf}:
        assert s[f"mbytes_up_{other}"] == 0.0
    assert sum(s["staleness_hist"].values()) == STEPS * C


def test_wire_format_property():
    as_strat = strategy_for(CommRule(kind="always", c=0.6, d_max=4,
                                     max_delay=10))
    assert as_strat.wire_format == "dense"
    laq = strategy_for(CommRule(kind="laq", c=0.6, d_max=4, max_delay=10))
    assert laq.wire_format == "quantized"
    topk = strategy_for(CommRule(kind="topk", c=0.6, d_max=4, max_delay=10,
                                 topk_frac=0.5, sparse_wire=True))
    assert topk.wire_format == "sparse"


def test_ledger_margin_and_staleness():
    led = CommLedger(rule="cada2")
    led.observe_margin([1.0, -2.0, np.inf, np.nan], 0.5)
    q = led.margin_quantiles()
    assert q["q50"] == pytest.approx((0.5 + (-2.5)) / 2)   # finite only
    led.observe_staleness([0, 0, 3])
    assert led.staleness_hist == {0: 2, 3: 1}
    led.observe_ring(np.array([0, 1, 1, 2]), capacity=5)
    assert led.ring_occupancy == 3 and led.ring_capacity == 5
    led.observe_pending(2)
    led.observe_pending(1)
    assert led.async_pending_max == 2
    with pytest.raises(ValueError):
        CommLedger(wire_format="carrier-pigeon")


# ------------------------------------------------------- sim trace plane

def test_sim_barrier_trace_tracks_and_ledger():
    """A traced WAN barrier sim opens as a valid Chrome trace with one
    track per worker + a server track, and ships a ledger whose totals
    match the SimResult's own counters."""
    m = 3
    params, batches = _problem(m=m, steps=6)
    rule = CommRule(kind="cada2", c=0.6, d_max=4, max_delay=10)
    tr = Tracer()
    res = simulate(logreg_loss, rule, params, batches, n_workers=m,
                   network="wan", mode="barrier", lr=0.01, trace=tr)
    assert set(tr.tracks) >= {f"worker {w}" for w in range(m)} | {"server"}
    agg = tr.aggregate("server")
    assert agg["round"]["count"] == 6
    for w in range(m):
        wa = tr.aggregate(f"worker {w}")
        assert wa["compute"]["count"] == 6
        assert wa["download"]["count"] == 6
    obj = to_chrome_trace(tr)
    validate_chrome_trace(obj)
    # sim clock lands on the µs axis: last event within the sim wall
    max_ts = max(e["ts"] + e.get("dur", 0.0)
                 for e in obj["traceEvents"] if e["ph"] != "M")
    assert max_ts <= res.wall_s * 1e6 * (1 + 1e-9)
    assert res.ledger is not None
    assert res.ledger["uploads"] == res.uploads
    assert res.ledger["bytes_up"] == res.bytes_up


def test_sim_async_trace_and_ledger():
    m = 3
    params, batches = _problem(m=m, steps=12)
    rule = CommRule(kind="cada1", c=0.6, d_max=4, max_delay=8)
    tr = Tracer()
    res = simulate(logreg_loss, rule, params, batches, n_workers=m,
                   network="hetero", mode="async", lr=0.01, trace=tr)
    validate_chrome_trace(to_chrome_trace(tr))
    assert {f"worker {w}" for w in range(m)} <= set(tr.tracks)
    assert tr.aggregate("server").get("apply_update", {}).get("count") \
        == res.steps
    led = res.ledger
    assert led is not None
    assert led["uploads"] == res.uploads
    assert led["rounds"] == res.steps
    assert sum(led["staleness_hist"].values()) > 0


def test_untraced_sim_has_no_tracer_cost_path():
    """trace=None rides the NULL tracer — same results, no events."""
    m = 3
    params, batches = _problem(m=m, steps=6)
    rule = CommRule(kind="cada2", c=0.6, d_max=4, max_delay=10)
    r0 = simulate(logreg_loss, rule, params, batches, n_workers=m,
                  network="wan", mode="barrier", lr=0.01)
    tr = Tracer()
    r1 = simulate(logreg_loss, rule, params, batches, n_workers=m,
                  network="wan", mode="barrier", lr=0.01, trace=tr)
    assert r0.wall_s == r1.wall_s
    np.testing.assert_array_equal(r0.upload_masks, r1.upload_masks)
    assert len(tr) > 0


# ------------------------------------------------- cohort pipeline spans

@pytest.mark.parametrize("pipeline", (False, True))
def test_run_cohort_rounds_pipeline_spans(pipeline):
    """Each cohort round contributes one gather/step/scatter span (plus
    patch spans on the pipelined driver) on the "pipeline" track."""
    cohorts = sample_cohorts(M, C, STEPS, seed=4)
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=6)
    params, batches = _problem(steps=STEPS)
    cohort_batches = [
        jax.tree.map(lambda x, i=i: x[i][cohorts[i]], batches)
        for i in range(STEPS)]
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
    st, pool = eng.init_cohort(params)
    tr = Tracer()
    st, mets = eng.run_cohort(st, pool, cohort_batches, cohorts,
                              pipeline=pipeline, trace=tr)
    agg = tr.aggregate("pipeline")
    for phase in ("gather", "step", "scatter"):
        assert agg[phase]["count"] == STEPS, (phase, agg)
        assert agg[phase]["total_s"] >= 0.0
    if pipeline:
        from repro.core.flat import cohort_overlap_schedule
        n_overlap = int((cohort_overlap_schedule(cohorts) >= 0)
                        .any(axis=1).sum())
        assert agg.get("patch", {}).get("count", 0) == n_overlap
    validate_chrome_trace(to_chrome_trace(tr))


def test_metrics_out_survives_error():
    """Satellite fix: an exception mid-run must not lose the device-side
    metrics window — ``metrics_out`` keeps every completed round (the
    driver drains in a finally), matching the serial oracle's prefix."""
    j = 9
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    params, batches = _problem(steps=STEPS)
    cohort_batches = [
        jax.tree.map(lambda x, i=i: x[i][cohorts[i]], batches)
        for i in range(STEPS)]
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=6)

    class Boom(RuntimeError):
        pass

    # serial oracle over the full schedule
    eng_s = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
    st_s, pool_s = eng_s.init_cohort(params)
    _, mets_s = eng_s.run_cohort(st_s, pool_s, cohort_batches, cohorts,
                                 pipeline=False)

    for pipeline in (False, True):
        def exploding(i, cohort):
            if i == j:
                raise Boom
            return cohort_batches[i]

        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
        st, pool = eng.init_cohort(params)
        out: list = []
        with pytest.raises(Boom):
            eng.run_cohort(st, pool, exploding, cohorts, pipeline=pipeline,
                           metrics_every=4, metrics_out=out)
        # every COMPLETED round made it out of the device-side window
        assert len(out) >= j - 1, (pipeline, len(out))
        for i, met in enumerate(out):
            for key in ("uploads", "bytes_up", "upload_mask"):
                np.testing.assert_array_equal(
                    np.asarray(met[key]), np.asarray(mets_s[i][key]),
                    err_msg=f"pipeline={pipeline}: metrics_out[{i}][{key}]")


# --------------------------------------------- traced M=10⁴ smoke (CI leg)

def test_obs_smoke_traced_m10k_cohort(tmp_path):
    """The CI obs-smoke: a traced M=10⁴ C=64 pipelined cohort run under
    the 6 GiB cap produces a schema-valid Chrome trace with per-round
    pipeline spans, and the ledger agrees with the round metrics."""
    m, c, rounds = 10_000, 64, 4
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100)
    ds = ijcnn1_like(n=20_000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_cohort_sampler(ds.x, ds.y, mtx, 32)
    params = mlp_init(jax.random.PRNGKey(7), 22, 64, 2)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.05), rule, m)
    st, pool = eng.init_cohort(params)
    cohorts = sample_cohorts(m, c, rounds, seed=0)

    def batch_fn(i, cohort):
        return sample(jax.random.PRNGKey(400 + i), jnp.asarray(cohort))

    tr = Tracer()
    mets_out: list = []
    st, mets = eng.run_cohort(st, pool, batch_fn, cohorts, pipeline=True,
                              metrics_every=4, trace=tr,
                              metrics_out=mets_out)
    assert mets is mets_out and len(mets) == rounds
    agg = tr.aggregate("pipeline")
    assert agg["step"]["count"] == rounds
    assert agg["gather"]["count"] == rounds

    led = CommLedger.for_strategy(eng.strategy)
    led.observe_pool(pool)
    for met in mets:
        led.observe_round(jax.device_get(met))
    assert led.uploads == int(sum(int(np.asarray(mm["uploads"]))
                                  for mm in mets))
    assert led.rounds == rounds
    assert led.pool_nbytes == pool.nbytes
    s = led.summary()
    assert s["pool_resident_nbytes"] == pool.resident_nbytes
    assert int(np.asarray(mets[0]["uploads"])) == c   # round 0 force-upload

    path = tmp_path / "cohort_trace.json"
    write_chrome_trace(tr, str(path),
                       meta={"runtime": "cohort", "m": m, "c": c})
    from repro.obs.export import main
    assert main(["--validate", str(path)]) == 0
