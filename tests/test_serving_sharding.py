"""Sharding policy + serving builders on the host mesh, and one real
(subprocess) dry-run combo as an integration test."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.distributed.serving import jit_decode_step, jit_prefill_step
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        param_pspecs, wants_fsdp)
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               set_mesh)
from repro.models.model import abstract_params, init_cache, init_params


def test_param_pspecs_cover_every_leaf():
    """Every arch's parameter tree gets a spec of matching rank."""
    mesh = make_host_mesh()
    for arch in C.list_archs():
        cfg = C.get_config(arch)
        aps = abstract_params(cfg)
        specs = param_pspecs(cfg, mesh)
        for leaf, spec in zip(jax.tree.leaves(aps), jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= leaf.ndim, (arch, leaf.shape, spec)


def test_fsdp_threshold_picks_big_archs():
    mesh = make_host_mesh()
    assert wants_fsdp(C.get_config("llama3-405b"), mesh)
    assert not wants_fsdp(C.get_config("internlm2-1.8b"), mesh)


def test_fsdp_axes_extension():
    """("data","pod") FSDP composes for the 405B multi-pod policy."""
    # host mesh has no pod axis: the pod entry must drop out gracefully
    mesh = make_host_mesh()
    cfg = C.get_config("llama3-405b")
    specs = param_pspecs(cfg, mesh, fsdp=True, fsdp_axes=("data", "pod"))
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)


def test_batch_pspecs_divisibility_guard():
    mesh = make_host_mesh()
    sds = {"tokens": jax.ShapeDtypeStruct((1,), jnp.int32)}  # B=1
    spec = batch_pspecs(sds, mesh)["tokens"]
    # B=1 cannot shard over a >1 data axis
    if mesh.shape["data"] > 1:
        assert spec[0] is None


def test_cache_pspecs_shapes():
    mesh = make_host_mesh()
    cfg = C.get_smoke_config("zamba2-2.7b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 64))
    specs = cache_pspecs(cfg, cache, mesh)
    assert len(specs.k) == 5 and len(specs.ssm) == 5


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b"])
def test_decode_step_builder_runs(arch):
    cfg = C.get_smoke_config(arch)
    mesh = make_host_mesh()
    with set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        step, cache_sds, inputs_sds = jit_decode_step(cfg, mesh, 2, 16)
        cache = init_cache(cfg, 2, 16)
        logits, cache = step(params, cache,
                             {"tokens": jnp.ones((2,), jnp.int32)})
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_prefill_step_builder_runs():
    cfg = C.get_smoke_config("granite-moe-1b-a400m")
    mesh = make_host_mesh()
    with set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        inputs = {"tokens": jnp.ones((2, 16), jnp.int32)}
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), inputs)
        step = jit_prefill_step(cfg, mesh, sds)
        logits, cache = step(params, inputs)
    assert logits.shape == (2, cfg.vocab)
    assert int(cache.index) == 16


def test_production_mesh_requires_512_devices():
    """On the 1-device test process the production mesh must refuse —
    proving tests don't silently fake the fleet (the dry-run does that,
    explicitly, via XLA_FLAGS)."""
    with pytest.raises(Exception):
        make_production_mesh()


@pytest.mark.slow
def test_dryrun_one_combo_subprocess():
    """Integration: a real dry-run combo (lower+compile on 512 fake
    devices) in a fresh interpreter."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo")
    assert "all 1 combos passed" in res.stdout, res.stdout + res.stderr
