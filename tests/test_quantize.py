"""LAQ-style quantized innovations: quantizer properties + engine
integration (beyond-paper feature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import CADAEngine, make_sampler
from repro.core.quantize import (per_worker_quantize_dequantize,
                                 quantize_dequantize)
from repro.core.rules import CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss
from repro.optim.adam import adam


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), bits=st.integers(2, 16))
def test_quantization_error_bound(seed, bits):
    """|x − x̂| <= scale / (2^(b-1) − 1) / 2 per entry."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64), jnp.float32)
    xq = quantize_dequantize({"x": x}, bits)["x"]
    bound = float(jnp.max(jnp.abs(x))) / (2 ** (bits - 1) - 1) / 2 + 1e-7
    assert float(jnp.max(jnp.abs(x - xq))) <= bound


def test_quantize_identity_cases(rng):
    x = {"w": jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)}
    for bits in (0, 32):
        out = quantize_dequantize(x, bits)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(x["w"]))


def test_per_worker_scales_independent(rng):
    """A huge outlier in worker 0 must not destroy worker 1's resolution."""
    x = jnp.stack([jnp.full((16,), 1000.0),
                   jnp.linspace(-1, 1, 16)])
    out = per_worker_quantize_dequantize({"g": x}, 8)["g"]
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray(x[1]), atol=0.01)


def test_engine_with_quantized_innovations_converges():
    m, iters = 8, 300
    ds = ijcnn1_like(n=2000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, 0))
    sample = make_sampler(ds.x, ds.y, mtx, 32)
    params = logreg_init(None, 22, 2)
    out = {}
    for bits in (0, 8, 4):
        eng = CADAEngine(logreg_loss, adam(lr=0.02),
                         CommRule(kind="cada2", c=0.6, d_max=10,
                                  max_delay=100, quantize_bits=bits), m)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        out[bits] = (float(np.asarray(mets["loss"])[-20:].mean()),
                     float(np.asarray(mets["bytes_up"]).sum()))
    loss32, bytes32 = out[0]
    loss8, bytes8 = out[8]
    loss4, bytes4 = out[4]
    assert loss8 < loss32 * 1.3          # 8-bit: near-lossless
    assert loss4 < 0.15                  # 4-bit: converges (degraded)
    assert bytes8 < bytes32 * 0.5        # and 4x fewer bytes at worst
    assert bytes4 < bytes32 * 0.35


def test_quantize_bits_validation():
    with pytest.raises(ValueError):
        CommRule(quantize_bits=1)
    with pytest.raises(ValueError):
        CommRule(quantize_bits=64)
