"""MoE routing + SSM block properties (hypothesis-swept)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro.models.layers import causal_conv1d, conv1d_step
from repro.models.model import init_params
from repro.models.moe import _capacity, moe_ffn
from repro.models.ssm import (mamba1_block, mamba1_decode, mamba2_block,
                              mamba2_decode, selective_scan_jnp)


# -------------------------------------------------------------------- MoE

def _moe_setup(key, e=4, k=2, d=32, ff=64, b=2, s=16):
    cfg = C.get_smoke_config("granite-moe-1b-a400m").with_(
        n_experts=e, top_k=k, d_model=d, d_ff=ff)
    params = init_params(cfg, key)
    lp = jax.tree.map(lambda p: p[0], params["blocks"])["mlp"]
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), cfg.jnp_dtype)
    return cfg, lp, x


def test_moe_output_finite_and_shaped(key):
    cfg, lp, x = _moe_setup(key)
    y, aux = moe_ffn(lp, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    assert float(aux) >= 0.0


def test_moe_aux_loss_penalizes_imbalance(key):
    """Uniform routing probabilities give aux == coeff (Switch lemma);
    a collapsed router gives ~E× more."""
    cfg, lp, x = _moe_setup(key, e=4, k=1)
    x = jnp.abs(x)  # positive activations -> the +100 column always wins
    # uniform probabilities (zero router)
    lp_u = dict(lp)
    lp_u["router"] = jnp.zeros_like(lp["router"])
    _, aux_u = moe_ffn(lp_u, cfg, x)
    # collapsed router: every token to expert 0 with probability ~1
    lp_c = dict(lp)
    lp_c["router"] = jnp.zeros_like(lp["router"]).at[:, 0].add(100.0)
    _, aux_c = moe_ffn(lp_c, cfg, x)
    assert float(aux_c) > float(aux_u) * 2.0, (float(aux_c), float(aux_u))
    np.testing.assert_allclose(float(aux_u), cfg.router_aux_coeff,
                               rtol=0.05)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 4096), e=st.integers(2, 32), k=st.integers(1, 4),
       cf=st.floats(1.0, 2.0))
def test_capacity_bounds(n, e, k, cf):
    k = min(k, e)
    cfg = C.get_smoke_config("granite-moe-1b-a400m").with_(
        n_experts=e, top_k=k, capacity_factor=cf)
    cap = _capacity(n, cfg)
    assert 1 <= cap <= n
    assert cap % 8 == 0 or cap == n


def test_moe_respects_capacity_drop(key):
    """With a collapsed router and capacity < tokens, overflow tokens are
    dropped (at most `capacity` output rows can be non-zero)."""
    cfg, lp, x = _moe_setup(key, e=4, k=1, b=1, s=64)
    x = jnp.abs(x)
    lp = dict(lp)
    lp["router"] = jnp.zeros_like(lp["router"]).at[:, 0].add(100.0)
    y, _ = moe_ffn(lp, cfg, x)
    flat = np.asarray(y.reshape(-1, y.shape[-1]).astype(jnp.float32))
    nonzero_rows = int((np.abs(flat).sum(axis=1) > 1e-6).sum())
    cap = _capacity(64, cfg)
    assert cap < 64, "test needs capacity pressure"
    assert nonzero_rows <= cap, (nonzero_rows, cap)


# -------------------------------------------------------------------- SSM

def test_selective_scan_linearity(rng):
    """The scan is linear in the drive input x (fixed dt)."""
    g, s, d, n = 1, 32, 8, 4
    dt = jnp.abs(jnp.asarray(rng.normal(size=(g, s, d)), jnp.float32)) * .1
    a = -jnp.abs(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    b = jnp.asarray(rng.normal(size=(g, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(g, s, n)), jnp.float32)
    x1 = jnp.asarray(rng.normal(size=(g, s, d)), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=(g, s, d)), jnp.float32)
    y1, _ = selective_scan_jnp(dt, x1, a, b, c, chunk=8)
    y2, _ = selective_scan_jnp(dt, x2, a, b, c, chunk=8)
    y12, _ = selective_scan_jnp(dt, x1 + 2.0 * x2, a, b, c, chunk=8)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(y1 + 2 * y2),
                               rtol=1e-3, atol=1e-3)


def test_selective_scan_chunk_invariance(rng):
    g, s, d, n = 2, 64, 8, 4
    dt = jnp.abs(jnp.asarray(rng.normal(size=(g, s, d)), jnp.float32)) * .1
    x = jnp.asarray(rng.normal(size=(g, s, d)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.normal(size=(d, n)), jnp.float32))
    b = jnp.asarray(rng.normal(size=(g, s, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(g, s, n)), jnp.float32)
    outs = [selective_scan_jnp(dt, x, a, b, c, chunk=ch)[0]
            for ch in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch,block,decode", [
    ("falcon-mamba-7b", mamba1_block, mamba1_decode),
    ("zamba2-2.7b", mamba2_block, mamba2_decode),
])
def test_ssm_block_decode_equals_parallel(arch, block, decode, key):
    """Recurrent decode over the sequence == parallel block (causality +
    state-carry correctness for both Mamba generations)."""
    cfg = C.get_smoke_config(arch)
    params = init_params(cfg, key)
    lp = jax.tree.map(lambda p: p[0], params["blocks"])
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, cfg.d_model),
                          cfg.jnp_dtype)
    y_par = block(lp, cfg, x, chunk=8)

    conv = jnp.zeros((b, cfg.ssm_conv - 1, cfg.d_inner), cfg.jnp_dtype)
    if cfg.block == "mamba1":
        state = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32)
    else:
        state = jnp.zeros((b, cfg.ssm_heads, cfg.mamba_headdim,
                           cfg.ssm_state), jnp.float32)
    ys = []
    for t in range(s):
        y_t, conv, state = decode(lp, cfg, x[:, t], conv, state)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_par, np.float32),
                               rtol=0.05, atol=0.05)


def test_causal_conv_decode_step_matches(rng):
    b, s, c, k = 2, 10, 6, 4
    x = jnp.asarray(rng.normal(size=(b, s, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, c)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(c,)), jnp.float32)
    full = causal_conv1d(x, w, bias)
    state = jnp.zeros((b, k - 1, c))
    outs = []
    for t in range(s):
        state, y = conv1d_step(state, x[:, t], w, bias)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-5)


def test_moe_local_dispatch_matches_global(key):
    """Per-row (sharded) dispatch == global dispatch when capacity is not
    binding (§Perf optimization must preserve semantics)."""
    cfg, lp, x = _moe_setup(key, e=4, k=2)
    cfg = cfg.with_(capacity_factor=8.0)
    yg, _ = moe_ffn(lp, cfg, x)
    yl, _ = moe_ffn(lp, cfg.with_(moe_local_dispatch=True), x)
    np.testing.assert_allclose(np.asarray(yg, np.float32),
                               np.asarray(yl, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_model_forward_with_pallas_scan_matches_jnp(key):
    """Whole-model equivalence: falcon-mamba forward through the Pallas
    selective-scan kernel (interpret) == the jnp chunked path."""
    from repro.models import ssm as ssm_mod
    from repro.models.model import forward
    cfg = C.get_smoke_config("falcon-mamba-7b").with_(remat=False)
    params = init_params(cfg, key)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 32), 0, cfg.vocab)
    y_jnp, _ = forward(cfg, params, tokens=toks)
    try:
        ssm_mod.set_scan_impl("pallas")
        y_pl, _ = forward(cfg, params, tokens=toks)
    finally:
        ssm_mod.set_scan_impl("jnp")
    np.testing.assert_allclose(np.asarray(y_jnp, np.float32),
                               np.asarray(y_pl, np.float32),
                               rtol=0.02, atol=0.02)
