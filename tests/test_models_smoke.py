"""Per-architecture smoke tests (task deliverable f): every assigned arch
instantiates a REDUCED same-family variant (≤2 layers, d_model ≤ 512, ≤4
experts) and runs one forward/train step on CPU, asserting shapes + no NaNs.
Also checks decode-vs-forward consistency and the analytic param count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.config import param_count
from repro.models.model import (
    decode_step, forward, init_cache, init_params, lm_loss, prefill,
)

ARCHS = C.list_archs()


def _batch(cfg, key, b=2, s=32):
    if cfg.embed_input:
        toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
        batch = {"tokens": toks}
    else:
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                             cfg.jnp_dtype),
                 "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    return batch


def test_all_archs_assigned():
    assert set(ARCHS) == {
        "falcon-mamba-7b", "grok-1-314b", "internlm2-1.8b",
        "granite-moe-1b-a400m", "yi-34b", "qwen2-vl-2b", "zamba2-2.7b",
        "musicgen-medium", "stablelm-1.6b", "llama3-405b"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = C.get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: lm_loss(cfg, p, b)[0]))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = C.get_smoke_config(arch)
    params = init_params(cfg, key)
    b = 2
    cache = init_cache(cfg, b, 16)
    if cfg.embed_input:
        logits, cache2 = decode_step(cfg, params, cache,
                                     tokens=jnp.ones((b,), jnp.int32))
    else:
        logits, cache2 = decode_step(
            cfg, params, cache,
            embeds=jnp.ones((b, 1, cfg.d_model), cfg.jnp_dtype))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache2.index) == 1


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "falcon-mamba-7b",
                                  "zamba2-2.7b", "granite-moe-1b-a400m"])
def test_prefill_decode_matches_forward(arch, key):
    """Last-token logits from (prefill S-1 → decode 1) == full forward."""
    cfg = C.get_smoke_config(arch)
    params = init_params(cfg, key)
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab)
    full, _ = forward(cfg, params, tokens=toks)
    _, cache = prefill(cfg, params, tokens=toks[:, :-1], max_seq=s)
    last, _ = decode_step(cfg, params, cache, tokens=toks[:, -1])
    np.testing.assert_allclose(
        np.asarray(full[:, -1], np.float32), np.asarray(last, np.float32),
        rtol=0.05, atol=0.05)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_init(arch, key):
    """Analytic param count (roofline napkin math) == real init."""
    cfg = C.get_smoke_config(arch)
    params = init_params(cfg, key)
    real = sum(p.size for p in jax.tree.leaves(params))
    assert real == param_count(cfg), (arch, real, param_count(cfg))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_numbers(arch):
    """The FULL configs carry the published numbers (no allocation)."""
    cfg = C.get_config(arch)
    cfg.validate()
    expected = {
        "falcon-mamba-7b": dict(n_layers=64, d_model=4096, vocab=65024,
                                ssm_state=16),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab=131072,
                            n_experts=8, top_k=2),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                               n_kv_heads=8, d_ff=8192, vocab=92544),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=32, top_k=8),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab=64000),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12,
                            n_kv_heads=2, d_ff=8960, vocab=151936),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, vocab=32000,
                            ssm_state=64),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab=2048),
        "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32,
                              n_kv_heads=32, d_ff=5632, vocab=100352),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab=128256),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source, f"{arch} missing citation"


def test_vlm_audio_are_embedding_stubs():
    """The modality-frontend carve-out: qwen2-vl / musicgen consume
    precomputed embeddings."""
    assert not C.get_config("qwen2-vl-2b").embed_input
    assert not C.get_config("musicgen-medium").embed_input
    assert C.get_config("qwen2-vl-2b").mrope
