"""Attention: flash == naive (property-swept), GQA grouping, RoPE/M-RoPE,
sliding windows, decode ring-buffer equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as A


def _qkv(rng, b, s, hq, hkv, hd):
    q = jnp.asarray(rng.normal(size=(b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 256])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_flash_equals_naive(rng, window, hq, hkv):
    q, k, v = _qkv(rng, 2, 1024, hq, hkv, 32)
    ref = A.naive_attention(q, k, v, window=window, dtype=jnp.float32)
    out = A.flash_attention(q, k, v, window=window, dtype=jnp.float32,
                            q_chunk=256, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       qc=st.sampled_from([64, 128, 256]),
       kc=st.sampled_from([64, 128, 256]),
       window=st.sampled_from([0, 100, 512]))
def test_flash_chunking_invariance(seed, qc, kc, window):
    """Property: the output is independent of the chunking."""
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 1, 512, 4, 2, 16)
    base = A.flash_attention(q, k, v, window=window, dtype=jnp.float32,
                             q_chunk=512, kv_chunk=512)
    out = A.flash_attention(q, k, v, window=window, dtype=jnp.float32,
                            q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-3, atol=2e-3)


def test_causality(rng):
    """Future keys cannot influence earlier queries."""
    q, k, v = _qkv(rng, 1, 64, 2, 2, 16)
    out1 = A.naive_attention(q, k, v, dtype=jnp.float32)
    k2 = k.at[:, -1].set(99.0)
    v2 = v.at[:, -1].set(99.0)
    out2 = A.naive_attention(q, k2, v2, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), rtol=1e-5)


def test_sliding_window_masks_old_keys(rng):
    q, k, v = _qkv(rng, 1, 64, 2, 2, 16)
    out_w = A.naive_attention(q, k, v, window=8, dtype=jnp.float32)
    # poisoning keys older than the window must not change the last query
    k2 = k.at[:, :32].set(99.0)
    v2 = v.at[:, :32].set(99.0)
    out_p = A.naive_attention(q, k2, v2, window=8, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_w[:, -1]),
                               np.asarray(out_p[:, -1]), rtol=1e-5)


def test_decode_attention_equals_prefix(rng):
    """Single-token decode over a cache == last row of full attention."""
    b, s, hq, hkv, hd = 2, 33, 4, 2, 16
    q, k, v = _qkv(rng, b, s, hq, hkv, hd)
    full = A.naive_attention(q, k, v, dtype=jnp.float32)
    valid = jnp.ones((b, s), bool)
    dec = A.decode_attention(q[:, -1:], k, v, valid, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-5, atol=1e-5)


def test_rope_preserves_norm_and_relativity(rng):
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 32)), jnp.float32)
    cos, sin = A.rope_angles(jnp.arange(8)[None], 32, 1e4)
    out = A.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # relativity: q·k after roping depends only on position difference
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

    def dot_at(pq, pk):
        cq, sq = A.rope_angles(jnp.asarray([[pq]]), 32, 1e4)
        ck, sk = A.rope_angles(jnp.asarray([[pk]]), 32, 1e4)
        qr = A.apply_rope(q, cq, sq)
        kr = A.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3


def test_mrope_sections(rng):
    pos = jnp.broadcast_to(jnp.arange(8)[None, None], (3, 1, 8)).astype(
        jnp.int32)
    cos, sin = A.mrope_angles(pos, 32, 1e4, (4, 6, 6))
    assert cos.shape == (1, 8, 16)
    # equal t/h/w positions == plain RoPE
    cos2, sin2 = A.rope_angles(jnp.arange(8)[None], 32, 1e4)
    np.testing.assert_allclose(np.asarray(cos), np.asarray(cos2), rtol=1e-6)


def test_partial_rotary(rng):
    """rotary_pct < 1 leaves the tail dims untouched (stablelm-style)."""
    x = jnp.asarray(rng.normal(size=(1, 4, 1, 32)), jnp.float32)
    cos, sin = A.rope_angles(jnp.arange(4)[None], 8, 1e4)
    out = A.apply_rope(x, cos, sin, rotary_pct=0.25)
    np.testing.assert_allclose(np.asarray(out[..., 8:]),
                               np.asarray(x[..., 8:]))
