"""Data pipeline (partitioners, samplers, synthetic sets) + checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import io as ckpt
from repro.core.engine import make_sampler
from repro.data.partition import (
    dirichlet_partition, pad_to_matrix, random_sizes_partition,
    uniform_partition,
)
from repro.data.synthetic import covtype_like, ijcnn1_like, lm_tokens


# ------------------------------------------------------------------ data

@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 500), m=st.integers(1, 10),
       seed=st.integers(0, 1000))
def test_uniform_partition_is_a_partition(n, m, seed):
    shards = uniform_partition(n, m, seed)
    allidx = np.concatenate(shards)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n  # disjoint and complete


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 8), seed=st.integers(0, 100))
def test_random_sizes_partition_covers(m, seed):
    shards = random_sizes_partition(500, m, seed)
    allidx = np.concatenate(shards)
    assert sorted(allidx.tolist()) == list(range(500))
    sizes = [len(s) for s in shards]
    assert max(sizes) > min(sizes)  # heterogeneous sizes (covtype setup)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 24), m=st.integers(2, 24), seed=st.integers(0, 200))
def test_random_sizes_partition_small_n_large_m(n, m, seed):
    """Satellite regression: with m close to n, the old
    ``sizes[-1] = n - sizes[:-1].sum()`` underflowed to <= 0 (every
    earlier shard is clamped to >= 1), handing the last worker an empty
    or negative shard. Every shard must stay non-empty and the shards
    must partition range(n); m > n must raise instead of degenerating."""
    if m > n:
        with pytest.raises(ValueError):
            random_sizes_partition(n, m, seed)
        return
    shards = random_sizes_partition(n, m, seed)
    assert len(shards) == m
    assert all(len(s) >= 1 for s in shards)
    assert sorted(np.concatenate(shards).tolist()) == list(range(n))


def test_dirichlet_partition_skews_labels():
    labels = np.repeat(np.arange(4), 250)
    shards = dirichlet_partition(labels, m=4, alpha=0.1, seed=0)
    allidx = np.concatenate(shards)
    assert len(np.unique(allidx)) == 1000
    # low alpha => at least one worker is label-skewed vs global (25%)
    fracs = []
    for s in shards:
        if len(s) == 0:
            continue
        _, counts = np.unique(labels[s], return_counts=True)
        fracs.append(counts.max() / len(s))
    assert max(fracs) > 0.5


def test_pad_to_matrix_wraps():
    m = pad_to_matrix([np.array([1, 2, 3]), np.array([7])])
    assert m.shape == (2, 3)
    assert set(m[1]) == {7}


@settings(max_examples=20, deadline=None)
@given(n_short=st.integers(2, 40), n_max=st.integers(41, 200),
       seed=st.integers(0, 100))
def test_pad_to_matrix_wrap_fill_is_uniform(n_short, n_max, seed):
    """Satellite regression: the wrap fill must not favour the shard head —
    every example appears ⌊n_max/len(s)⌋ or that+1 times, so per-example
    sampling probability is uniform to within one part in len(s)."""
    short = np.arange(1000, 1000 + n_short)
    mtx = pad_to_matrix([np.arange(n_max), short], seed=seed)
    _, counts = np.unique(mtx[1], return_counts=True)
    assert counts.max() - counts.min() <= 1, counts
    assert set(mtx[1]) == set(short)  # still only shard-own examples
    # deterministic for a fixed seed
    np.testing.assert_array_equal(
        mtx, pad_to_matrix([np.arange(n_max), short], seed=seed))


def test_sampler_shapes_and_determinism():
    ds = ijcnn1_like(n=300)
    mtx = pad_to_matrix(uniform_partition(ds.n, 5, 0))
    sample = make_sampler(ds.x, ds.y, mtx, 8)
    xb, yb = sample(jax.random.PRNGKey(0))
    assert xb.shape == (5, 8, 22) and yb.shape == (5, 8)
    xb2, _ = sample(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(xb), np.asarray(xb2))


def test_sampler_respects_shards():
    """Worker w only ever samples rows from its own shard."""
    ds = covtype_like(n=200)
    shards = uniform_partition(ds.n, 4, 1)
    mtx = pad_to_matrix(shards)
    sample = make_sampler(ds.x, ds.y, mtx, 16)
    xb, _ = sample(jax.random.PRNGKey(3))
    for w in range(4):
        shard_rows = np.asarray(ds.x)[shards[w]]
        for row in np.asarray(xb[w]):
            assert (np.abs(shard_rows - row).sum(axis=1) < 1e-6).any()


def test_lm_tokens_zipf():
    toks = lm_tokens(10000, vocab=1000)
    assert toks.min() >= 0 and toks.max() < 1000
    # Zipf: the most common token dominates
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() > 0.2 * len(toks)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(4, 5)), jnp.bfloat16),
            "b": {"c": jnp.arange(7)}}
    ckpt.save(str(tmp_path / "step_3"), tree, step=3)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(str(tmp_path / "step_3"), like)
    assert step == 3
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert l1.dtype == l2.dtype
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path / "s"), {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path / "s"), {"zz": jnp.zeros(3)})


def test_checkpoint_dtype_policy_mismatch_raises(tmp_path):
    """Satellite regression: a checkpoint saved under one dtype policy must
    not silently cast into a ``like`` with another — the error names the
    offending leaf."""
    ckpt.save(str(tmp_path / "s"),
              {"ok": jnp.zeros(2, jnp.float32),
               "m": jnp.zeros(4, jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch") as ei:
        ckpt.restore(str(tmp_path / "s"),
                     {"ok": jnp.zeros(2, jnp.float32),
                      "m": jnp.zeros(4, jnp.bfloat16)})
    assert "'m'" in str(ei.value)
    assert "float32" in str(ei.value) and "bfloat16" in str(ei.value)
    # the INTENTIONAL widened round-trip keeps working: bf16 leaves are
    # stored as fp32 bits but their logical dtype matches the target
    ckpt.save(str(tmp_path / "w"), {"e": jnp.ones(3, jnp.bfloat16)})
    back, _ = ckpt.restore(str(tmp_path / "w"),
                           {"e": jnp.zeros(3, jnp.bfloat16)})
    assert back["e"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["e"], np.float32), 1.0)


def test_latest_step_dir(tmp_path):
    assert ckpt.latest_step_dir(str(tmp_path)) is None
    for s in (1, 10, 2):
        os.makedirs(tmp_path / f"step_{s}")
    assert ckpt.latest_step_dir(str(tmp_path)).endswith("step_10")


def test_trainer_state_checkpoint_roundtrip(tmp_path):
    """Full DistTrainState (params + moments + CADA trees) survives a
    save/restore cycle — the production resume path."""
    import repro.configs as C
    from repro.core.rules import CommRule
    from repro.distributed.trainer import (TrainHParams, init_train_state,
                                           make_train_step, worker_split)

    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="cada2", c=0.5, d_max=4,
                                    max_delay=10), lr=1e-3)
    m = 2
    step = jax.jit(make_train_step(cfg, hp, m))
    st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab)}, m)
    st, _ = step(st, batch)

    ckpt.save(str(tmp_path / "step_1"), st._asdict(), step=1)
    like = jax.tree.map(jnp.zeros_like, st._asdict())
    restored, step_no = ckpt.restore(str(tmp_path / "step_1"), like)
    assert step_no == 1
    for a, b in zip(jax.tree.leaves(st._asdict()),
                    jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)
    # resuming continues bit-compatibly
    st2, m1 = step(type(st)(**restored), batch)
    st3, m2 = step(st, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def _fused_engine(kind, m=3):
    from repro.core.engine import CADAEngine, make_sampler
    from repro.core.rules import CommRule
    from repro.models.small import logreg_init, logreg_loss
    ds = ijcnn1_like(n=200)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 16)
    eng = CADAEngine(logreg_loss,
                     rule=CommRule(kind=kind, c=1.0, d_max=4, max_delay=6),
                     n_workers=m)
    return eng, logreg_init(None, 22, 2), sample


@pytest.mark.parametrize("kind", ["laq", "topk", "cada1"])
def test_fused_engine_state_checkpoint_roundtrip(tmp_path, kind):
    """EngineState on the FUSED plane — FlatCommState with dict extras
    (incl. the error-feedback residual planes) plus params_flat — survives
    save/restore and resumes bit-compatibly."""
    eng, params, sample = _fused_engine(kind)
    step = jax.jit(eng.step)
    st = eng.init(params)
    for i in range(2):
        st, _ = step(st, sample(jax.random.PRNGKey(i)))

    ckpt.save(str(tmp_path / f"step_2_{kind}"), st._asdict(), step=2)
    like = jax.tree.map(jnp.zeros_like, st._asdict())
    restored, step_no = ckpt.restore(str(tmp_path / f"step_2_{kind}"), like)
    assert step_no == 2
    for a, b in zip(jax.tree.leaves(st._asdict()),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st_r, mr = step(type(st)(**restored), sample(jax.random.PRNGKey(9)))
    st_c, mc = step(st, sample(jax.random.PRNGKey(9)))
    np.testing.assert_array_equal(np.asarray(mr["upload_mask"]),
                                  np.asarray(mc["upload_mask"]))
    for a, b in zip(jax.tree.leaves(st_r.params),
                    jax.tree.leaves(st_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_trainer_state_checkpoint_roundtrip(tmp_path):
    """DistTrainState on the fused plane (flat h/v̂ + FlatCommState with
    the laq residual plane) round-trips through checkpoint.io."""
    import repro.configs as C
    from repro.core.rules import CommRule
    from repro.distributed.trainer import (TrainHParams, init_train_state,
                                           make_train_step, worker_split)
    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="laq", c=0.5, d_max=4,
                                    max_delay=10), lr=1e-3)
    m = 2
    assert hp.fused  # the default plane — this test pins the fused layout
    step = jax.jit(make_train_step(cfg, hp, m))
    st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab)}, m)
    st, _ = step(st, batch)
    assert isinstance(st.comm.extras, dict) and "residual" in st.comm.extras

    ckpt.save(str(tmp_path / "step_1"), st._asdict(), step=1)
    restored, _ = ckpt.restore(str(tmp_path / "step_1"),
                               jax.tree.map(jnp.zeros_like, st._asdict()))
    for a, b in zip(jax.tree.leaves(st._asdict()),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st2, m1 = step(type(st)(**restored), batch)
    _, m2 = step(st, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_sharded_flat_state_reshard_roundtrip(tmp_path):
    """Satellite gate: a DistTrainState on the SHARDED flat plane (4-way
    layout — padded n_flat differs from the 2-way one) checkpoints with
    ``flat_meta`` and restores into a 2-way layout: every true entry of
    the moments and the FlatCommState planes (incl. the laq residual)
    survives; only the zero padding is re-cut."""
    import repro.configs as C
    from repro.core.rules import CommRule
    from repro.distributed.trainer import (TrainHParams, flat_layout,
                                           init_train_state,
                                           make_train_step, worker_split)

    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="laq", c=0.5, d_max=4,
                                    max_delay=10), lr=1e-3)
    m = 2
    lay2 = flat_layout(cfg, shards=2)
    # pick a source shard count whose padded n_flat actually differs from
    # the 2-way target (4→2 is a no-op when n already divides 8·4 — the
    # reshard must be real, not a plain restore)
    shards_src = next(s for s in (4, 8, 16, 32, 64, 128)
                      if flat_layout(cfg, shards=s).n_flat != lay2.n_flat)
    lay4 = flat_layout(cfg, shards=shards_src)
    assert lay4.n_flat != lay2.n_flat
    step4 = jax.jit(make_train_step(cfg, hp, m, shards=shards_src))
    st4 = init_train_state(cfg, hp, m, jax.random.PRNGKey(0),
                           shards=shards_src)
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab)}, m)
    st4, _ = step4(st4, batch)

    ckpt.save(str(tmp_path / "s4"), st4._asdict(), step=1, flat_meta=lay4)
    st2_like = jax.tree.map(
        jnp.zeros_like,
        init_train_state(cfg, hp, m, jax.random.PRNGKey(7),
                         shards=2)._asdict())
    restored, step_no = ckpt.restore(str(tmp_path / "s4"), st2_like)
    assert step_no == 1
    n = lay4.n
    for name in ("h", "vhat"):
        np.testing.assert_array_equal(
            np.asarray(restored[name][:n]),
            np.asarray(st4._asdict()[name][:n]))
        assert restored[name].shape == (lay2.n_flat,)
        np.testing.assert_array_equal(np.asarray(restored[name][n:]), 0.0)
    np.testing.assert_array_equal(
        np.asarray(restored["comm"].nabla[:n]),
        np.asarray(st4.comm.nabla[:n]))
    np.testing.assert_array_equal(
        np.asarray(restored["comm"].worker_grads[:, :n]),
        np.asarray(st4.comm.worker_grads[:, :n]))
    np.testing.assert_array_equal(
        np.asarray(restored["comm"].extras["residual"][:, :n]),
        np.asarray(st4.comm.extras["residual"][:, :n]))
    # the restored 2-shard state resumes: same masks as the 4-shard run
    step2 = jax.jit(make_train_step(cfg, hp, m, shards=2))
    _, m2 = step2(type(st4)(**restored), batch)
    _, m4 = step4(st4, batch)
    np.testing.assert_array_equal(np.asarray(m2["upload_mask"]),
                                  np.asarray(m4["upload_mask"]))


def test_flat_reshard_layout_mismatch_names_plane(tmp_path):
    """A flat checkpoint whose true entry count does not fit the restore
    target raises a clean error NAMING the offending plane; a non-flat
    shape mismatch still raises the plain shape error."""
    from repro.core import flat as F
    tree = {"x": jnp.ones((8,), jnp.float32)}
    lay = F.layout_of(tree, shards=4)  # n=8, n_flat=32
    ckpt.save(str(tmp_path / "f"),
              {"plane": lay.pack(tree), "other": jnp.zeros(3)},
              flat_meta=lay)
    # target plane too small for the 8 true entries
    with pytest.raises(ValueError, match="layout mismatch at .*plane"):
        ckpt.restore(str(tmp_path / "f"),
                     {"plane": jnp.zeros(4), "other": jnp.zeros(3)})
    # non-flat mismatch (leaf whose last dim is not the recorded n_flat)
    with pytest.raises(ValueError, match="shape mismatch at .*other"):
        ckpt.restore(str(tmp_path / "f"),
                     {"plane": jnp.zeros(32), "other": jnp.zeros(5)})
    # a "plane" whose tail is NOT zero padding is rejected, not truncated
    ckpt.save(str(tmp_path / "g"),
              {"plane": jnp.arange(32, dtype=jnp.float32),
               "other": jnp.zeros(3)}, flat_meta=lay)
    with pytest.raises(ValueError, match="padding tail .* not zero"):
        ckpt.restore(str(tmp_path / "g"),
                     {"plane": jnp.zeros(16), "other": jnp.zeros(3)})


def test_fused_state_layout_mismatch_raises(tmp_path):
    """Restoring a fused checkpoint into a DIFFERENT layout fails loudly:
    another rule's extras (tree mismatch) and another model's flat width
    (shape mismatch, named leaf)."""
    eng_a, params_a, sample = _fused_engine("laq")
    st_a = eng_a.init(params_a)
    ckpt.save(str(tmp_path / "a"), st_a._asdict())
    # different rule family ⇒ different extras keys
    eng_b, params_b, _ = _fused_engine("cada1")
    with pytest.raises(ValueError, match="tree mismatch"):
        ckpt.restore(str(tmp_path / "a"),
                     jax.tree.map(jnp.zeros_like,
                                  eng_b.init(params_b)._asdict()))
    # same rule, different model size ⇒ different n_flat
    from repro.core.engine import CADAEngine
    from repro.core.rules import CommRule
    from repro.models.small import logreg_init, logreg_loss
    eng_c = CADAEngine(logreg_loss,
                       rule=CommRule(kind="laq", c=1.0, d_max=4,
                                     max_delay=6), n_workers=3)
    st_c = eng_c.init(logreg_init(None, 10, 2))
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path / "a"),
                     jax.tree.map(jnp.zeros_like, st_c._asdict()))
