"""The payload/cadence axis: delta-payload rules, per-worker local steps,
and the H = 1 degenerate gate.

Three contracts pinned here:

  * **Seed-engine parity** — the strategy-layer ``local_momentum`` /
    ``fedadam`` rules (core/local_update.py) reproduce the seed
    :class:`LocalUpdateEngine` trajectories at the same H and seeds, on
    both the per-leaf pytree plane and the fused flat plane. The seed
    engine survives ONLY as this oracle; everything else routes through
    the rule layer.
  * **H = 1 degeneracy** — for the 8 gradient-payload rules the
    refactored round is BIT-exact to the pre-axis form (the delta branch
    is a static Python ``if``, so their graph is untouched): an inline
    oracle of the pre-refactor ``comm_round`` body must match exactly.
    For the delta rules, a plain (M, b, ·) batch and the explicit
    (1, M, b, ·) local-axis form are bit-identical.
  * **Adaptation** — ``adapt_period`` (the helper shared by avp's upload
    period and per-worker H), the sim's comm-vs-compute H schedule (grows
    on the WAN, collapses to 1 on free links), and the pricing identity
    ``round_time(·, h=1) == iter_time``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import (CommContext, adapt_period, comm_round,
                             init_comm_state, select_rows, strategy_for)
from repro.core.engine import CADAEngine
from repro.core.local_update import LocalUpdateEngine
from repro.core.rules import LOCAL_RULES, RULES, CommRule
from repro.sim.clock import network_profile
from repro.sim.runtime import SimConfig, SimRuntime

M = 3
H = 4
ROUNDS = 5


def _loss_fn(p, b):
    x, y = b
    return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)


def _params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"w": jax.random.normal(k1, (6, 2)) * 0.3,
            "b": jax.random.normal(k2, (2,)) * 0.1}


def _batches(rounds=ROUNDS, h=H, m=M, seed=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (rounds, h, m, 8, 6)),
            jax.random.normal(ky, (rounds, h, m, 8, 2)))


def _local_rule(kind, h=H, **kw):
    return CommRule(kind=kind, c=0.6, d_max=4, max_delay=10,
                    local_steps=h, local_lr=0.05, local_beta=0.9,
                    server_lr=0.01, **kw)


# ------------------------------------------------- seed-engine parity

@pytest.mark.parametrize("kind", LOCAL_RULES)
@pytest.mark.parametrize("h", [1, H])
@pytest.mark.parametrize("fused", [False, True])
def test_strategy_rules_match_seed_engine(kind, h, fused):
    """Same H, same seeds: the registered delta-payload rule's trajectory
    equals the seed LocalUpdateEngine's (params allclose — the float
    association differs; uploads / grad-eval accounting exactly)."""
    params = _params()
    batches = _batches(h=h)

    seed_eng = LocalUpdateEngine(_loss_fn, n_workers=M, h_period=h,
                                 algo=kind, lr=0.05, beta=0.9,
                                 server_lr=0.01)
    sst, smets = jax.jit(seed_eng.run)(seed_eng.init(params), batches)

    rule = _local_rule(kind, h=h)
    eng = CADAEngine(_loss_fn, None, rule, M, fused=fused)
    ebatches = (batches if h > 1
                else jax.tree.map(lambda x: x[:, 0], batches))
    est, emets = jax.jit(eng.run)(eng.init(params), ebatches)

    for a, b in zip(jax.tree.leaves(sst.params),
                    jax.tree.leaves(est.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    # per-round accounting: M uploads, M·H gradient evaluations
    np.testing.assert_array_equal(
        np.asarray(smets["uploads"]),
        np.asarray(emets["uploads"]).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(smets["grad_evals"]),
        np.asarray(emets["grad_evals"]).astype(np.int32))
    # per-round mean loss (grand mean over the H × M evaluations)
    np.testing.assert_allclose(
        np.asarray(smets["loss"]).mean(axis=1),
        np.asarray(emets["loss"]), rtol=1e-5, atol=1e-7)


# --------------------------------------------------- H = 1 degeneracy

def _oracle_round(strategy, comm, params, batch, k, *, vgrad, vgrad_per):
    """The PRE-refactor ``comm_round`` body, inlined verbatim (gradient
    payload, no participation): the bit-exactness oracle for the 8
    gradient-payload rules."""
    r = strategy.rule
    m = comm.staleness.shape[0]
    extras = strategy.pre_step(comm.extras, params, k)
    losses, fresh = vgrad(params, batch)
    ctx = CommContext(params=params, batch=batch, fresh=fresh,
                      comm=comm._replace(extras=extras), step=k, m=m,
                      vgrad=vgrad, vgrad_per=vgrad_per,
                      participation=None)
    lhs, cache = strategy.lhs(ctx, extras)
    rhs = r.rhs(comm.diff_hist)
    upload = (lhs > rhs) | (comm.staleness >= r.max_delay)
    delta = jax.tree.map(
        lambda f, s: f.astype(jnp.float32) - s.astype(jnp.float32),
        fresh, comm.worker_grads)
    delta = strategy.wire_delta(ctx, extras, cache, delta)
    zeros = jax.tree.map(jnp.zeros_like, delta)
    wire = jax.tree.map(
        lambda d, s: d.astype(s.dtype),
        select_rows(upload, delta, zeros), comm.worker_grads)
    nabla = jax.tree.map(
        lambda n, d: (n.astype(jnp.float32)
                      + jnp.mean(d.astype(jnp.float32), axis=0)
                      ).astype(n.dtype),
        comm.nabla, wire)
    worker_grads = jax.tree.map(
        lambda s, d: (s.astype(jnp.float32) + d.astype(jnp.float32)
                      ).astype(s.dtype),
        comm.worker_grads, wire)
    staleness = jnp.where(upload, 1, comm.staleness + 1)
    extras = strategy.post_upload(extras, cache, upload, ctx)
    return (losses, upload, staleness, nabla, worker_grads, extras)


@pytest.mark.parametrize("kind", RULES)
def test_grad_rules_bit_exact_vs_pre_refactor_round(kind):
    """The refactored round leaves every gradient-payload rule's graph
    untouched: outputs are BITWISE equal to the inline pre-refactor
    oracle, iteration by iteration."""
    rule = CommRule(kind=kind, c=0.6, d_max=4, max_delay=10)
    strategy = strategy_for(rule)
    params = _params()
    vgrad = jax.vmap(jax.value_and_grad(_loss_fn), in_axes=(None, 0))
    vgrad_per = jax.vmap(jax.value_and_grad(_loss_fn), in_axes=(0, 0))
    comm = init_comm_state(strategy, params, M)
    batches = jax.tree.map(lambda x: x[:, 0], _batches(h=1))

    for k in range(ROUNDS):
        b = jax.tree.map(lambda x: x[k], batches)
        out = comm_round(strategy, comm, params, b, k,
                         vgrad=vgrad, vgrad_per=vgrad_per)
        ol, ou, os_, on, ow, oe = _oracle_round(
            strategy, comm, params, b, k,
            vgrad=vgrad, vgrad_per=vgrad_per)
        np.testing.assert_array_equal(np.asarray(out.upload),
                                      np.asarray(ou))
        np.testing.assert_array_equal(np.asarray(out.comm.staleness),
                                      np.asarray(os_))
        for a, e in zip(jax.tree.leaves((out.losses, out.comm.nabla,
                                         out.comm.worker_grads,
                                         out.comm.extras)),
                        jax.tree.leaves((ol, on, ow, oe))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(e))
        comm = out.comm
        # drift the params so later iterations exercise fresh state
        params = jax.tree.map(lambda p: p - 0.01 * p, params)


@pytest.mark.parametrize("kind", LOCAL_RULES)
@pytest.mark.parametrize("fused", [False, True])
def test_delta_rules_plain_batch_equals_h1_axis(kind, fused):
    """At H = 1 a delta rule accepts the plain (M, b, ·) batch form; the
    explicit (1, M, b, ·) local-axis form (driven by an all-ones
    per-worker schedule, the sim's adaptive plumbing) is bit-identical."""
    params = _params()
    batches = _batches(h=1)
    rule = _local_rule(kind, h=1)
    eng = CADAEngine(_loss_fn, None, rule, M, fused=fused)
    st0 = eng.init(params)
    st_a, mets_a = jax.jit(eng.run)(
        st0, batches, None, jnp.ones((ROUNDS, M), jnp.int32))
    st_b, mets_b = jax.jit(eng.run)(
        st0, jax.tree.map(lambda x: x[:, 0], batches))
    for a, b in zip(jax.tree.leaves(st_a.params),
                    jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(mets_a["loss"]),
                                  np.asarray(mets_b["loss"]))


def test_cohort_matches_dense_participation_local_momentum():
    """Fixed-H local momentum on the cohort plane is bit-exact to the
    dense flat plane run with the cohort's indicator mask (the pooled
    momenta plane rides the same gather/scatter as laq's residual)."""
    from repro.core.engine import cohorts_to_participation, sample_cohorts

    params = _params()
    rule = _local_rule("local_momentum", h=H)
    batches = _batches()
    cohorts = sample_cohorts(M, 2, ROUNDS, seed=3)
    pmasks = cohorts_to_participation(cohorts, M)

    dense = CADAEngine(_loss_fn, None, rule, M, fused=True)
    dst, dmets = jax.jit(dense.run)(dense.init(params), batches,
                                    jnp.asarray(pmasks))

    coh = CADAEngine(_loss_fn, None, rule, M, fused=True)
    cst, pool = coh.init_cohort(params)
    for k in range(ROUNDS):
        cohort = cohorts[k]
        cb = jax.tree.map(lambda x: x[k][:, cohort], batches)
        cst, cm = coh.step_cohort(cst, pool, cb, cohort)
        np.testing.assert_array_equal(
            np.asarray(dmets["upload_mask"])[k][cohort],
            np.asarray(cm["upload_mask"]))
    np.testing.assert_array_equal(np.asarray(dst.params_flat),
                                  np.asarray(cst.params_flat))


def test_quantize_composes_with_delta_payload():
    """laq-style quantized uploads of the model delta ride the existing
    wire hook: the run works and ships fewer bytes than fp32."""
    params = _params()
    batches = _batches()
    fp32 = _local_rule("local_momentum")
    q8 = _local_rule("local_momentum", quantize_bits=8)
    b_fp32, b_q8 = [], []
    for rule, sink in ((fp32, b_fp32), (q8, b_q8)):
        eng = CADAEngine(_loss_fn, None, rule, M)
        _, mets = jax.jit(eng.run)(eng.init(params), batches)
        assert np.isfinite(np.asarray(mets["loss"])).all()
        sink.append(float(np.asarray(mets["bytes_up"]).sum()))
    assert b_q8[0] < b_fp32[0]


# ----------------------------------------------------------- adaptation

def test_adapt_period_shared_helper():
    h = jnp.array([1, 3, 8], jnp.int32)
    grow = jnp.array([True, False, True])
    np.testing.assert_array_equal(
        np.asarray(adapt_period(h, grow, 1, 8)), [2, 2, 8])
    # clipping at both bounds
    np.testing.assert_array_equal(
        np.asarray(adapt_period(jnp.array([1]), jnp.array([False]), 1, 8)),
        [1])


@pytest.mark.parametrize("profile", ["wan", "hetero"])
def test_round_time_h1_is_iter_time(profile):
    compute = network_profile(profile, M).compute
    for w in range(M):
        for k in (0, 3, 7):
            assert compute.round_time(w, k, 1.5, 1, 2) == \
                compute.iter_time(w, k, 1.5, 2)
    # h successive local iterations accumulate (start times advance)
    assert compute.round_time(0, 0, 0.0, 4, 1) > \
        compute.round_time(0, 0, 0.0, 1, 1)


def test_adaptive_schedule_grows_on_wan_shrinks_on_zero():
    params = _params()
    batches = _batches(rounds=6, h=8)
    rule = CommRule(kind="local_momentum", c=0.6, d_max=4, max_delay=10,
                    adapt_local_steps=True, local_steps_max=8,
                    local_lr=0.05)
    hs = {}
    for profile in ("wan", "zero"):
        rt = SimRuntime(_loss_fn, rule, M,
                        SimConfig(network=network_profile(profile, M)))
        res = rt.run(params, batches)
        hs[profile] = np.asarray(res.metrics["local_steps"])
    # WAN: comm dominates -> H climbs toward the cap
    assert (hs["wan"][-1] > hs["wan"][0]).all()
    assert hs["wan"].max() > 1
    # free links: compute dominates -> H collapses to (and stays at) 1
    assert (hs["zero"][1:] == 1).all()


# ----------------------------------------------------------- validation

def test_rule_validation_rejects_bad_local_steps():
    with pytest.raises(ValueError):
        CommRule(kind="local_momentum", local_steps=0)
    with pytest.raises(ValueError):
        CommRule(kind="local_momentum", local_lr=0.0)
    with pytest.raises(ValueError):
        CommRule(kind="local_momentum", local_beta=1.0)
    with pytest.raises(ValueError):
        CommRule(kind="local_momentum", adapt_local_steps=True,
                 local_steps_min=4, local_steps_max=2)
    # the payload/cadence axis belongs to delta-payload rules only
    with pytest.raises(ValueError):
        CommRule(kind="cada2", local_steps=2)
    with pytest.raises(ValueError):
        CommRule(kind="cada2", adapt_local_steps=True)


def test_bare_engine_rejects_adaptive_h():
    rule = CommRule(kind="local_momentum", adapt_local_steps=True)
    with pytest.raises(ValueError, match="clock"):
        CADAEngine(_loss_fn, None, rule, M)
    # the sim IS the clock: its constructor opts in
    CADAEngine(_loss_fn, None, rule, M, allow_adaptive_local_steps=True)


def test_sim_rejects_delta_async_and_adaptive_cohort():
    rule = _local_rule("fedadam")
    with pytest.raises(ValueError, match="barrier-only"):
        SimRuntime(_loss_fn, rule, M,
                   SimConfig(network=network_profile("wan", M),
                             mode="async"))
    arule = CommRule(kind="fedadam", adapt_local_steps=True,
                     local_lr=0.05)
    with pytest.raises(ValueError, match="cohort"):
        SimRuntime(_loss_fn, arule, M,
                   SimConfig(network=network_profile("wan", M),
                             cohort_size=2))


def test_grad_rules_reject_local_steps_argument():
    rule = CommRule(kind="cada2", c=0.6, d_max=4, max_delay=10)
    eng = CADAEngine(_loss_fn, None, rule, M)
    st = eng.init(_params())
    b = jax.tree.map(lambda x: x[0, 0], _batches(h=1))
    with pytest.raises(ValueError, match="delta-payload"):
        eng.step(st, b, local_steps=jnp.full((M,), 1, jnp.int32))
