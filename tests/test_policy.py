"""Per-arch training policy + hlo-cost DCN attribution unit tests."""
import jax
import numpy as np

import repro.configs as C
from repro.launch.mesh import make_host_mesh
from repro.launch.policy import train_policy
from repro.utils.hlo_cost import _spans_pods


def test_policy_big_archs_get_memory_policy():
    mesh = make_host_mesh()
    for arch in ("llama3-405b", "grok-1-314b"):
        hp = train_policy(C.get_config(arch), mesh)
        assert hp.cada_dtype == "bfloat16"
        assert hp.moments_dtype == "bfloat16"
        assert hp.microbatches >= 8
        # single-pod fallback: the paper's own baseline
        assert hp.rule.kind == "always"


def test_policy_small_archs_keep_paper_protocol():
    mesh = make_host_mesh()
    hp = train_policy(C.get_config("internlm2-1.8b"), mesh)
    assert hp.rule.kind == "cada2"
    assert hp.cada_dtype == "float32"       # paper-faithful
    assert hp.moments_dtype == "float32"


def test_spans_pods_iota_format():
    # 2 groups of 256 along pods: does NOT span
    line = 'x = f32[4] all-reduce(%a), replica_groups=[2,256]<=[512]'
    assert not _spans_pods(line, 256)
    # 256 groups of 2 pairing i and i+256: spans
    line2 = ('x = f32[4] all-reduce(%a), '
             'replica_groups=[256,2]<=[2,256]T(1,0)')
    assert _spans_pods(line2, 256)


def test_spans_pods_explicit_format():
    assert _spans_pods('replica_groups={{0,256},{1,257}}', 256)
    assert not _spans_pods('replica_groups={{0,1},{2,3}}', 256)


def test_multihost_bootstrap_noop_without_env(monkeypatch):
    from repro.launch import multihost
    monkeypatch.delenv("REPRO_COORDINATOR", raising=False)
    assert multihost.bootstrap() is False


def test_multihost_assert_fleet_fails_on_cpu():
    import pytest as _pytest
    from repro.launch import multihost
    with _pytest.raises(RuntimeError):
        multihost.assert_fleet("16x16")
