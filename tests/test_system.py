"""End-to-end behaviour of the whole system: paper protocol on a real
(small) problem through the PUBLIC api — engine + rules + optimizer + data
— and the LM path through configs + models + distributed trainer."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import dirichlet_partition, pad_to_matrix
from repro.data.synthetic import ijcnn1_like
from repro.distributed.trainer import (TrainHParams, init_train_state,
                                       make_train_step, worker_split)
from repro.models.small import logreg_init, logreg_loss
from repro.optim.adam import adam


def test_end_to_end_federated_cada_beats_adam_on_uploads():
    """The paper's headline experiment, end to end: heterogeneous workers,
    CADA2 reaches Adam-level loss with far fewer uploads."""
    m, iters = 10, 400
    ds = ijcnn1_like(n=4000)
    shards = pad_to_matrix(dirichlet_partition(ds.y, m=m, alpha=0.3,
                                               seed=0))
    sample = make_sampler(ds.x, ds.y, shards, 32)
    params = logreg_init(None, 22, 2)

    out = {}
    for kind in ("always", "cada2"):
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind=kind, c=0.6, d_max=10,
                                  max_delay=100), m)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        out[kind] = (float(np.asarray(mets["loss"])[-20:].mean()),
                     int(np.asarray(mets["uploads"]).sum()))

    loss_adam, up_adam = out["always"]
    loss_cada, up_cada = out["cada2"]
    assert loss_cada < loss_adam * 1.25          # comparable loss
    assert up_cada < up_adam * 0.4               # >=60% fewer uploads


def test_end_to_end_lm_training_loss_decreases():
    """LM path: config registry -> model -> hierarchical CADA trainer."""
    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="cada2", c=1.0, d_max=5,
                                    max_delay=20), lr=1e-3)
    m = 2
    step = jax.jit(make_train_step(cfg, hp, m))
    st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    # fixed batch: the step must be able to memorize it
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 33), 0,
                                      cfg.vocab)}, m)
    losses = []
    for _ in range(12):
        st, mets = step(st, batch)
        losses.append(float(mets["loss"]))
    assert losses[-1] < losses[0] * 0.9
    assert all(np.isfinite(losses))


import pytest


@pytest.mark.parametrize("kind", ["cada2", "cada1", "lag", "always"])
def test_engine_and_distributed_trainer_agree(kind):
    """The paper-faithful engine (core/engine.py) and the production
    pod-trainer (distributed/trainer.py) implement the SAME Algorithm 1:
    identical data => identical parameter trajectories, for EVERY rule."""
    from repro.core.engine import CADAEngine
    from repro.optim.adam import adam

    cfg = C.get_smoke_config("stablelm-1.6b")
    m, steps = 2, 3
    rule = CommRule(kind=kind, c=0.5, d_max=4, max_delay=10)
    lr = 1e-3

    def loss_fn(params, batch):
        from repro.models.model import lm_loss
        return lm_loss(cfg, params, batch)[0]

    batches = [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(10 + i),
                                      (4, 33), 0, cfg.vocab)}, m)
        for i in range(steps)]

    # engine
    eng = CADAEngine(loss_fn, adam(lr=lr), rule, m)
    from repro.models.model import init_params
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    est = eng.init(params0)
    estep = jax.jit(eng.step)
    for b in batches:
        est, _ = estep(est, b)

    # distributed trainer
    hp = TrainHParams(rule=rule, lr=lr)
    tstep = jax.jit(make_train_step(cfg, hp, m))
    tst = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    for b in batches:
        tst, _ = tstep(tst, b)

    for a, b in zip(jax.tree.leaves(est.params),
                    jax.tree.leaves(tst.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)
