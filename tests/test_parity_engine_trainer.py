"""Engine ↔ trainer parity: the guardrail for the shared Algorithm-1 core.

Both `core/engine.py` (vmap-simulated workers) and `distributed/trainer.py`
(pod runtime) consume the SAME Algorithm-1 core; this test pins that
contract on the DEFAULT configuration of both — the flat-buffer state
plane with the fused AMSGrad/CADA server update (core/flat.py +
optim/fused.py): on identical data, for EVERY rule, identical
per-iteration upload masks, staleness vectors, and (numerically) identical
parameter trajectories. The per-leaf reference pair (fused=False engine vs
non-fused trainer) is pinned for cada2 as the oracle-side guardrail.

The SHARDED leg (needs an 8-device forced-host mesh — the CI mesh matrix
leg sets XLA_FLAGS=--xla_force_host_platform_device_count=8) pins the
fused flat plane under ZeRO'd state (``state_fsdp_axes=("data",)``)
against the per-leaf pytree reference for EVERY rule: `_flat_enabled` is
gone, so these hparams now run the fused sharded plane, and the masks /
staleness must be bit-identical to the reference. The policy-knob tests
(bf16 moments, explicit FSDP, ZeRO'd state) run mesh-free on any device
count — the configurations that used to fall back to the per-leaf path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.engine import CADAEngine
from repro.core.rules import RULES, CommRule
from repro.distributed.trainer import (TrainHParams, flat_state_shards,
                                       init_train_state, jit_train_step,
                                       make_train_step, worker_split)
from repro.launch.mesh import compat_make_mesh, set_mesh
from repro.models.model import init_params, lm_loss
from repro.optim.adam import adam
from repro.optim.fused import FusedAMSGrad

CFG = C.get_smoke_config("stablelm-1.6b")
M = 2
STEPS = 6
LR = 1e-3

needs_mesh8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI mesh matrix leg)")


def _loss_fn(params, wbatch):
    return lm_loss(CFG, params, wbatch)[0]


def _batches():
    return [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (4, 33), 0, CFG.vocab)}, M)
        for i in range(STEPS)]


def _run_engine(rule, fused=True):
    # FusedAMSGrad IS the trainer's fused stream; the reference pair uses
    # adam() whose defaults match it: amsgrad=True, eps inside the sqrt,
    # no bias correction (paper eqs. 2a-2c)
    if fused:
        eng = CADAEngine(_loss_fn, FusedAMSGrad(lr=LR), rule, M)
    else:
        eng = CADAEngine(_loss_fn, adam(lr=LR), rule, M, fused=False)
    st = eng.init(init_params(CFG, jax.random.PRNGKey(0)))
    step = jax.jit(eng.step)
    mets = []
    for b in _batches():
        st, m = step(st, b)
        mets.append(m)
    return st, mets


def _run_trainer(rule, fused=True):
    hp = TrainHParams(rule=rule, lr=LR, fused=fused)
    step = jax.jit(make_train_step(CFG, hp, M))
    st = init_train_state(CFG, hp, M, jax.random.PRNGKey(0))
    mets = []
    for b in _batches():
        st, m = step(st, b)
        mets.append(m)
    return st, mets


def _assert_parity(kind, emets, tmets, est, tst):

    for i, (em, tm) in enumerate(zip(emets, tmets)):
        np.testing.assert_array_equal(
            np.asarray(em["upload_mask"]), np.asarray(tm["upload_mask"]),
            err_msg=f"{kind}: upload mask diverged at iteration {i}")
        np.testing.assert_array_equal(
            np.asarray(em["staleness"]), np.asarray(tm["staleness"]),
            err_msg=f"{kind}: staleness diverged at iteration {i}")
        assert int(em["uploads"]) == int(tm["uploads"])

    for a, b in zip(jax.tree.leaves(est.params),
                    jax.tree.leaves(tst.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("kind", RULES)
def test_engine_and_trainer_identical_per_iteration(kind):
    """Default (fused flat-plane) engine vs default trainer — all rules.

    c chosen so the mask is MIXED over the run (some uploads, some skips)
    for the adaptive rules — parity on all-upload trajectories alone
    would not exercise the stale branches.
    """
    rule = CommRule(kind=kind, c=20.0, d_max=4, max_delay=10)
    est, emets = _run_engine(rule)
    tst, tmets = _run_trainer(rule)
    _assert_parity(kind, emets, tmets, est, tst)


def test_reference_pair_parity_cada2():
    """The per-leaf reference implementations stay in lockstep too."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    est, emets = _run_engine(rule, fused=False)
    tst, tmets = _run_trainer(rule, fused=False)
    _assert_parity("cada2-ref", emets, tmets, est, tst)


def test_adaptive_rules_actually_skip_in_this_setup():
    """Meta-check: the parity run exercises BOTH branches (uploads and
    skips) for the adaptive rules — otherwise the test above proves less
    than it claims."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    _, emets = _run_engine(rule)
    total = sum(int(m["uploads"]) for m in emets)
    assert 0 < total < STEPS * M, total


# ------------------------------------------------- sharding-policy parity
# The hparams that used to force the per-leaf fallback (_flat_enabled) now
# run the fused flat plane; each must still match the per-leaf reference.

POLICIES = {
    "bf16_moments": dict(moments_dtype="bfloat16"),
    "fsdp": dict(fsdp=True),
    "zero_state": dict(state_fsdp_axes=("data",)),
}


def _run_trainer_hp(hp, m, batches):
    step = jax.jit(make_train_step(CFG, hp, m))
    st = init_train_state(CFG, hp, m, jax.random.PRNGKey(0))
    mets = []
    for b in batches:
        st, mm = step(st, b)
        mets.append(mm)
    return st, mets


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_policy_knobs_run_fused_and_match_reference(policy):
    """Mesh-free: bf16 moments / FSDP / ZeRO'd-state hparams run the flat
    plane (h is a single (n_flat,) buffer) and match the per-leaf
    reference per iteration."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    kw = POLICIES[policy]
    hp_f = TrainHParams(rule=rule, lr=LR, **kw)
    hp_r = TrainHParams(rule=rule, lr=LR, fused=False, **kw)
    batches = _batches()
    stf, mf = _run_trainer_hp(hp_f, M, batches)
    assert stf.h.ndim == 1, "flat plane expected (no fallback fork left)"
    if policy == "bf16_moments":
        assert stf.h.dtype == jnp.bfloat16
    str_, mr = _run_trainer_hp(hp_r, M, batches)
    _assert_parity(f"cada2-{policy}", mf, mr, stf, str_)


@needs_mesh8
@pytest.mark.parametrize("kind", RULES)
def test_fused_sharded_matches_reference_all_rules(kind):
    """The acceptance gate: fused flat plane with ZeRO'd state on an
    8-device (data=8, model=1) mesh vs the per-leaf pytree reference, for
    EVERY rule — upload masks and staleness bit-identical, parameters
    numerically identical. Quantized-wire rules (cinn/laq) get a wider
    parameter tolerance: the mesh partitions the gradient reductions, and
    one-ulp gradient differences flip quantization buckets (a full
    quantization step, ~1e-4·scale), while the Algorithm-1 decisions stay
    exact."""
    mesh = compat_make_mesh((8, 1), ("data", "model"))
    m, steps = 8, 4
    rule = CommRule(kind=kind, c=20.0, d_max=4, max_delay=10)
    batches = [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (8, 33), 0, CFG.vocab)}, m)
        for i in range(steps)]

    hp_s = TrainHParams(rule=rule, lr=LR, state_fsdp_axes=("data",))
    make, _, mm = jit_train_step(CFG, mesh, hp_s)
    assert mm == m
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batches[0])
    with set_mesh(mesh):
        step = make(sds)
        st = init_train_state(CFG, hp_s, m, jax.random.PRNGKey(0),
                              shards=flat_state_shards(CFG, mesh, hp_s))
        ms = []
        for b in batches:
            st, met = step(st, b)
            ms.append(met)
    # the server planes actually shard over the data axis
    assert st.h.sharding.spec[0] == "data"

    hp_r = TrainHParams(rule=rule, lr=LR, fused=False)
    str_, mr = _run_trainer_hp(hp_r, m, batches)

    for i, (a, b) in enumerate(zip(ms, mr)):
        np.testing.assert_array_equal(
            np.asarray(a["upload_mask"]), np.asarray(b["upload_mask"]),
            err_msg=f"{kind}: sharded mask diverged at iteration {i}")
        np.testing.assert_array_equal(
            np.asarray(a["staleness"]), np.asarray(b["staleness"]),
            err_msg=f"{kind}: sharded staleness diverged at iteration {i}")
    rtol, atol = ((1e-2, 2e-3) if kind in ("cinn", "laq")
                  else (1e-4, 1e-6))
    for a, b in zip(jax.tree.leaves(st.params),
                    jax.tree.leaves(str_.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


@needs_mesh8
def test_sharded_parity_mask_is_mixed():
    """Meta-check for the sharded gate: the cada2 run above exercises both
    uploads and skips."""
    mesh = compat_make_mesh((8, 1), ("data", "model"))
    m, steps = 8, 4
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    hp = TrainHParams(rule=rule, lr=LR, state_fsdp_axes=("data",))
    make, _, _ = jit_train_step(CFG, mesh, hp)
    batches = [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (8, 33), 0, CFG.vocab)}, m)
        for i in range(steps)]
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batches[0])
    total = 0
    with set_mesh(mesh):
        step = make(sds)
        st = init_train_state(CFG, hp, m, jax.random.PRNGKey(0),
                              shards=flat_state_shards(CFG, mesh, hp))
        for b in batches:
            st, met = step(st, b)
            total += int(met["uploads"])
    assert 0 < total < steps * m, total
