"""Engine ↔ trainer parity: the guardrail for the shared Algorithm-1 core.

Both `core/engine.py` (vmap-simulated workers) and `distributed/trainer.py`
(pod runtime) consume the SAME Algorithm-1 core; this test pins that
contract on the DEFAULT configuration of both — the flat-buffer state
plane with the fused AMSGrad/CADA server update (core/flat.py +
optim/fused.py): on identical data, for EVERY rule, identical
per-iteration upload masks, staleness vectors, and (numerically) identical
parameter trajectories. The per-leaf reference pair (fused=False engine vs
non-fused trainer) is pinned for cada2 as the oracle-side guardrail.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.engine import CADAEngine
from repro.core.rules import RULES, CommRule
from repro.distributed.trainer import (TrainHParams, init_train_state,
                                       make_train_step, worker_split)
from repro.models.model import init_params, lm_loss
from repro.optim.adam import adam
from repro.optim.fused import FusedAMSGrad

CFG = C.get_smoke_config("stablelm-1.6b")
M = 2
STEPS = 6
LR = 1e-3


def _loss_fn(params, wbatch):
    return lm_loss(CFG, params, wbatch)[0]


def _batches():
    return [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (4, 33), 0, CFG.vocab)}, M)
        for i in range(STEPS)]


def _run_engine(rule, fused=True):
    # FusedAMSGrad IS the trainer's fused stream; the reference pair uses
    # adam() whose defaults match it: amsgrad=True, eps inside the sqrt,
    # no bias correction (paper eqs. 2a-2c)
    if fused:
        eng = CADAEngine(_loss_fn, FusedAMSGrad(lr=LR), rule, M)
    else:
        eng = CADAEngine(_loss_fn, adam(lr=LR), rule, M, fused=False)
    st = eng.init(init_params(CFG, jax.random.PRNGKey(0)))
    step = jax.jit(eng.step)
    mets = []
    for b in _batches():
        st, m = step(st, b)
        mets.append(m)
    return st, mets


def _run_trainer(rule, fused=True):
    hp = TrainHParams(rule=rule, lr=LR, fused=fused)
    step = jax.jit(make_train_step(CFG, hp, M))
    st = init_train_state(CFG, hp, M, jax.random.PRNGKey(0))
    mets = []
    for b in _batches():
        st, m = step(st, b)
        mets.append(m)
    return st, mets


def _assert_parity(kind, emets, tmets, est, tst):

    for i, (em, tm) in enumerate(zip(emets, tmets)):
        np.testing.assert_array_equal(
            np.asarray(em["upload_mask"]), np.asarray(tm["upload_mask"]),
            err_msg=f"{kind}: upload mask diverged at iteration {i}")
        np.testing.assert_array_equal(
            np.asarray(em["staleness"]), np.asarray(tm["staleness"]),
            err_msg=f"{kind}: staleness diverged at iteration {i}")
        assert int(em["uploads"]) == int(tm["uploads"])

    for a, b in zip(jax.tree.leaves(est.params),
                    jax.tree.leaves(tst.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("kind", RULES)
def test_engine_and_trainer_identical_per_iteration(kind):
    """Default (fused flat-plane) engine vs default trainer — all rules.

    c chosen so the mask is MIXED over the run (some uploads, some skips)
    for the adaptive rules — parity on all-upload trajectories alone
    would not exercise the stale branches.
    """
    rule = CommRule(kind=kind, c=20.0, d_max=4, max_delay=10)
    est, emets = _run_engine(rule)
    tst, tmets = _run_trainer(rule)
    _assert_parity(kind, emets, tmets, est, tst)


def test_reference_pair_parity_cada2():
    """The per-leaf reference implementations stay in lockstep too."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    est, emets = _run_engine(rule, fused=False)
    tst, tmets = _run_trainer(rule, fused=False)
    _assert_parity("cada2-ref", emets, tmets, est, tst)


def test_adaptive_rules_actually_skip_in_this_setup():
    """Meta-check: the parity run exercises BOTH branches (uploads and
    skips) for the adaptive rules — otherwise the test above proves less
    than it claims."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    _, emets = _run_engine(rule)
    total = sum(int(m["uploads"]) for m in emets)
    assert 0 < total < STEPS * M, total
