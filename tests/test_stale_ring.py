"""The stale-iterate ring (cada2's flat eval-point state) and the grouped /
stacked second-evaluation forms.

The contract under test: staleness ≤ max_delay = D bounds the number of
DISTINCT global iterates among the M stale copies θ^{k−τ_m} at D+1, so the
flat plane's R = min(M, D)+1 ring rows + (M,) slot index represent the
dense (M,)-leading ``worker_params`` pytree EXACTLY. The dense plane is
reconstructed here as a test-local strategy subclass (the pre-ring hooks,
verbatim) and pinned against the ring across seeds and D ∈ {1, 5, 50} on
the engine, the trainer, and the async sim runtime — upload masks,
staleness, and parameters bit-exact. Property tests check the occupancy
bound and that ``ring[slot[m]]`` reproduces each worker's exact θ^{k−τ_m}
at every iteration; the large-M smoke (the CI leg's regression trap
against re-densifying) checks eval-point state stays O(D·n) at M=2048.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm
from repro.core.comm import broadcast_to_workers
from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss, mlp_init, mlp_loss
from repro.optim.fused import FusedAMSGrad
from repro.sim import SimConfig, SimRuntime, network_profile, simulate

M = 4
STEPS = 12


class DenseCADA2(comm.CADA2Strategy):
    """The PRE-RING dense flat plane, restored verbatim as the oracle:
    stale iterates as an (M,)-leading ``worker_params`` pytree, the second
    eval via the legacy ``second_eval_per_worker`` hook."""

    def init_flat_extras(self, layout, params, params_flat, m, grad_dtype):
        del layout, params_flat, grad_dtype
        return {"worker_params": broadcast_to_workers(params, m)}

    def flat_extras_specs(self, param_spec, worker_param_spec, waxis, P,
                          col_axes=()):
        del param_spec, waxis, col_axes
        return {"worker_params": worker_param_spec}

    def second_eval_indexed(self, extras):
        return None

    def second_eval_per_worker(self, extras):
        return extras["worker_params"]

    def flat_post_upload(self, extras, cache, upload, ctx):
        return self.post_upload(extras, cache, upload, ctx)

    async_indexed_extras = ()


class SharedCADA1(comm.CADA1Strategy):
    """CADA1 forced onto the LEGACY shared-point eval path (indexed hook
    disabled) — the pre-ring dispatch, for the degenerate-ring parity."""

    def second_eval_indexed(self, extras):
        return None


def _problem(m=M, steps=STEPS, seed=2, n=400, batch=16):
    ds = ijcnn1_like(n=n)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, batch)
    params = logreg_init(None, 22, 2)
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(seed), steps))
    return params, batches


def _run(rule, params, batches, strategy=None, **kw):
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M, **kw)
    if strategy is not None:
        eng.strategy = strategy(rule)
    st, mets = jax.jit(eng.run)(eng.init(params), batches)
    return st, mets


def _assert_bit_exact(sa, ma, sb, mb, what):
    np.testing.assert_array_equal(
        np.asarray(ma["upload_mask"]), np.asarray(mb["upload_mask"]),
        err_msg=f"{what}: upload masks diverged")
    np.testing.assert_array_equal(
        np.asarray(ma["staleness"]), np.asarray(mb["staleness"]),
        err_msg=f"{what}: staleness diverged")
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=f"{what}: params diverged")


# ------------------------------------------------- ring vs dense (engine)

@pytest.mark.parametrize("seed", (2, 7))
@pytest.mark.parametrize("max_delay", (1, 5, 50))
def test_ring_matches_dense_plane_engine(max_delay, seed):
    """The acceptance gate: the ring-indexed cada2 flat plane is
    BIT-EXACT (masks, staleness, params) against the pre-ring dense
    ``worker_params`` plane, across seeds and D ∈ {1, 5, 50} — D=1 forces
    an upload every round (maximal ring churn), D=50 > steps never
    cap-forces (slots pin to row 0 until rule-driven uploads)."""
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=max_delay)
    params, batches = _problem(seed=seed)
    st_r, m_r = _run(rule, params, batches)
    st_d, m_d = _run(rule, params, batches, strategy=DenseCADA2)
    assert "ring" in st_r.comm.extras and "worker_params" in st_d.comm.extras
    _assert_bit_exact(st_r, m_r, st_d, m_d, f"cada2 D={max_delay} s={seed}")


def test_ring_mask_is_mixed_meta():
    """Meta-check: the D=5 parity run above exercises BOTH branches."""
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=5)
    params, batches = _problem()
    _, mets = _run(rule, params, batches)
    total = int(np.asarray(mets["uploads"]).sum())
    assert 0 < total < STEPS * M, total


def test_cada1_degenerate_ring_matches_legacy_shared():
    """CADA1's snapshot rides the DEGENERATE ring (R=1, slot=None) via the
    base ``second_eval_indexed`` adapter — bit-exact vs the legacy
    shared-point dispatch it replaced."""
    rule = CommRule(kind="cada1", c=5.0, d_max=4, max_delay=6)
    params, batches = _problem()
    st_r, m_r = _run(rule, params, batches)
    st_s, m_s = _run(rule, params, batches, strategy=SharedCADA1)
    _assert_bit_exact(st_r, m_r, st_s, m_s, "cada1 degenerate ring")


# ------------------------------------------------- ring properties

def test_ring_occupancy_and_gather_reproduction():
    """Per-iteration properties of the ring invariant:

      * occupancy — the number of DISTINCT slots referenced never exceeds
        min(M, D)+1 (the bound that makes R rows sufficient);
      * gather reproduction — ``ring[slot[m]]`` is bit-exactly worker m's
        θ^{k−τ_m}: the iterate current when it last uploaded (θ^0 before
        any upload), tracked independently host-side from the masks.
    """
    d = 5
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=d)
    params, batches = _problem(steps=STEPS)
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
    st = eng.init(params)
    step = jax.jit(eng.step)
    expected = [params] * M
    for i in range(STEPS):
        before = st.params
        st, mets = step(st, jax.tree.map(lambda x: x[i], batches))
        mask = np.asarray(mets["upload_mask"])
        for w in range(M):
            if mask[w]:
                expected[w] = before
        slot = np.asarray(st.comm.extras["slot"])
        ring = st.comm.extras["ring"]
        assert len(np.unique(slot)) <= min(M, d) + 1
        for w in range(M):
            got = jax.tree.map(lambda x: x[slot[w]], ring)
            for a, b in zip(jax.tree.leaves(got),
                            jax.tree.leaves(expected[w])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"worker {w} stale point wrong at iter {i}")


def test_ring_rows_formula():
    for m, d, want in ((4, 5, 5), (4, 50, 5), (2048, 8, 9), (1, 1, 2)):
        strat = comm.strategy_for(
            CommRule(kind="cada2", max_delay=d))
        assert strat.ring_rows(m) == want


# ------------------------------------- grouped / stacked eval forms

def test_grouped_second_eval_matches_gathered():
    """``group_evals``: ≤R broadcast-point evals scattered by slot — each
    worker keeps its own sample, masks and staleness bit-exact vs the
    gathered per-worker vmap; params numerically identical."""
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=5)
    params, batches = _problem()
    st_g, m_g = _run(rule, params, batches, group_evals=True)
    st_r, m_r = _run(rule, params, batches)
    np.testing.assert_array_equal(np.asarray(m_g["upload_mask"]),
                                  np.asarray(m_r["upload_mask"]))
    np.testing.assert_array_equal(np.asarray(m_g["staleness"]),
                                  np.asarray(m_r["staleness"]))
    for a, b in zip(jax.tree.leaves(st_g.params),
                    jax.tree.leaves(st_r.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("strategy", (None, DenseCADA2),
                         ids=("ring", "legacy-dense"))
def test_stacked_fused_eval_close_to_unfused(strategy):
    """``fuse_evals`` (the broadcast 2-way eval axis, batch NOT copied —
    the default) on both the ring-gather route and the legacy dense
    per-worker route: numerically equivalent to the two-call dispatch.
    Masks are pinned exact; params get allclose headroom because vmap
    nesting forms are allowed to differ by ulps on other backends (the
    strict bit-exact pins against the reference plane live in the parity
    gates, which run this default)."""
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=5)
    params, batches = _problem()
    st_f, m_f = _run(rule, params, batches, strategy=strategy,
                     fuse_evals=True)
    st_u, m_u = _run(rule, params, batches, strategy=strategy,
                     fuse_evals=False)
    np.testing.assert_array_equal(np.asarray(m_f["upload_mask"]),
                                  np.asarray(m_u["upload_mask"]))
    for a, b in zip(jax.tree.leaves(st_f.params),
                    jax.tree.leaves(st_u.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


# ------------------------------------------------- trainer leg

def test_ring_matches_dense_plane_trainer(monkeypatch):
    """The pod trainer consumes the same flat hooks: ring vs dense
    bit-exact on the LM smoke config (dense arm via a registry patch)."""
    import repro.configs as C
    from repro.distributed.trainer import (TrainHParams, init_train_state,
                                           make_train_step, worker_split)
    cfg = C.get_smoke_config("stablelm-1.6b")
    m, steps = 2, 6
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    batches = [worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                      (4, 33), 0, cfg.vocab)}, m)
        for i in range(steps)]

    def arm():
        hp = TrainHParams(rule=rule, lr=1e-3)
        step = jax.jit(make_train_step(cfg, hp, m))
        st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
        mets = []
        for b in batches:
            st, mm = step(st, b)
            mets.append(mm)
        return st, mets

    st_r, m_r = arm()
    assert "ring" in st_r.comm.extras
    monkeypatch.setitem(comm.STRATEGIES, "cada2", DenseCADA2)
    st_d, m_d = arm()
    assert "worker_params" in st_d.comm.extras
    for i, (a, b) in enumerate(zip(m_r, m_d)):
        np.testing.assert_array_equal(
            np.asarray(a["upload_mask"]), np.asarray(b["upload_mask"]),
            err_msg=f"trainer masks diverged at iteration {i}")
        np.testing.assert_array_equal(
            np.asarray(a["staleness"]), np.asarray(b["staleness"]))
    for a, b in zip(jax.tree.leaves(st_r.params),
                    jax.tree.leaves(st_d.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ------------------------------------------------- sim async leg

def test_ring_matches_dense_plane_async_sim():
    """The async event loop tracks each worker's exact stale point
    host-side and hands the gate a synthetic one-row ring — losses,
    uploads, and final params bit-exact vs the dense per-worker slicing
    the pre-ring runtime did."""
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    params, batches = _problem(m=3, steps=8)
    res_r = simulate(logreg_loss, rule, params, batches, n_workers=3,
                     network="zero", mode="async", async_tau=5, lr=0.01)
    cfg = SimConfig(network=network_profile("zero", 3), mode="async",
                    async_tau=5)
    rt = SimRuntime(logreg_loss, rule, 3, cfg, lr=0.01)
    rt.engine.strategy = DenseCADA2(rule)
    res_d = rt.run(params, batches)
    assert res_r.uploads == res_d.uploads
    np.testing.assert_array_equal(res_r.losses, res_d.losses)
    for a, b in zip(jax.tree.leaves(res_r.final_params),
                    jax.tree.leaves(res_d.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- checkpoint round-trip

def test_ring_checkpoint_reshard_roundtrip(tmp_path):
    """Ring + slot + version survive a checkpoint reshard across state
    shard counts: the (M, n_flat) planes re-cut their padding while the
    ring extras (param/index-shaped, not flat planes) take the exact-shape
    path verbatim."""
    import repro.checkpoint.io as ckpt
    import repro.configs as C
    from repro.distributed.trainer import (TrainHParams, flat_layout,
                                           init_train_state,
                                           make_train_step, worker_split)
    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="cada2", c=0.5, d_max=4,
                                    max_delay=10), lr=1e-3)
    m = 2
    lay2 = flat_layout(cfg, shards=2)
    shards_src = next(s for s in (4, 8, 16, 32, 64, 128)
                      if flat_layout(cfg, shards=s).n_flat != lay2.n_flat)
    lay4 = flat_layout(cfg, shards=shards_src)
    step4 = jax.jit(make_train_step(cfg, hp, m, shards=shards_src))
    st4 = init_train_state(cfg, hp, m, jax.random.PRNGKey(0),
                           shards=shards_src)
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab)}, m)
    st4, _ = step4(st4, batch)

    ckpt.save(str(tmp_path / "s4"), st4._asdict(), step=1, flat_meta=lay4)
    st2_like = jax.tree.map(
        jnp.zeros_like,
        init_train_state(cfg, hp, m, jax.random.PRNGKey(7),
                         shards=2)._asdict())
    restored, step_no = ckpt.restore(str(tmp_path / "s4"), st2_like)
    assert step_no == 1
    src = st4._asdict()["comm"].extras
    dst = restored["comm"].extras
    assert set(dst) == {"ring", "slot", "ring_version"}
    for key in ("ring", "slot", "ring_version"):
        for a, b in zip(jax.tree.leaves(dst[key]),
                        jax.tree.leaves(src[key])):
            assert a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    # the worker plane really resharded (padding re-cut)
    assert restored["comm"].worker_grads.shape == (m, lay2.n_flat)


# ------------------------------------------------- large-M smoke (CI leg)

def test_large_m_engine_smoke_state_is_ring_bounded():
    """The federated-scale smoke and re-densification trap: M=2048 workers
    on a tiny MLP, cada2. Eval-point state must be O(D·n) — the ring holds
    R = D+1 rows and NO extras leaf except the (M,) slot index leads with
    M — and a few fused steps must run."""
    m, d = 2048, 8
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=d)
    ds = ijcnn1_like(n=4096)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 2)
    params = mlp_init(jax.random.PRNGKey(0), 22, 16, 2)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.05), rule, m)
    st = eng.init(params)

    extras = st.comm.extras
    assert set(extras) == {"ring", "slot", "ring_version"}
    rr = min(m, d) + 1
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(params))
    ring_bytes = sum(int(l.size * l.dtype.itemsize)
                     for l in jax.tree.leaves(extras["ring"]))
    assert ring_bytes == rr * n_params * 4        # O(D·n), NOT O(M·n)
    assert extras["slot"].shape == (m,)
    assert extras["ring_version"].shape == (rr,)
    for key in ("ring", "ring_version"):
        for leaf in jax.tree.leaves(extras[key]):
            assert leaf.shape[0] == rr            # nothing M-leading
    # dense-equivalent state would be m * n_params * 4 — 227x larger here
    assert ring_bytes * 64 < m * n_params * 4

    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(3), 3))
    st, mets = jax.jit(eng.run)(st, batches)
    assert np.isfinite(np.asarray(mets["loss"])).all()
    assert int(np.asarray(mets["uploads"]).sum()) > 0
