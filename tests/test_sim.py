"""The discrete-event heterogeneous-cluster runtime (repro.sim).

The two gates the subsystem stands on:

  * **degenerate parity** — under the ``zero`` profile (zero latency,
    homogeneous compute, barrier mode, full participation) the sim MUST
    reproduce the plain synchronous engine: per-iteration upload masks and
    staleness bit-exact, params numerically identical, for every
    registered rule;
  * **the wall-clock claim** — where uploads are expensive, a compressed
    rule beats ``always`` on simulated time-to-target-loss; where they
    are free, it does not.

Plus the async bounded-staleness mode (convergence, staleness cap,
determinism, straggler tolerance), partial participation, and the clock /
event machinery.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import RULES, CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss
from repro.optim.fused import FusedAMSGrad
from repro.sim import (ComputeModel, EventQueue, LinkModel, NetworkProfile,
                       ParticipationModel, SimConfig, SimRuntime,
                       network_profile, simulate, summarize, time_to_target)

M = 3
STEPS = 8


def _problem(m=M, iters=STEPS, n=600, batch=16):
    ds = ijcnn1_like(n=n)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, batch)
    params = logreg_init(None, 22, 2)
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(1), iters))
    return params, batches


# ----------------------------------------------------- degenerate parity

@pytest.mark.parametrize("kind", RULES)
def test_degenerate_sim_matches_engine(kind):
    """Acceptance gate: zero-latency homogeneous barrier sim ≡ the plain
    synchronous engine, for every registered rule — masks and staleness
    bit-exact, params numerically equal. (c chosen so the adaptive rules
    produce MIXED masks over the run.)"""
    params, batches = _problem()
    rule = CommRule(kind=kind, c=20.0, d_max=4, max_delay=10)

    res = simulate(logreg_loss, rule, params, batches, n_workers=M,
                   network="zero", mode="barrier", lr=0.01)

    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.01), rule, M)
    st = eng.init(params)
    fst, mets = jax.jit(eng.run)(st, batches)

    np.testing.assert_array_equal(
        res.upload_masks, np.asarray(mets["upload_mask"]),
        err_msg=f"{kind}: sim upload masks diverged from the engine")
    np.testing.assert_array_equal(
        res.staleness, np.asarray(mets["staleness"]),
        err_msg=f"{kind}: sim staleness diverged from the engine")
    np.testing.assert_array_equal(
        res.losses, np.asarray(mets["loss"], np.float64))
    for a, b in zip(jax.tree.leaves(res.final_params),
                    jax.tree.leaves(fst.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)


def test_degenerate_parity_run_has_mixed_masks():
    """Meta-check: the parity run above exercises BOTH branches."""
    params, batches = _problem()
    rule = CommRule(kind="cada2", c=20.0, d_max=4, max_delay=10)
    res = simulate(logreg_loss, rule, params, batches, n_workers=M,
                   network="zero", mode="barrier", lr=0.01)
    total = int(res.upload_masks.sum())
    assert 0 < total < STEPS * M, total


# ------------------------------------------------------ wall-clock claims

def _thin_uplink_profile(m):
    """Uploads bandwidth-dominated even for logreg's 184-byte plane."""
    return NetworkProfile(
        name="thin",
        compute=ComputeModel.make(m, eval_s=1e-3),
        link=LinkModel.make(m, latency_s=1e-3, bandwidth=2e3,
                            down_bandwidth=2e5),
    )


def test_compressed_rule_wins_wall_clock_where_uploads_cost():
    """laq (8-bit wire + lazy skipping) must beat always on simulated
    time-to-target when the uplink is the bottleneck — and must NOT beat
    it when communication is free (zero profile)."""
    m, iters, target = 4, 150, 0.1
    params, batches = _problem(m=m, iters=iters, n=1200, batch=32)
    rules = {
        "always": CommRule(kind="always", c=0.6, d_max=10, max_delay=100),
        "laq": CommRule(kind="laq", c=0.6, d_max=10, max_delay=100),
    }
    t_thin, t_zero = {}, {}
    for name, rule in rules.items():
        res = simulate(logreg_loss, rule, params, batches, n_workers=m,
                       network=_thin_uplink_profile(m), mode="barrier",
                       lr=0.01)
        t_thin[name] = time_to_target(res, target)
        res0 = simulate(logreg_loss, rule, params, batches, n_workers=m,
                        network="zero", mode="barrier", lr=0.01)
        t_zero[name] = time_to_target(res0, target)
    assert t_thin["laq"] is not None and t_thin["always"] is not None
    assert t_thin["laq"] < t_thin["always"], t_thin
    # free links: the per-iteration-best rule is the wall-clock-best rule
    assert t_zero["always"] <= t_zero["laq"], t_zero


def test_wan_profile_prices_rounds_above_zero_profile():
    params, batches = _problem()
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=10)
    res0 = simulate(logreg_loss, rule, params, batches, n_workers=M,
                    network="zero", mode="barrier", lr=0.01)
    resw = simulate(logreg_loss, rule, params, batches, n_workers=M,
                    network="wan", mode="barrier", lr=0.01)
    # identical trajectory (profiles only price the schedule) ...
    np.testing.assert_array_equal(res0.upload_masks, resw.upload_masks)
    # ... at a very different price
    assert resw.wall_s > 10 * res0.wall_s
    assert resw.bytes_up == res0.bytes_up


def test_straggler_stalls_barrier_rounds():
    """Barrier mode: one 10× straggler prices every round ~10×."""
    params, batches = _problem()
    rule = CommRule(kind="always", c=0.6, d_max=10, max_delay=10)
    base = NetworkProfile(
        name="base", compute=ComputeModel.make(M, eval_s=1e-3),
        link=LinkModel.make(M))
    slow = NetworkProfile(
        name="slow",
        compute=ComputeModel.make(M, eval_s=1e-3,
                                  slowdown=[1.0] * (M - 1) + [10.0]),
        link=LinkModel.make(M))
    r_base = simulate(logreg_loss, rule, params, batches, n_workers=M,
                      network=base, mode="barrier", lr=0.01)
    r_slow = simulate(logreg_loss, rule, params, batches, n_workers=M,
                      network=slow, mode="barrier", lr=0.01)
    assert r_slow.wall_s == pytest.approx(10 * r_base.wall_s, rel=1e-6)
    # fast workers idle while the straggler finishes
    assert r_slow.utilization[0] == pytest.approx(0.1, rel=1e-6)


# ------------------------------------------------- partial participation

def test_partial_participation_masks_uploads():
    params, batches = _problem(iters=20)
    rule = CommRule(kind="always", c=0.6, d_max=10, max_delay=100)
    res = simulate(logreg_loss, rule, params, batches, n_workers=M,
                   network="zero", mode="barrier", participation=0.5,
                   lr=0.01)
    # uploads only ever come from participants ...
    assert not (res.upload_masks & ~res.participation_masks).any()
    # ... every round draws exactly ceil(0.5 * M) of them ...
    np.testing.assert_array_equal(
        res.participation_masks.sum(axis=1),
        np.full(20, int(np.ceil(0.5 * M))))
    # ... and offline workers outwait the sync staleness cap unharmed
    assert res.uploads < 20 * M


def test_participation_freezes_offline_avp_periods():
    """An offline worker's avp period must not adapt to a gradient it
    never computed (rule state frozen while offline). Huge c makes the
    RHS unclearable, so every ACTIVE worker's period grows each round —
    any growth on the offline worker would be adaptation to a gradient
    the sim charged zero compute for."""
    params, batches = _problem(iters=6)
    rule = CommRule(kind="avp", c=1e9, d_max=4, max_delay=50,
                    period_min=1, period_max=8)
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.01), rule, M)
    st = eng.init(params)
    offline = 1
    part = jnp.asarray([w != offline for w in range(M)])
    step = jax.jit(eng.step)
    for i in range(6):
        st, _ = step(st, jax.tree.map(lambda x: x[i], batches), part)
    periods = np.asarray(st.comm.extras["period"])
    assert periods[offline] == rule.period_min          # frozen at init
    assert (np.delete(periods, offline) > rule.period_min).all()


def test_async_rejects_partial_participation():
    with pytest.raises(ValueError, match="barrier"):
        SimConfig(network=network_profile("zero", 2), mode="async",
                  participation=0.5)


def test_async_tau_one_forces_upload_every_iteration():
    """τ_max=1 must reproduce max_delay=1: the post-upload counter
    restarts at 1, so every gate is capped."""
    params, batches = _problem(iters=10)
    rule = CommRule(kind="cada2", c=1e9, d_max=4, max_delay=50)
    res = simulate(logreg_loss, rule, params, batches, n_workers=M,
                   network="zero", mode="async", async_tau=1, lr=0.01)
    # huge c → the rule itself never fires; every upload is the cap's.
    # gates = local iterations; in-flight uploads at shutdown may leave
    # at most one gap per worker
    gates = len(res.losses)
    assert res.uploads >= gates - M
    assert res.uploads == pytest.approx(gates, abs=M)


def test_participation_model_is_deterministic():
    pm = ParticipationModel(8, 0.4, seed=3)
    m1, m2 = pm.mask(5), pm.mask(5)
    np.testing.assert_array_equal(m1, m2)
    assert pm.mask(6).sum() == pm.k_active == 4  # ceil(0.4 * 8)
    assert any((pm.mask(k) != m1).any() for k in range(6, 16))


# ----------------------------------------------------------- async mode

def test_async_converges_and_respects_staleness_cap():
    m, tau = 4, 6
    params, batches = _problem(m=m, iters=80, n=1200, batch=32)
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100)
    res = simulate(logreg_loss, rule, params, batches, n_workers=m,
                   network="zero", mode="async", async_tau=tau, lr=0.01)
    assert res.steps == 80                      # hit the version target
    assert res.uploads >= res.steps             # one upload per version
    # staleness observed AT the gate: the cap plus at most one iteration's
    # worth of other-worker server updates
    assert res.max_staleness <= tau + 2 * m
    # converged: the loss came down from log(2)
    order = np.argsort(res.loss_times)
    tail = res.losses[order][-12:]
    assert tail.mean() < 0.3, tail
    # wall-clock bookkeeping is self-consistent
    assert res.wall_s > 0 and (res.utilization <= 1.0 + 1e-9).all()


def test_async_replays_exactly():
    params, batches = _problem(m=3, iters=30)
    rule = CommRule(kind="laq", c=0.6, d_max=10, max_delay=20)
    runs = [simulate(logreg_loss, rule, params, batches, n_workers=3,
                     network="hetero", mode="async", async_tau=8, lr=0.01)
            for _ in range(2)]
    np.testing.assert_array_equal(runs[0].losses, runs[1].losses)
    np.testing.assert_array_equal(runs[0].loss_times, runs[1].loss_times)
    assert runs[0].wall_s == runs[1].wall_s
    assert runs[0].uploads == runs[1].uploads


def test_async_keeps_workers_busy_under_stragglers():
    """The point of the async mode: a straggler collapses barrier-mode
    utilization but not async utilization."""
    m = 4
    params, batches = _problem(m=m, iters=40, n=1200, batch=32)
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=50)
    prof = NetworkProfile(
        name="strag",
        compute=ComputeModel.make(m, eval_s=1e-3,
                                  slowdown=[1.0] * (m - 1) + [8.0]),
        link=LinkModel.make(m))
    r_bar = simulate(logreg_loss, rule, params, batches, n_workers=m,
                     network=prof, mode="barrier", lr=0.01)
    r_asy = simulate(logreg_loss, rule, params, batches, n_workers=m,
                     network=prof, mode="async", async_tau=10, lr=0.01)
    assert float(r_asy.utilization[:-1].mean()) \
        > 2 * float(r_bar.utilization[:-1].mean())


@pytest.mark.parametrize("kind", RULES)
def test_async_runs_every_registered_rule(kind):
    """Every strategy's flat hooks survive the one-row async slicing
    (shared extras pass through whole, per-worker extras slice/merge)."""
    params, batches = _problem(iters=12)
    rule = CommRule(kind=kind, c=0.6, d_max=4, max_delay=6)
    res = simulate(logreg_loss, rule, params, batches, n_workers=M,
                   network="zero", mode="async", async_tau=5, lr=0.01)
    assert res.steps == 12
    assert np.isfinite(res.losses).all()
    assert res.uploads >= res.steps


# --------------------------------------------------- clock / event units

def test_event_queue_orders_by_time_then_fifo():
    q = EventQueue()
    q.push(2.0, "b")
    q.push(1.0, "a1")
    q.push(1.0, "a2")
    q.push(0.5, "first", worker=7, tag="x")
    kinds = [q.pop() for _ in range(4)]
    assert [e.kind for e in kinds] == ["first", "a1", "a2", "b"]
    assert kinds[0].worker == 7 and kinds[0].payload == {"tag": "x"}
    assert not q


def test_link_model_prices_latency_plus_bytes():
    link = LinkModel.make(2, latency_s=0.01, bandwidth=1e3,
                          down_bandwidth=1e4)
    assert link.up_time(0, 500) == pytest.approx(0.01 + 0.5)
    assert link.down_time(0, 500) == pytest.approx(0.01 + 0.05)
    assert link.up_time(1, 0) == 0.0            # nothing to send
    free = LinkModel.make(1)                    # inf bandwidth, 0 latency
    assert free.up_time(0, 1e9) == 0.0


def test_compute_model_kinds():
    det = ComputeModel.make(2, eval_s=[1e-3, 2e-3])
    assert det.iter_time(0, 0, 0.0, 2) == pytest.approx(2e-3)
    assert det.iter_time(1, 5, 0.0, 1) == pytest.approx(2e-3)

    logn = ComputeModel.make(3, eval_s=1e-3, kind="lognormal", sigma=0.5,
                             seed=1)
    a = logn.eval_time(1, 4, 0, 0.0)
    assert a == logn.eval_time(1, 4, 0, 0.0)    # keyed draws replay
    assert a != logn.eval_time(1, 5, 0, 0.0)    # ... but vary by iter
    draws = [logn.eval_time(0, i, 0, 0.0) for i in range(400)]
    assert np.mean(draws) == pytest.approx(1e-3, rel=0.2)  # mean-preserving

    tr = ComputeModel.make(1, kind="trace", traces=[[1.0, 2.0]])
    seen = {tr.eval_time(0, i, 0, 0.0) for i in range(4)}
    assert seen == {1.0, 2.0}                   # cycles the trace

    windowed = ComputeModel.make(1, eval_s=1e-3,
                                 transient=[(0, 1.0, 2.0, 5.0)])
    assert windowed.eval_time(0, 0, 0, 0.5) == pytest.approx(1e-3)
    assert windowed.eval_time(0, 0, 0, 1.5) == pytest.approx(5e-3)
    assert windowed.eval_time(0, 0, 0, 2.5) == pytest.approx(1e-3)


def test_network_profiles_construct_and_validate():
    for name in ("zero", "lan", "wan", "hetero"):
        p = network_profile(name, 4)
        assert p.link.m == p.compute.m == 4
    with pytest.raises(ValueError):
        network_profile("dialup", 4)
    with pytest.raises(ValueError):
        SimConfig(network=network_profile("zero", 2), mode="warp")


def test_summarize_reports_time_to_target():
    params, batches = _problem(iters=30, m=2)
    rule = CommRule(kind="always", c=0.6, d_max=10, max_delay=100)
    res = simulate(logreg_loss, rule, params, batches, n_workers=2,
                   network="lan", mode="barrier", lr=0.01)
    row = summarize(res, target_loss=0.5)
    assert row["time_to_target_s"] is not None
    assert 0 < row["time_to_target_s"] <= round(res.wall_s, 6)
    assert row["mbytes_up"] > 0 and row["utilization_mean"] <= 1.0
    # unreachable target → None, not a crash
    assert summarize(res, target_loss=1e-9)["time_to_target_s"] is None


def test_link_trace_interpolates_and_holds():
    """Trace-driven bandwidth: (t, up_mbit_s, down_mbit_s) rows, linear
    interpolation between points, edge hold outside, cycled per worker."""
    link = LinkModel.make(3, latency_s=0.0,
                          trace=[[(0.0, 8.0, 80.0), (10.0, 16.0, 160.0)]])
    nb = 1e6                                     # send one MB
    # 8 Mbit/s = 1e6 B/s at t=0; 12 Mbit/s midway; 16 Mbit/s held after
    assert link.up_time(0, nb, now=0.0) == pytest.approx(1.0)
    assert link.up_time(0, nb, now=5.0) == pytest.approx(1 / 1.5)
    assert link.up_time(0, nb, now=99.0) == pytest.approx(0.5)
    assert link.up_time(0, nb) == pytest.approx(1.0)   # now defaults to 0
    # downlink reads the third column (10x fatter here)
    assert link.down_time(0, nb, now=0.0) == pytest.approx(0.1)
    # one trace, three workers: cycles like ComputeModel traces
    assert link.up_time(2, nb, now=0.0) == link.up_time(0, nb, now=0.0)
    # two-column rows mean a symmetric link
    sym = LinkModel.make(1, trace=[[(0.0, 8.0)]])
    assert sym.down_time(0, nb) == sym.up_time(0, nb) == pytest.approx(1.0)
    with pytest.raises(ValueError, match="non-decreasing"):
        LinkModel.make(1, trace=[[(1.0, 8.0), (0.0, 8.0)]])
    with pytest.raises(ValueError, match="positive"):
        LinkModel.make(1, trace=[[(0.0, -1.0)]])


def test_network_profile_trace_hook():
    """``network_profile(..., trace=)`` overlays time-varying bandwidth on
    a preset, keeping its latency and compute model."""
    tr = [[(0.0, 1.0), (100.0, 2.0)]]
    prof = network_profile("wan", 2, trace=tr)
    plain = network_profile("wan", 2)
    assert prof.link.latency_s == plain.link.latency_s
    assert prof.compute.eval_s == plain.compute.eval_s
    nb = 1.25e5                                  # = 1 Mbit in bytes
    lat = plain.link.latency_s[0]
    assert prof.link.up_time(0, nb, now=0.0) == pytest.approx(lat + 1.0)
    assert prof.link.up_time(0, nb, now=100.0) == pytest.approx(lat + 0.5)
    # a diurnal trace changes what a round costs over simulated time
    params, batches = _problem(iters=6, m=2)
    rule = CommRule(kind="always", c=0.6, d_max=10, max_delay=100)
    res = simulate(logreg_loss, rule, params, batches, n_workers=2,
                   network=prof, mode="barrier", lr=0.01)
    assert np.isfinite(res.losses).all() and res.wall_s > 0


# ------------------------------------------------ federated cohort plane

def test_cohort_sampling_matches_participation_model():
    """``sample_cohorts`` and ``ParticipationModel`` key their draws the
    same way ((seed, round) rng, choice without replacement), so a cohort
    run and a participation run sample THE SAME workers each round."""
    from repro.core.engine import cohorts_to_participation, sample_cohorts
    m, frac, steps, seed = 8, 0.4, 10, 3
    pm = ParticipationModel(m, frac, seed=seed)
    cohorts = sample_cohorts(m, pm.k_active, steps, seed=seed)
    np.testing.assert_array_equal(cohorts_to_participation(cohorts, m),
                                  pm.masks(steps))


def test_federated_cohort_sim_prices_cohort_only():
    """``cohort_size``: the federated barrier mode — C-worker rounds on
    the host-pool cohort plane, wall-clock priced over cohort members
    only, O(C·n)/O(M·n) byte split reported in the metrics."""
    m, c, rounds = 32, 8, 10
    params, _ = _problem(m=2, iters=1)           # params only
    ds = ijcnn1_like(n=600)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    from repro.core.engine import make_cohort_sampler
    sampler = make_cohort_sampler(ds.x, ds.y, mtx, 16)

    def batches(k, cohort):
        return sampler(jax.random.PRNGKey(100 + k), jnp.asarray(cohort))

    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=50)
    res = simulate(logreg_loss, rule, params, batches, n_workers=m,
                   network="lan", mode="barrier", cohort_size=c,
                   rounds=rounds, lr=0.01)
    assert res.steps == rounds
    assert res.upload_masks.shape == (rounds, c)
    assert np.isfinite(res.losses).all()
    assert res.metrics["cohorts"].shape == (rounds, c)
    assert res.metrics["device_worker_plane_bytes"] * (m // c) \
        <= res.metrics["host_pool_bytes"]
    # only cohort members download: C per round, never M
    n = sum(x.size for x in jax.tree.leaves(params))
    assert res.bytes_down == pytest.approx(rounds * c * 4.0 * n)
    # round 0: every first-sampled worker force-uploads (τ starts at cap)
    assert res.upload_masks[0].all()
    # array batches work too (small M): same plane, pre-sliced rows
    params2, dense_batches = _problem(m=4, iters=5)
    res2 = simulate(logreg_loss, rule, params2, dense_batches, n_workers=4,
                    network="zero", mode="barrier", cohort_size=2, lr=0.01)
    assert res2.steps == 5 and res2.upload_masks.shape == (5, 2)


@pytest.mark.parametrize("kind", ("cada1", "laq"))
def test_async_host_pool_matches_device_plane(kind):
    """``host_pool``: streaming each gate's row through the numpy pool is
    bit-exact with the device (M, n_flat) plane — same losses, same
    uploads, same clock (cada1/laq are the pooled-extras rules)."""
    params, batches = _problem(iters=10)
    rule = CommRule(kind=kind, c=0.6, d_max=4, max_delay=6)
    runs = [simulate(logreg_loss, rule, params, batches, n_workers=M,
                     network="hetero", mode="async", async_tau=5,
                     host_pool=hp, lr=0.01)
            for hp in (False, True)]
    np.testing.assert_array_equal(runs[0].losses, runs[1].losses)
    np.testing.assert_array_equal(runs[0].loss_times, runs[1].loss_times)
    assert runs[0].uploads == runs[1].uploads
    assert runs[0].wall_s == runs[1].wall_s
    for a, b in zip(jax.tree.leaves(runs[0].final_params),
                    jax.tree.leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cohort_and_host_pool_config_validation():
    net = network_profile("zero", 4)
    with pytest.raises(ValueError, match="barrier-mode"):
        SimConfig(network=net, mode="async", cohort_size=2)
    with pytest.raises(ValueError, match="async-mode"):
        SimConfig(network=net, mode="barrier", host_pool=True)
    with pytest.raises(ValueError, match="two ways"):
        SimConfig(network=net, cohort_size=2, participation=0.5)
    cfg = SimConfig(network=net, cohort_size=8)
    with pytest.raises(ValueError, match="cohort_size"):
        SimRuntime(logreg_loss, CommRule(kind="always"), 4, cfg).run(
            logreg_init(None, 22, 2), None, rounds=3)


def test_async_requires_fused_optimizer():
    from repro.optim.adam import adam
    cfg = SimConfig(network=network_profile("zero", 2), mode="async")
    with pytest.raises(ValueError, match="fused"):
        SimRuntime(logreg_loss, CommRule(kind="always"), 2, cfg,
                   optimizer=adam(lr=0.01))
