"""sim/report.py edge cases: empty loss series, never-settling runs,
final windows shorter than the smoothing window — and the additive-ledger
contract (``summarize`` grows ledger fields without touching any
pre-existing key)."""
import numpy as np
import pytest

from repro.obs import CommLedger
from repro.sim.report import (final_loss, smoothed_loss, summarize,
                              time_to_target)
from repro.sim.runtime import SimResult


def _result(losses, times=None, m=4, wall=None, ledger=None):
    losses = np.asarray(losses, np.float64)
    if times is None:
        times = np.arange(len(losses), dtype=np.float64)
    times = np.asarray(times, np.float64)
    wall = float(wall if wall is not None
                 else (times[-1] if len(times) else 0.0))
    return SimResult(
        mode="barrier", profile="zero", steps=len(losses), wall_s=wall,
        times=times, loss_times=times, losses=losses,
        uploads=len(losses), grad_evals=len(losses) * m,
        bytes_up=184.0 * len(losses), bytes_down=0.0,
        utilization=np.full(m, 0.5), max_staleness=0,
        final_params=None, ledger=ledger)


# ------------------------------------------------------------ edge cases

def test_time_to_target_empty_loss_series():
    """A run that recorded no losses (zero rounds) settles nowhere."""
    res = _result([])
    t, smooth = smoothed_loss(res)
    assert len(t) == 0 and len(smooth) == 0
    assert time_to_target(res, target_loss=0.5) is None


def test_time_to_target_never_settles():
    """Loss stuck above target for the whole run -> None, not a crash."""
    res = _result(np.linspace(2.0, 1.0, 40))
    assert time_to_target(res, target_loss=0.5) is None
    # and a transient dip below target must NOT claim it (suffix-max)
    dip = np.full(40, 2.0)
    dip[10] = 0.01
    assert time_to_target(_result(dip), target_loss=0.5) is None


def test_time_to_target_shorter_than_smoothing_window():
    """A final window shorter than the smoothing window clips the window
    to the series length instead of producing an empty convolution."""
    res = _result([0.4, 0.3, 0.2], m=4)   # default window = max(5, 2*4) = 8
    t, smooth = smoothed_loss(res)
    assert len(smooth) == 1               # one full-series mean
    np.testing.assert_allclose(smooth[0], np.mean([0.4, 0.3, 0.2]))
    ttt = time_to_target(res, target_loss=0.5)
    assert ttt == pytest.approx(2.0)      # settles at the window's end
    assert final_loss(res) == pytest.approx(np.mean([0.4, 0.3, 0.2]))


def test_single_observation_run():
    res = _result([0.1], times=[3.0], wall=3.0)
    t, smooth = smoothed_loss(res)
    assert len(smooth) == 1
    assert time_to_target(res, target_loss=0.5) == pytest.approx(3.0)


def test_summarize_handles_zero_wall():
    row = summarize(_result([], wall=0.0))
    assert row["steps_per_sim_sec"] is None
    assert row["final_loss"] is None      # not NaN — the JSON sinks choke
    assert row["steps"] == 0


# ------------------------------------------------- additive ledger fields

def test_summarize_ledger_fields_are_additive():
    """Every pre-ledger key is byte-identical with and without a ledger;
    the ledger only ADDS fields."""
    losses = np.linspace(1.0, 0.2, 30)
    led = CommLedger(rule="cada2", wire_format="dense")
    for k in range(30):
        led.observe_round({"uploads": 2, "bytes_up": 368.0,
                           "staleness": [0, 1, 0, 3]})
    led.observe_margin([0.5, -0.25], 0.1)
    led.observe_ring(np.array([0, 1, 1]), capacity=5)
    bare = summarize(_result(losses), target_loss=0.5)
    rich = summarize(_result(losses, ledger=led.summary()), target_loss=0.5)
    for key, val in bare.items():
        assert rich[key] == val, key      # byte-identical, not just close
    extra = set(rich) - set(bare)
    assert {"wire_format", "mbytes_up_dense", "mbytes_up_quantized",
            "mbytes_up_sparse", "staleness_hist", "gate_margin",
            "ring_occupancy", "ring_capacity"} <= extra
    assert rich["wire_format"] == "dense"
    assert rich["mbytes_up_quantized"] == 0.0
    assert rich["staleness_hist"] == {"0": 60, "1": 30, "3": 30}
    assert set(rich["gate_margin"]) == {"q10", "q50", "q90"}
