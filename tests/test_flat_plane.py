"""Flat-buffer state plane (core/flat.py): layout round-tripping, the
batched-LHS kernels vs the pytree oracles, flat quantization equivalence,
fused-vs-reference engine parity for every registered rule (Pallas kernels
exercised in interpret mode), and donation aliasing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import flat as F
from repro.core.comm import per_worker_sq_norm, strategy_for
from repro.core.quantize import per_worker_quantize_dequantize
from repro.core.rules import RULES, CommRule
from repro.kernels import cada_update as _cu
from repro.kernels import ops as kops


def _mixed_tree(rng, bf16=True):
    return {
        "w": jnp.asarray(rng.normal(size=(13, 7)), jnp.float32),
        "e": jnp.asarray(rng.normal(size=(5, 3, 2)),
                         jnp.bfloat16 if bf16 else jnp.float32),
        "b": jnp.asarray(rng.normal(size=(11,)), jnp.float32),
        "s": jnp.asarray(rng.normal(size=()), jnp.float32),
    }


# ------------------------------------------------------------------ layout

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans())
def test_pack_unpack_roundtrip_property(seed, bf16):
    """pack -> unpack is exact for every leaf (incl. bf16 storage), and
    the padded tail is identically zero."""
    rng = np.random.default_rng(seed)
    tree = _mixed_tree(rng, bf16=bf16)
    layout = F.layout_of(tree)
    buf = layout.pack(tree)
    assert buf.shape == (layout.n_flat,) and layout.n_flat % F.PAD_ALIGN == 0
    assert layout.n == sum(np.prod(l.shape, dtype=int)
                           for l in jax.tree.leaves(tree))
    np.testing.assert_array_equal(np.asarray(buf[layout.n:]), 0.0)
    back = layout.unpack(buf)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_worker_roundtrip(rng):
    m = 4
    tree = _mixed_tree(rng)
    wtree = jax.tree.map(
        lambda l: jnp.stack([l + i for i in range(m)]), tree)
    layout = F.layout_of(tree)
    plane = layout.pack_worker(wtree)
    assert plane.shape == (m, layout.n_flat)
    back = layout.unpack_worker(plane)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(wtree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_cast_roundtrip_matches_pack_of_unpack(rng):
    """cast_roundtrip(buf) == pack(unpack(buf)) — the invariant that keeps
    the engine's carried flat params consistent with the trainer's
    re-packed ones under reduced-precision leaves."""
    tree = _mixed_tree(rng, bf16=True)
    layout = F.layout_of(tree)
    buf = layout.pack(tree) + 1e-4  # perturb off exact bf16 values
    rt = layout.cast_roundtrip(buf)
    np.testing.assert_array_equal(
        np.asarray(rt[:layout.n]),
        np.asarray(layout.pack(layout.unpack(buf))[:layout.n]))
    # the padding tail passes through untouched
    np.testing.assert_array_equal(np.asarray(rt[layout.n:]),
                                  np.asarray(buf[layout.n:]))
    # all-fp32 layouts: a no-op (object identity — no ops inserted)
    t32 = _mixed_tree(rng, bf16=False)
    l32 = F.layout_of(t32)
    b32 = l32.pack(t32)
    assert l32.cast_roundtrip(b32) is b32


# --------------------------------------------------------- batched kernels

def test_batched_diff_sq_norm_kernel_vs_oracle(rng):
    """The batched one-pass Pallas kernel (interpret mode) computes all M
    per-worker ||a_m − b_m||² exactly like per_worker_sq_norm."""
    m, n = 3, 2 * _cu.BLOCK
    a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    got = _cu.batched_diff_sq_norm_flat(a, b, interpret=True)
    want = per_worker_sq_norm({"x": a - b})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    got1 = _cu.batched_sq_norm_flat(a, interpret=True)
    want1 = per_worker_sq_norm({"x": a})
    np.testing.assert_allclose(np.asarray(got1), np.asarray(want1),
                               rtol=1e-5)


def test_batched_wrappers_pad_arbitrary_widths(rng):
    """kernels/ops.py wrappers accept any flat width (satellite: no
    n % BLOCK restriction) on both the jnp and interpret-Pallas routes."""
    m, n = 4, 1234
    a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    want = np.sum((np.asarray(a) - np.asarray(b)) ** 2, axis=1)
    for interpret in (None, True):
        got = kops.batched_diff_sq_norm(a, b, interpret=interpret)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


def test_fused_amsgrad_flat_arbitrary_length(rng):
    """ops.fused_amsgrad_flat pads through to the kernel for any n —
    logreg-sized buffers take the fused route too (satellite 1)."""
    from repro.kernels import ref
    n = 777
    theta = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32)
    vhat = jnp.abs(jnp.asarray(rng.normal(size=n) * 0.01, jnp.float32))
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    want = ref.amsgrad_ref(theta, h, vhat, g, 0.01)
    for interpret in (None, True):
        got = kops.fused_amsgrad_flat(theta, h, vhat, g, 0.01,
                                      interpret=interpret)
        for a, b in zip(got, want):
            assert np.asarray(a).shape == np.asarray(b).shape
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- flat quantization

def test_flat_quantize_matches_pytree_quantize(rng):
    """Per-(worker, leaf-segment) scales on the flat plane are bit-equal
    to the pytree per-worker quantizer (the wire-format sync property)."""
    m = 3
    tree = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, 4)), jnp.float32)}
    layout = F.layout_of({"w": tree["w"][0], "b": tree["b"][0]})
    plane = layout.pack_worker(tree)
    for bits in (2, 4, 8):
        q_flat = F.per_worker_quantize_dequantize_flat(layout, plane, bits)
        q_tree = per_worker_quantize_dequantize(tree, bits)
        np.testing.assert_array_equal(
            np.asarray(q_flat), np.asarray(layout.pack_worker(q_tree)))
    # padded tail survives untouched
    np.testing.assert_array_equal(
        np.asarray(F.per_worker_quantize_dequantize_flat(
            layout, plane, 4)[:, layout.n:]),
        np.asarray(plane[:, layout.n:]))


def test_flat_topk_matches_pytree_topk(rng):
    """Per-(worker, leaf-segment) top-k on the flat plane is bit-equal to
    the pytree sparsifier (same threshold rule over the same entries),
    and the padded tail passes through untouched."""
    from repro.core.quantize import per_worker_topk_sparsify
    m = 3
    tree = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(m, 4)), jnp.float32)}
    layout = F.layout_of({"w": tree["w"][0], "b": tree["b"][0]})
    plane = layout.pack_worker(tree)
    for frac in (0.1, 0.5, 1.0):
        s_flat = F.per_worker_topk_sparsify_flat(layout, plane, frac)
        s_tree = per_worker_topk_sparsify(tree, frac)
        np.testing.assert_array_equal(
            np.asarray(s_flat), np.asarray(layout.pack_worker(s_tree)))
    np.testing.assert_array_equal(
        np.asarray(F.per_worker_topk_sparsify_flat(
            layout, plane, 0.25)[:, layout.n:]),
        np.asarray(plane[:, layout.n:]))


# ------------------------------------- fused vs reference engine parity

def _small_problem(m):
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.core.engine import make_sampler
    from repro.models.small import logreg_init, logreg_loss
    ds = ijcnn1_like(n=400)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 16)
    return logreg_loss, logreg_init(None, 22, 2), sample


@pytest.mark.parametrize("kind", RULES)
def test_fused_engine_matches_reference_engine(kind):
    """The flat-plane hot path and the per-leaf reference implementation
    of Algorithm 1 agree per iteration for EVERY registered rule — masks
    exactly, parameters numerically — with the Pallas kernels running in
    interpret mode on the fused side."""
    from repro.core.engine import CADAEngine
    from repro.optim.fused import FusedAMSGrad
    m, steps = 3, 8
    loss_fn, params, sample = _small_problem(m)
    # c chosen so adaptive rules produce a MIXED mask over the run
    rule = CommRule(kind=kind, c=5.0, d_max=4, max_delay=6)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(2),
                                                steps))
    eng_f = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m,
                       interpret=True)
    eng_r = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m,
                       fused=False)
    stf, mf = jax.jit(eng_f.run)(eng_f.init(params), batches)
    str_, mr = jax.jit(eng_r.run)(eng_r.init(params), batches)
    np.testing.assert_array_equal(np.asarray(mf["upload_mask"]),
                                  np.asarray(mr["upload_mask"]))
    np.testing.assert_array_equal(np.asarray(mf["staleness"]),
                                  np.asarray(mr["staleness"]))
    np.testing.assert_allclose(np.asarray(mf["bytes_up"]),
                               np.asarray(mr["bytes_up"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(stf.params),
                    jax.tree.leaves(str_.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-6)


def test_fused_engine_mixed_mask_meta():
    """Meta-check: the parity setup above exercises BOTH branches (uploads
    and skips) for cada2 — all-upload trajectories would prove less."""
    from repro.core.engine import CADAEngine
    from repro.optim.fused import FusedAMSGrad
    m, steps = 3, 8
    loss_fn, params, sample = _small_problem(m)
    rule = CommRule(kind="cada2", c=5.0, d_max=4, max_delay=6)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(2),
                                                steps))
    eng = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m)
    _, mets = jax.jit(eng.run)(eng.init(params), batches)
    total = int(np.asarray(mets["uploads"]).sum())
    assert 0 < total < steps * m, total


# ---------------------------------------------------------------- donation

def test_donated_engine_state_aliases():
    """donate_argnums on the jitted run actually aliases the state buffers
    (verified on the compiled module — a donated-but-copied state would
    show zero aliases), and the undonated version shows none for the
    matching param buffers only."""
    from repro.core.engine import CADAEngine
    from repro.optim.fused import FusedAMSGrad
    from repro.utils.hlo_cost import donation_aliases
    m, steps = 3, 4
    loss_fn, params, sample = _small_problem(m)
    rule = CommRule(kind="cada2", c=0.6, d_max=4, max_delay=6)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(0),
                                                steps))
    eng = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m)
    st = eng.init(params)
    donated = jax.jit(eng.run, donate_argnums=(0,)).lower(
        st, batches).compile()
    assert donation_aliases(donated.as_text()) > 0
    plain = jax.jit(eng.run).lower(st, batches).compile()
    assert donation_aliases(plain.as_text()) == 0
    # the donated executable still runs and matches the plain one
    out_d, _ = donated(jax.tree.map(lambda x: x.copy(), st), batches)
    out_p, _ = plain(st, batches)
    for a, b in zip(jax.tree.leaves(out_d.params),
                    jax.tree.leaves(out_p.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ------------------------------------------------------- sharded layout

needs_mesh8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI mesh matrix leg)")


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_layout_shards_pad_and_roundtrip(rng, shards):
    """n_flat pads to a multiple of align·shards; per-shard split/merge
    and pack/unpack round-trip bit-exactly; the true entries are invariant
    to the shard count (only the padding tail moves)."""
    tree = _mixed_tree(rng)
    lay = F.layout_of(tree, shards=shards)
    assert lay.shards == shards
    assert lay.n_flat % (F.PAD_ALIGN * shards) == 0
    assert lay.shard_len * shards == lay.n_flat
    buf = lay.pack(tree)
    np.testing.assert_array_equal(
        np.asarray(lay.shard_merge(lay.shard_split(buf))), np.asarray(buf))
    back = lay.unpack(buf)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # shard-count independence of the true prefix
    base = F.layout_of(tree)
    np.testing.assert_array_equal(np.asarray(buf[:lay.n]),
                                  np.asarray(base.pack(tree)[:base.n]))
    # (M, n_flat) planes split the same way
    wtree = jax.tree.map(lambda l: jnp.stack([l, l + 1]), tree)
    plane = lay.pack_worker(wtree)
    np.testing.assert_array_equal(
        np.asarray(lay.shard_merge(lay.shard_split(plane))),
        np.asarray(plane))


def test_spec_dim():
    from jax.sharding import PartitionSpec as P
    assert F.spec_dim(()) is None
    assert F.spec_dim(("data",)) == "data"
    assert F.spec_dim(("data", "pod")) == ("data", "pod")
    assert P(F.spec_dim(("data",))) == P("data")


def test_fused_amsgrad_bf16_moments_matches_per_leaf_reference(rng):
    """Dtype-parametric moments: the fused kernel (jnp fallback AND
    interpret-mode Pallas) with bf16 {h, v̂} matches the per-leaf reference
    stream's dtype discipline — the STORED (rounded) moment drives the
    update."""
    from repro.distributed.trainer import TrainHParams, _amsgrad_apply
    n = 700
    theta = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.normal(size=n) * 0.1, jnp.bfloat16)
    vhat = jnp.abs(jnp.asarray(rng.normal(size=n) * 0.01, jnp.bfloat16))
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    hp = TrainHParams(lr=0.01, moments_dtype="bfloat16")
    want_p, want_h, want_v, want_sq = _amsgrad_apply(
        {"x": theta}, {"x": h}, {"x": vhat}, {"x": g}, hp)
    for interpret in (None, True):
        t2, h2, v2, sq = kops.fused_amsgrad_flat(theta, h, vhat, g, 0.01,
                                                 interpret=interpret)
        assert h2.dtype == jnp.bfloat16 and v2.dtype == jnp.bfloat16
        # θ to 1-2 ulp (separately-jitted programs fuse the update stream
        # differently); the STORED moments must round identically
        np.testing.assert_allclose(np.asarray(t2),
                                   np.asarray(want_p["x"]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(h2, np.float32),
                                      np.asarray(want_h["x"], np.float32))
        np.testing.assert_array_equal(np.asarray(v2, np.float32),
                                      np.asarray(want_v["x"], np.float32))
        np.testing.assert_allclose(float(sq), float(want_sq), rtol=1e-6)


# --------------------------------------------------- shard-local kernels

def _mesh_shard(shape, axes, waxis, saxes):
    from repro.distributed.sharding import FlatSharding
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh(shape, axes)
    return mesh, FlatSharding(mesh=mesh, waxis=waxis, axes=saxes)


@needs_mesh8
def test_sharded_fused_update_matches_unsharded(rng):
    """The shard_map'd fused update (each device one n_flat/S slice, one
    psum'd ‖Δθ‖²) equals the whole-plane form."""
    mesh, shard = _mesh_shard((8, 1), ("data", "model"), "data", ("data",))
    n = 8 * 32
    theta = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32)
    vhat = jnp.abs(jnp.asarray(rng.normal(size=n) * 0.01, jnp.float32))
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    want = kops.fused_amsgrad_flat(theta, h, vhat, g, 0.01)
    got = jax.jit(lambda *a: kops.fused_amsgrad_flat(
        *a, 0.01, shard=shard))(theta, h, vhat, g)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


@needs_mesh8
@pytest.mark.parametrize("mesh_kind", ["rows", "rows_and_cols"])
def test_sharded_batched_norms_match_oracle(rng, mesh_kind):
    """The shard-local batched LHS forms (manual over worker rows, psum
    over column shards) equal the plain whole-plane kernels — on a
    worker-axis-only mesh and on a pod×data mesh where the flat dim itself
    shards (the pod-mesh layout)."""
    if mesh_kind == "rows":
        mesh, shard = _mesh_shard((8, 1), ("data", "model"), "data",
                                  ("data",))
        m = 8
    else:
        mesh, shard = _mesh_shard((2, 4), ("pod", "data"), "pod",
                                  ("data",))
        m = 2
    n = 4 * 24
    a = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(jax.jit(lambda x, y: kops.batched_diff_sq_norm(
            x, y, shard=shard))(a, b)),
        np.asarray(kops.batched_diff_sq_norm(a, b)), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(jax.jit(lambda x: kops.batched_sq_norm(
            x, shard=shard))(a)),
        np.asarray(kops.batched_sq_norm(a)), rtol=1e-5)


# -------------------------------------------------------- sparse topk wire

def test_topk_sparse_wire_extract_scatter_roundtrip(rng):
    """(values, indices) extraction from a sparsified plane reconstructs
    it bit-exactly (tie-free data), padding tail untouched."""
    tree = {"w": jnp.zeros((6, 2), jnp.float32),
            "b": jnp.zeros((4,), jnp.float32)}
    layout = F.layout_of(tree)
    plane = jnp.asarray(rng.normal(size=(3, layout.n_flat)), jnp.float32)
    sp = F.per_worker_topk_sparsify_flat(layout, plane, 0.3)
    vals, idx = F.per_worker_topk_extract_flat(layout, sp, 0.3)
    dense = F.sparse_rows_to_dense(idx, vals, layout.n_flat)
    np.testing.assert_array_equal(np.asarray(dense[:, :layout.n]),
                                  np.asarray(sp[:, :layout.n]))
    # fixed payload size: K = Σ_seg ⌈frac·s⌉
    from repro.core.quantize import topk_count
    K = sum(topk_count(s, 0.3) for s in layout.sizes)
    assert vals.shape == idx.shape == (3, K)


def test_topk_sparse_wire_parity_with_dense(rng):
    """Satellite gate: the topk rule with ``sparse_wire=True`` (the (v, i)
    pairs ride the simulated collective) reproduces the dense-wire run
    bit-exactly — identical masks, staleness, bytes, and parameters."""
    from repro.core.engine import CADAEngine
    from repro.optim.fused import FusedAMSGrad
    m, steps = 3, 8
    loss_fn, params, sample = _small_problem(m)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(2),
                                                steps))
    runs = {}
    for sparse in (False, True):
        rule = CommRule(kind="topk", c=5.0, d_max=4, max_delay=6,
                        topk_frac=0.25, sparse_wire=sparse)
        eng = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m)
        runs[sparse] = jax.jit(eng.run)(eng.init(params), batches)
    std, md = runs[False]
    sts, ms = runs[True]
    np.testing.assert_array_equal(np.asarray(ms["upload_mask"]),
                                  np.asarray(md["upload_mask"]))
    np.testing.assert_array_equal(np.asarray(ms["staleness"]),
                                  np.asarray(md["staleness"]))
    np.testing.assert_allclose(np.asarray(ms["bytes_up"]),
                               np.asarray(md["bytes_up"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(sts.params),
                    jax.tree.leaves(std.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_avp_compose_gates_on_energy(rng):
    """Satellite: ``avp_compose`` composes the period gate with the CADA
    LHS. Pointwise the composed gate is a SUBSET of the plain one
    (the energy check can only veto), so up to and including the FIRST
    iteration where the two trajectories' masks differ, composed ⊆
    plain — after that the states diverge and no global ordering holds.
    The max-staleness cap still forces uploads."""
    from repro.core.engine import CADAEngine
    from repro.optim.fused import FusedAMSGrad
    m, steps = 3, 10
    loss_fn, params, sample = _small_problem(m)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(4),
                                                steps))
    mets = {}
    for compose in (False, True):
        rule = CommRule(kind="avp", c=5.0, d_max=4, max_delay=6,
                        period_min=2, period_max=4, avp_compose=compose)
        eng = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m)
        _, mets[compose] = jax.jit(eng.run)(eng.init(params), batches)
    plain = np.asarray(mets[False]["upload_mask"])
    comp = np.asarray(mets[True]["upload_mask"])
    for i in range(steps):
        assert not (comp[i] & ~plain[i]).any(), \
            f"composed gate added an upload at iteration {i}"
        if not np.array_equal(comp[i], plain[i]):
            break  # trajectories diverge from here on
    else:
        pytest.fail("compose never vetoed an upload — the test setup "
                    "does not exercise the composition")
    assert int(comp.sum()) > 0  # the staleness cap still forces uploads
    # flat plane and pytree reference agree on the composed gate too
    rule = CommRule(kind="avp", c=5.0, d_max=4, max_delay=6,
                    period_min=2, period_max=4, avp_compose=True)
    eng_r = CADAEngine(loss_fn, FusedAMSGrad(lr=0.05), rule, m,
                       fused=False)
    _, mr = jax.jit(eng_r.run)(eng_r.init(params), batches)
    np.testing.assert_array_equal(np.asarray(mets[True]["upload_mask"]),
                                  np.asarray(mr["upload_mask"]))


def test_donated_trainer_step_aliases():
    """The trainer's jitted step with donated state aliases too (the
    launch/train.py and benchmarks/run.py hot loops)."""
    import repro.configs as C
    from repro.distributed.trainer import (TrainHParams, init_train_state,
                                           make_train_step, worker_split)
    from repro.utils.hlo_cost import donation_aliases
    cfg = C.get_smoke_config("stablelm-1.6b")
    hp = TrainHParams(rule=CommRule(kind="cada2", c=0.5, d_max=4,
                                    max_delay=10), lr=1e-3)
    m = 2
    step = jax.jit(make_train_step(cfg, hp, m), donate_argnums=(0,))
    st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                      cfg.vocab)}, m)
    compiled = step.lower(st, batch).compile()
    assert donation_aliases(compiled.as_text()) > 0
