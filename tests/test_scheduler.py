"""Continuous-batching decode scheduler: request lifecycle, EOS, padding
correctness vs single-request decode."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.distributed.scheduler import DecodeScheduler, Request
from repro.models.model import decode_step, init_params, prefill


def _setup(n_slots=2, max_seq=64):
    cfg = C.get_smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, DecodeScheduler(cfg, params, n_slots=n_slots,
                                        max_seq=max_seq)


def test_all_requests_complete():
    cfg, params, sched = _setup(n_slots=2)
    rng = np.random.default_rng(0)
    for uid in range(5):
        sched.submit(Request(uid=uid,
                             prompt=rng.integers(0, cfg.vocab, 8,
                                                 dtype=np.int32),
                             max_new=6))
    done = sched.run()
    assert len(done) == 5
    assert all(r.done and len(r.out) == 6 for r in done)


def test_eos_stops_early():
    cfg, params, sched = _setup(n_slots=1)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 8, dtype=np.int32)
    # find what the model greedily emits first, then use it as EOS
    probe = Request(uid=0, prompt=prompt.copy(), max_new=1)
    sched.submit(probe)
    sched.run_round()
    first = probe.out[0]
    req = Request(uid=1, prompt=prompt.copy(), max_new=16, eos_id=first)
    sched.submit(req)
    sched.run_round()
    assert req.out[0] == first and len(req.out) == 1


def test_scheduler_matches_unbatched_decode():
    """A request served in a mixed batch produces the same tokens as the
    same request decoded alone (padding/slot isolation)."""
    cfg, params, sched = _setup(n_slots=2, max_seq=64)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab, 10, dtype=np.int32)
    p2 = rng.integers(0, cfg.vocab, 10, dtype=np.int32)  # same length
    r1 = Request(uid=0, prompt=p1, max_new=5)
    r2 = Request(uid=1, prompt=p2, max_new=5)
    sched.submit(r1)
    sched.submit(r2)
    sched.run()

    # reference: decode r1 alone
    logits, cache = jax.jit(lambda p, t: prefill(cfg, p, tokens=t,
                                                 max_seq=64))(
        params, jnp.asarray(p1)[None])
    outs = []
    nxt = jnp.argmax(logits, axis=-1)
    for _ in range(5):
        outs.append(int(nxt[0]))
        logits, cache = decode_step(cfg, params, cache, tokens=nxt)
        nxt = jnp.argmax(logits, axis=-1)
    assert r1.out == outs
