"""The cohort-virtualized worker plane (core/flat.py::flat_cohort_round).

The contract under test: a cohort round — only the C sampled workers'
rows on device, gathered from the host WorkerPool, computed as a
(C, n_flat) plane, scattered back, with the server holding only the
INCREMENTAL eq. (3) aggregate — is BIT-EXACT against the dense plane run
with ``participation`` = the cohort indicator mask, for every registered
rule. The order-fixed row accumulation (``kops.eq3_row_mean``) is what
makes the aggregate exact: masked zero rows are IEEE-754 no-ops, so the
dense masked mean and the C-row cohort sum agree bit-for-bit in fp32.

Also here: the pool gather/scatter round-trip property (bf16 planes and
error-feedback residuals included), the ``resum_every`` drift guard, the
pool checkpoint reshard round-trip, and the M=10⁴ federated smoke the CI
``federated-smoke`` leg runs under the 6 GiB cap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, flat as F
from repro.core.engine import (CADAEngine, cohorts_to_participation,
                               make_cohort_sampler, make_sampler,
                               sample_cohorts)
from repro.core.rules import RULES, CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss, mlp_init, mlp_loss
from repro.optim.fused import FusedAMSGrad

M = 8
C = 3
STEPS = 24

ARMS = RULES + ("topk_sparse",)


def _rule(kind):
    if kind == "topk_sparse":
        return CommRule(kind="topk", c=5.0, d_max=4, max_delay=6,
                        topk_frac=0.5, sparse_wire=True)
    kw = dict(kind=kind, c=5.0, d_max=4, max_delay=6)
    if kind == "topk":
        kw["topk_frac"] = 0.5
    if kind == "avp":
        kw.update(period_min=1, period_max=4)
    return CommRule(**kw)


def _problem(m=M, steps=STEPS, seed=2, n=400, batch=8):
    ds = ijcnn1_like(n=n)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, batch)
    params = logreg_init(None, 22, 2)
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(seed), steps))
    return params, batches


def _dense_run(rule, params, batches, pmasks, m=M):
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, m)
    st, mets = jax.jit(eng.run)(eng.init(params), batches,
                                jnp.asarray(pmasks))
    return st, mets


def _cohort_run(rule, params, batches, cohorts, m=M, resum_every=0):
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, m,
                     resum_every=resum_every)
    st, pool = eng.init_cohort(params)
    cohort_batches = [jax.tree.map(lambda x: x[i][cohorts[i]], batches)
                      for i in range(cohorts.shape[0])]
    st, mets = eng.run_cohort(st, pool, cohort_batches, cohorts)
    return st, pool, mets, eng


def _assert_cohort_parity(st_c, pool, mets_c, st_d, mets_d, cohorts, rule,
                          what):
    dm = np.asarray(mets_d["upload_mask"])            # (steps, M)
    for i, mm in enumerate(mets_c):
        np.testing.assert_array_equal(
            np.asarray(mm["upload_mask"]), dm[i, cohorts[i]],
            err_msg=f"{what}: round {i} masks diverged")
        off = np.ones(dm.shape[1], bool)
        off[cohorts[i]] = False
        assert not dm[i, off].any(), \
            f"{what}: dense oracle uploaded outside the cohort at round {i}"
    np.testing.assert_array_equal(
        np.asarray(st_c.server.staleness), np.asarray(st_d.comm.staleness),
        err_msg=f"{what}: staleness diverged")
    # satellite: the INCREMENTAL aggregate is bit-exact fp32 vs the
    # dense-plane masked mean, accumulated over every round
    np.testing.assert_array_equal(
        np.asarray(st_c.server.nabla), np.asarray(st_d.comm.nabla),
        err_msg=f"{what}: incremental nabla diverged from dense masked mean")
    np.testing.assert_array_equal(
        pool.planes["worker_grads"], np.asarray(st_d.comm.worker_grads),
        err_msg=f"{what}: pooled worker_grads diverged")
    np.testing.assert_array_equal(
        np.asarray(st_c.server.diff_hist), np.asarray(st_d.comm.diff_hist),
        err_msg=f"{what}: diff_hist diverged")
    for a, b in zip(jax.tree.leaves(st_c.params),
                    jax.tree.leaves(st_d.params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=f"{what}: params diverged")
    # pooled planes vs the dense extras; server extras vs the dense extras
    strat = comm.strategy_for(rule)
    pooled = strat.pooled_extras()
    for name in pooled:
        np.testing.assert_array_equal(
            pool.planes[name], np.asarray(st_d.comm.extras[name]),
            err_msg=f"{what}: pooled extras[{name}] diverged")
    for name, val in st_c.server.extras.items():
        for a, b in zip(jax.tree.leaves(val),
                        jax.tree.leaves(st_d.comm.extras[name])):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                err_msg=f"{what}: server extras[{name}] diverged")


# -------------------------------------------- cohort vs dense (all rules)

@pytest.mark.parametrize("kind", ARMS)
def test_cohort_matches_dense_all_rules(kind):
    """The acceptance gate: cohort plane vs dense plane + participation,
    bit-exact masks/staleness/params/nabla/worker_grads/extras, for every
    registered rule (+ the true-sparse topk wire)."""
    rule = _rule(kind)
    params, batches = _problem()
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    pmasks = cohorts_to_participation(cohorts, M)
    st_d, m_d = _dense_run(rule, params, batches, pmasks)
    st_c, pool, m_c, _ = _cohort_run(rule, params, batches, cohorts)
    _assert_cohort_parity(st_c, pool, m_c, st_d, m_d, cohorts, rule,
                          f"cohort {kind}")


def test_cohort_masks_are_mixed_meta():
    """Meta-check: the cada2 parity run exercises both upload branches."""
    rule = _rule("cada2")
    params, batches = _problem()
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    _, _, mets, _ = _cohort_run(rule, params, batches, cohorts)
    total = int(sum(np.asarray(m["uploads"]) for m in mets))
    assert 0 < total < STEPS * C, total


# ------------------------------------- incremental ∇̄ property (200 rounds)

@pytest.mark.parametrize("kind", RULES)
def test_incremental_nabla_bit_exact_200_rounds(kind):
    """Satellite: ∇̄ += Σ_cohort δ_m / M accumulated over 200 partial-
    participation rounds lands bit-exactly on the dense plane's masked
    mean — no drift guard needed for exactness, only for fp headroom."""
    steps = 200
    rule = _rule(kind)
    params, batches = _problem(steps=steps, n=240, batch=4)
    cohorts = sample_cohorts(M, C, steps, seed=11)
    pmasks = cohorts_to_participation(cohorts, M)
    st_d, _ = _dense_run(rule, params, batches, pmasks)
    st_c, pool, _, _ = _cohort_run(rule, params, batches, cohorts)
    np.testing.assert_array_equal(
        np.asarray(st_c.server.nabla), np.asarray(st_d.comm.nabla),
        err_msg=f"{kind}: incremental nabla drifted within 200 rounds")
    np.testing.assert_array_equal(
        pool.planes["worker_grads"], np.asarray(st_d.comm.worker_grads))
    for a, b in zip(jax.tree.leaves(st_c.params),
                    jax.tree.leaves(st_d.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# -------------------------------------------------- pool round-trip property

@pytest.mark.parametrize("dtype", (np.float32, jnp.bfloat16),
                         ids=("f32", "bf16"))
def test_pool_gather_scatter_roundtrip(dtype):
    """pool → (C, n_flat) → pool is bit-exact, residual planes and bf16
    storage included; non-cohort rows are never touched."""
    rng = np.random.default_rng(0)
    m, n_flat = 32, 48
    dt = np.dtype(dtype)
    planes = {
        "worker_grads": rng.normal(size=(m, n_flat)).astype(dt),
        "residual": rng.normal(size=(m, n_flat)).astype(dt),
    }
    pool = F.WorkerPool({k: v.copy() for k, v in planes.items()})
    cohort = np.sort(rng.choice(m, 7, replace=False)).astype(np.int32)

    rows = pool.gather(cohort)
    for name in planes:
        assert rows[name].shape == (7, n_flat)
        np.testing.assert_array_equal(np.asarray(rows[name]),
                                      planes[name][cohort])
    # identity scatter: the whole pool is bit-identical
    pool.scatter(cohort, rows)
    for name in planes:
        np.testing.assert_array_equal(pool.planes[name], planes[name])
    # real update: cohort rows take the new values, others untouched
    new_rows = {name: jnp.asarray(rng.normal(size=(7, n_flat)),
                                  dtype=rows[name].dtype)
                for name in planes}
    pool.scatter(cohort, new_rows)
    off = np.setdiff1d(np.arange(m), cohort)
    for name in planes:
        np.testing.assert_array_equal(pool.planes[name][cohort],
                                      np.asarray(new_rows[name]))
        np.testing.assert_array_equal(pool.planes[name][off],
                                      planes[name][off])


def test_pool_split_per_rule():
    """Which state lands where: O(M·n) planes pool, everything else stays
    on device — and error_feedback=False pools no residual at all."""
    params = logreg_init(None, 22, 2)
    lay = F.layout_of(params)
    want_pool = {"always": set(), "lag": set(), "cada2": set(),
                 "cinn": set(), "avp": set(),
                 "cada1": {"worker_delta"}, "laq": {"residual"},
                 "topk": {"residual"}}
    for kind, extra in want_pool.items():
        strat = comm.strategy_for(_rule(kind))
        server, pool = F.init_cohort_state(strat, lay, params, M)
        assert set(pool.planes) == {"worker_grads"} | extra, kind
        for name, val in server.extras.items():
            for leaf in jax.tree.leaves(val):
                assert leaf.shape[:2] != (M, lay.n_flat), (kind, name)
    strat = comm.strategy_for(CommRule(kind="laq", error_feedback=False))
    _, pool = F.init_cohort_state(strat, lay, params, M)
    assert set(pool.planes) == {"worker_grads"}


# ------------------------------------------------------------- drift guard

def test_drift_guard_resum():
    """``resum_every``: after a guard round the server aggregate equals
    the fp64 pool mean exactly (the invariant the guard restores), and
    the unguarded incremental aggregate sits within fp32 rounding of
    that invariant (what makes the guard a no-op in exact arithmetic —
    the correction it applies is pure accumulated rounding noise, so a
    trajectory-level comparison would only measure chaos)."""
    rule = _rule("cada2")
    params, batches = _problem(steps=20)
    cohorts = sample_cohorts(M, C, 20, seed=3)
    st_g, pool_g, mets_g, _ = _cohort_run(rule, params, batches, cohorts,
                                          resum_every=5)
    np.testing.assert_array_equal(np.asarray(st_g.server.nabla),
                                  pool_g.resum_nabla())
    assert np.isfinite(np.asarray([m["loss"] for m in mets_g])).all()
    st_u, pool_u, _, _ = _cohort_run(rule, params, batches, cohorts)
    incr = np.asarray(st_u.server.nabla, np.float64)
    true = pool_u.resum_nabla().astype(np.float64)
    # accumulated fp32 rounding over 20 rounds of O(1e-1) wire addends
    # lands around 4e-8 here (deterministic seeds); 1e-6 is ~25x headroom
    # while still catching any real aggregation bug (those are O(addend))
    assert float(np.max(np.abs(incr - true))) < 1e-6


# ------------------------------------------------- checkpoint round-trip

def test_pool_checkpoint_reshard_roundtrip(tmp_path):
    """The pool's (M, n_flat) numpy planes ride checkpoint/io.py as
    ordinary flat worker planes: restoring into a template cut for a
    different shard count re-pads the flat axis, true entries bit-exact."""
    import repro.checkpoint.io as ckpt
    params = logreg_init(None, 22, 2)
    lay_src = F.layout_of(params)
    lay_dst = F.layout_of(params, shards=16)
    assert lay_src.n_flat != lay_dst.n_flat
    rng = np.random.default_rng(1)
    strat = comm.strategy_for(_rule("laq"))
    _, pool = F.init_cohort_state(strat, lay_src, params, M)
    for name in pool.planes:
        pool.planes[name][:, :lay_src.n] = rng.normal(
            size=(M, lay_src.n)).astype(np.float32)
    ckpt.save(str(tmp_path / "pool"), {"pool": pool.state_dict()}, step=3,
              flat_meta=lay_src)
    template = {"pool": {name: np.zeros((M, lay_dst.n_flat), np.float32)
                         for name in pool.planes}}
    restored, step_no = ckpt.restore(str(tmp_path / "pool"), template)
    assert step_no == 3
    _, pool2 = F.init_cohort_state(strat, lay_dst, params, M)
    pool2.load_state_dict(restored["pool"])
    for name in pool.planes:
        got = pool2.planes[name]
        assert got.shape == (M, lay_dst.n_flat)
        np.testing.assert_array_equal(got[:, :lay_src.n],
                                      pool.planes[name][:, :lay_src.n])
        np.testing.assert_array_equal(got[:, lay_src.n:], 0.0)


# ------------------------------------------- federated smoke (CI leg)

def test_federated_smoke_m_10k_cohort():
    """The federated-magnitude smoke the CI ``federated-smoke`` leg runs
    under ulimit -v 6 GiB: M=10⁴ workers, C=64 cohort, the MLP problem —
    impossible on the dense plane under the cap (the (steps, M, b, ·)
    batch plane alone is ~8.4 GB at 300 steps), routine on the cohort
    plane. Device worker-plane bytes must scale with C, not M."""
    m, c, rounds = 10_000, 64, 6
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100)
    ds = ijcnn1_like(n=20_000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_cohort_sampler(ds.x, ds.y, mtx, 32)
    params = mlp_init(jax.random.PRNGKey(7), 22, 64, 2)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.05), rule, m)
    st, pool = eng.init_cohort(params)

    n_flat = eng._layout.n_flat
    # the O(C·n) vs O(M·n) split, as numbers
    assert pool.nbytes == m * n_flat * 4                 # host side
    assert pool.device_row_bytes(c) == c * n_flat * 4    # device side
    assert pool.device_row_bytes(c) * (m // c) <= pool.nbytes
    # nothing O(M·n) on device: server extras + state are ring/scalars
    for leaf in jax.tree.leaves((st.server, st.opt_state, st.params_flat)):
        assert not (leaf.ndim >= 2 and leaf.shape[0] == m and
                    leaf.shape[-1] == n_flat), leaf.shape

    cohorts = sample_cohorts(m, c, rounds, seed=0)
    mets = []
    for i in range(rounds):
        batch = sample(jax.random.PRNGKey(200 + i), jnp.asarray(cohorts[i]))
        st, mm = eng.step_cohort(st, pool, batch, cohorts[i])
        mets.append(mm)
    losses = np.asarray([m_["loss"] for m_ in mets])
    assert np.isfinite(losses).all()
    assert int(sum(np.asarray(m_["uploads"]) for m_ in mets)) > 0
    # round 0 cohort force-uploads (staleness starts at the cap)
    assert int(np.asarray(mets[0]["uploads"])) == c
