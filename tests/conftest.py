"""Shared fixtures + a deterministic ``hypothesis`` fallback.

NOTE: no XLA_FLAGS here — tests run on the 1 real CPU device; only
launch/dryrun.py fakes 512 devices (task contract).

``hypothesis`` is an optional dependency: when it is not installed (the
pinned CI image has no network), a tiny seeded-random parameter-sweep
shim is installed under the same import name BEFORE the test modules
import it. The shim draws ``max_examples`` pseudo-random examples from a
per-test seed derived from the test's qualified name, so sweeps are
deterministic across runs and machines. It covers exactly the API this
suite uses: ``given``, ``settings``, and the ``integers`` / ``floats`` /
``sampled_from`` / ``lists`` strategies.
"""
import functools
import inspect
import random
import sys
import types
import zlib

import jax
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        """A strategy is just a seeded draw function."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd):
            return self._draw(rnd)

    def _integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def _floats(min_value, max_value, allow_nan=False, allow_infinity=False):
        del allow_nan, allow_infinity  # bounded draws are always finite
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rnd: rnd.choice(elements))

    def _booleans():
        return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 8

        def draw(rnd):
            return [elements.example(rnd)
                    for _ in range(rnd.randint(min_size, hi))]

        return _Strategy(draw)

    def _given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples",
                            _DEFAULT_EXAMPLES)
                rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    args = [s.example(rnd) for s in arg_strategies]
                    kwargs = {k: s.example(rnd)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # hide the strategy parameters from pytest's fixture resolver
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return decorate

    def _settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_):
        del deadline

        def decorate(fn):
            fn._stub_max_examples = max_examples
            return fn

        return decorate

    def _assume(condition):
        if not condition:
            raise pytest.skip.Exception("assumption failed",
                                        _use_item_location=True)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.lists = _lists
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
