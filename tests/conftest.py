"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device; only launch/dryrun.py fakes 512 devices (task contract)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
