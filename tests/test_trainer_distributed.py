"""Distributed (hierarchical-CADA) trainer: step semantics on the host mesh,
rule equivalences, microbatch invariance, spec plumbing, local-update
baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.core.local_update import LocalUpdateEngine
from repro.core.rules import CommRule
from repro.distributed.trainer import (
    DistTrainState, TrainHParams, init_train_state, jit_train_step,
    make_train_step, train_state_specs, worker_split, worker_split_abstract,
)
from repro.launch.mesh import make_host_mesh, set_mesh

CFG = C.get_smoke_config("internlm2-1.8b")


def _batch(key, b=8, s=32):
    return {"tokens": jax.random.randint(key, (b, s + 1), 0, CFG.vocab)}


def _steps(kind, n=4, m=4, microbatches=1, c=0.5, seed=0, lr=1e-3):
    hp = TrainHParams(rule=CommRule(kind=kind, c=c, d_max=4, max_delay=10),
                      lr=lr, microbatches=microbatches)
    step = make_train_step(CFG, hp, m)
    st = init_train_state(CFG, hp, m, jax.random.PRNGKey(42))
    step = jax.jit(step)
    outs = []
    for i in range(n):
        batch = worker_split(_batch(jax.random.PRNGKey(seed + i)), m)
        st, mets = step(st, batch)
        outs.append(mets)
    return st, outs


@pytest.mark.parametrize("kind", ["always", "cada1", "cada2", "lag", "cinn"])
def test_step_runs_and_loss_finite(kind):
    st, outs = _steps(kind, n=3)
    for m in outs:
        assert np.isfinite(float(m["loss"]))
    assert int(st.step) == 3


def test_cada2_c0_equals_always():
    """c=0 ⇒ every pod uploads ⇒ trajectory == distributed AMSGrad."""
    st_c, _ = _steps("cada2", n=3, c=0.0)
    st_a, _ = _steps("always", n=3, c=0.0)
    for a, b in zip(jax.tree.leaves(st_c.params),
                    jax.tree.leaves(st_a.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_microbatch_invariance():
    """Gradient accumulation must not change the trajectory (same data)."""
    st1, _ = _steps("always", n=2, microbatches=1)
    st2, _ = _steps("always", n=2, microbatches=2)
    for a, b in zip(jax.tree.leaves(st1.params),
                    jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_huge_c_skips_everything_after_warmup():
    hp = TrainHParams(rule=CommRule(kind="cada2", c=1e12, d_max=4,
                                    max_delay=100))
    m = 4
    step = jax.jit(make_train_step(CFG, hp, m))
    st = init_train_state(CFG, hp, m, jax.random.PRNGKey(0))
    st, mets0 = step(st, worker_split(_batch(jax.random.PRNGKey(1)), m))
    assert int(mets0["uploads"]) == m  # staleness init forces round 0
    st, mets1 = step(st, worker_split(_batch(jax.random.PRNGKey(2)), m))
    assert int(mets1["uploads"]) == 0
    assert float(mets1["skip_rate"]) == 1.0


def test_worker_split_shapes():
    b = {"tokens": jnp.zeros((8, 33), jnp.int32),
         "positions": jnp.zeros((3, 8, 32), jnp.int32)}
    out = worker_split(b, 4)
    assert out["tokens"].shape == (4, 2, 33)
    assert out["positions"].shape == (4, 3, 2, 32)
    sds = worker_split_abstract(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b), 4)
    assert sds["positions"].shape == (4, 3, 2, 32)


def test_state_specs_structure():
    mesh = make_host_mesh()
    hp = TrainHParams(rule=CommRule(kind="cada2"))
    specs = train_state_specs(CFG, mesh, hp)
    assert isinstance(specs, DistTrainState)
    # per-worker trees lead with the worker axis
    lead = jax.tree.leaves(specs.comm.worker_grads,
                           is_leaf=lambda x: isinstance(x, P))[0]
    assert lead[0] == "data"
    # the strategy owns its extra slices: CADA2 stores the stale-iterate
    # ring (R rows shard like params — replicated leading axis) plus the
    # per-worker slot index and the row versions (both replicated)
    assert set(specs.comm.extras) == {"ring", "slot", "ring_version"}
    ring = jax.tree.leaves(specs.comm.extras["ring"],
                           is_leaf=lambda x: isinstance(x, P))[0]
    assert ring[0] is None
    assert specs.comm.extras["slot"] == P(None)
    # CADA1 stores a snapshot (param-spec'd) + per-worker innovations
    specs_1 = train_state_specs(CFG, mesh, TrainHParams(
        rule=CommRule(kind="cada1")))
    assert set(specs_1.comm.extras) == {"snapshot", "worker_delta"}
    # 'always' is stateless: the whole comm state is dropped
    specs_a = train_state_specs(CFG, mesh, TrainHParams(
        rule=CommRule(kind="always")))
    assert specs_a.comm is None


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (the CI mesh matrix leg)")
def test_flat_round_with_manual_shard_maps_on_pod_mesh(monkeypatch):
    """The flat state plane on the MULTI-POD mesh (worker = pod): a
    (pod=2, data=4, model=1) mesh with the CADA state sharded over 'data'
    — worker planes shard pod × data, so the batched LHS and the fused
    update run under MANUAL shard_maps over both axes and psum their
    fp32 partials over the column shards. The run must match the
    mesh-free reference's masks.

    The pod-manual VGRAD shard_map stays off (REPRO_NO_PODMAP): executing
    it trips an XLA spmd-partitioner CHECK (hlo_sharding_util.cc
    IsManualSubgroup) on the pinned jax 0.4.37 for BOTH state planes —
    a pre-existing partial-auto limitation recorded in ROADMAP's
    jax-compat item (revisit at jax >= 0.6). The kernel-side manual
    shard_maps this test exercises are the flat round's own."""
    from repro.launch.mesh import compat_make_mesh
    from repro.distributed.trainer import flat_state_shards
    monkeypatch.setenv("REPRO_NO_PODMAP", "1")
    mesh = compat_make_mesh((2, 4, 1), ("pod", "data", "model"))
    hp = TrainHParams(rule=CommRule(kind="cada2", c=20.0, d_max=4,
                                    max_delay=10), lr=1e-3,
                      shard_cada_state=True)
    make, sspecs, m = jit_train_step(CFG, mesh, hp)
    assert m == 2  # the pod is the worker
    batches = [worker_split(_batch(jax.random.PRNGKey(50 + i)), m)
               for i in range(3)]
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batches[0])
    mets = []
    with set_mesh(mesh):
        step = make(sds)
        st = init_train_state(CFG, hp, m, jax.random.PRNGKey(42),
                              shards=flat_state_shards(CFG, mesh, hp))
        for b in batches:
            st, mm = step(st, b)
            mets.append(mm)
    # worker planes really shard pod × data
    wg = st.comm.worker_grads
    assert tuple(wg.sharding.spec) == ("pod", "data")
    # mesh-free reference trajectory: identical Algorithm-1 decisions
    hp_r = TrainHParams(rule=hp.rule, lr=1e-3, fused=False)
    step_r = jax.jit(make_train_step(CFG, hp_r, m))
    str_ = init_train_state(CFG, hp_r, m, jax.random.PRNGKey(42))
    for i, b in enumerate(batches):
        str_, mr = step_r(str_, b)
        np.testing.assert_array_equal(np.asarray(mets[i]["upload_mask"]),
                                      np.asarray(mr["upload_mask"]),
                                      err_msg=f"pod-map mask at step {i}")
        assert np.isfinite(float(mets[i]["loss"]))


def test_jit_train_step_on_host_mesh():
    mesh = make_host_mesh()
    hp = TrainHParams(rule=CommRule(kind="cada2", c=0.5, d_max=4,
                                    max_delay=10), microbatches=2)
    make, _, m = jit_train_step(CFG, mesh, hp)
    batch = worker_split(_batch(jax.random.PRNGKey(0)), m)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    with set_mesh(mesh):
        step = make(sds)
        st = init_train_state(CFG, hp, m, jax.random.PRNGKey(0))
        st, mets = step(st, batch)
    assert np.isfinite(float(mets["loss"]))


# ---------------------------------------------------- federated cohort step

def test_cohort_train_step_runs_lm():
    """The mesh-free federated LM step: O(C·n) device rows streamed
    through the host pool, finite losses, first-sampled workers
    force-uploading, and the O(M·n) plane never on device."""
    from repro.core.engine import sample_cohorts
    from repro.distributed.trainer import (init_cohort_train_state,
                                           make_cohort_train_step)
    m, c, rounds = 16, 4, 3
    hp = TrainHParams(rule=CommRule(kind="cada2", c=0.5, d_max=4,
                                    max_delay=10), microbatches=2)
    step = make_cohort_train_step(CFG, hp, m)
    st, pool = init_cohort_train_state(CFG, hp, m, jax.random.PRNGKey(3))
    n_flat = pool.n_flat
    assert pool.nbytes == m * n_flat * 4
    assert pool.device_row_bytes(c) == c * n_flat * 4
    for leaf in jax.tree.leaves((st.server, st.h, st.vhat)):
        assert leaf.shape != (m, n_flat)
    cohorts = sample_cohorts(m, c, rounds, seed=0)
    for k in range(rounds):
        full = _batch(jax.random.PRNGKey(50 + k), b=c * 2)
        batch = worker_split(full, c)        # (C, b_c, ...) cohort rows
        st, mets = step(st, pool, batch, cohorts[k])
        assert np.isfinite(float(mets["loss"]))
        assert mets["upload_mask"].shape == (c,)
    assert int(st.step) == rounds
    # round 0 force-uploads its whole cohort (staleness starts at the cap)
    assert pool.planes["worker_grads"][cohorts[0]].any()
    untouched = np.setdiff1d(np.arange(m), cohorts.ravel())
    if untouched.size:
        assert not pool.planes["worker_grads"][untouched].any()


def test_cohort_train_state_requires_fused():
    from repro.distributed.trainer import (init_cohort_train_state,
                                           make_cohort_train_step)
    hp = TrainHParams(rule=CommRule(kind="cada2"), fused=False)
    with pytest.raises(ValueError, match="fused"):
        init_cohort_train_state(CFG, hp, 4, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="fused"):
        make_cohort_train_step(CFG, hp, 4)


# --------------------------------------------------- local-update baselines

def test_local_update_baselines_converge():
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.core.engine import make_sampler
    from repro.models.small import logreg_init, logreg_loss

    ds = ijcnn1_like(n=1000)
    mtx = pad_to_matrix(uniform_partition(ds.n, 4, 0))
    sample = make_sampler(ds.x, ds.y, mtx, 16)
    params = logreg_init(None, 22, 2)
    for algo in ("local_momentum", "fedadam"):
        eng = LocalUpdateEngine(logreg_loss, n_workers=4, h_period=5,
                                algo=algo, lr=0.05, server_lr=0.05)
        st = eng.init(params)
        rngs = jax.random.split(jax.random.PRNGKey(0), 30 * 5)
        batches = jax.vmap(sample)(rngs)
        batches = jax.tree.map(
            lambda x: x.reshape((30, 5) + x.shape[1:]), batches)
        st, mets = jax.jit(eng.run)(st, batches)
        losses = np.asarray(mets["loss"])  # (rounds, H)
        assert losses[-1].mean() < losses[0].mean() * 0.8, algo
        assert int(np.asarray(mets["uploads"]).sum()) == 30 * 4
