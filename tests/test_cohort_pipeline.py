"""The pipelined cohort driver (core/flat.py::run_cohort_rounds).

The contract under test: the double-buffered transfer pipeline — fused
one-block gathers, H2D prefetch of round i+1 under round i's compute,
round i's scatter deferred one round with overlapping cohort rows
forwarded ON DEVICE — reorders TRANSFERS, never arithmetic. It must be
BIT-EXACT against the serial oracle (``pipeline=False``) for every
registered rule, on the engine, trainer and sim paths, for params, masks,
staleness, ∇̄, pooled planes and server extras.

Also here: the memmap-backed WorkerPool (gather/scatter round-trip,
checkpoint reshard, residency accounting), drain-on-early-exit (an
interrupted pipeline leaves the pool consistent through the last
completed round), ``metrics_every`` equivalence, the overlap-forwarding
schedule property, and the pipelined/memmap federated smokes the CI
``federated-smoke`` leg runs under the 6 GiB cap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, flat as F
from repro.core.engine import (CADAEngine, make_cohort_sampler,
                               make_sampler, sample_cohorts)
from repro.core.rules import RULES, CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss, mlp_init, mlp_loss
from repro.optim.fused import FusedAMSGrad

M = 8
C = 3
STEPS = 18

ARMS = RULES + ("topk_sparse", "local_momentum", "fedadam")


def _rule(kind):
    if kind == "topk_sparse":
        return CommRule(kind="topk", c=5.0, d_max=4, max_delay=6,
                        topk_frac=0.5, sparse_wire=True)
    if kind in ("local_momentum", "fedadam"):
        return CommRule(kind=kind, c=0.6, d_max=4, max_delay=6,
                        local_steps=2, local_lr=0.05, local_beta=0.9)
    kw = dict(kind=kind, c=5.0, d_max=4, max_delay=6)
    if kind == "topk":
        kw["topk_frac"] = 0.5
    if kind == "avp":
        kw.update(period_min=1, period_max=4)
    return CommRule(**kw)


def _problem(m=M, steps=STEPS, seed=2, n=400, batch=8):
    ds = ijcnn1_like(n=n)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, batch)
    params = logreg_init(None, 22, 2)
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(seed), steps))
    return params, batches


def _delta_batches(steps=STEPS, h=2, m=M, seed=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (steps, h, m, 8, 22)),
            jax.random.normal(ky, (steps, h, m, 8, 2)))


def _delta_loss(p, b):
    x, y = b
    return jnp.mean((x @ p["w"] + p["b"] - y) ** 2)


def _cohort_run(kind, cohorts, *, pipeline, metrics_every=8,
                pool_storage="ram", pool_path=None, resum_every=0):
    """One cohort run of ``kind`` over ``cohorts`` — returns
    (state, pool, host metrics, engine)."""
    rule = _rule(kind)
    delta = kind in ("local_momentum", "fedadam")
    if delta:
        params = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                         (22, 2)) * 0.3,
                  "b": jnp.zeros((2,))}
        batches = _delta_batches(steps=cohorts.shape[0])
        eng = CADAEngine(_delta_loss, None, rule, M,
                         resum_every=resum_every)
        cohort_batches = [
            jax.tree.map(lambda x, i=i: x[i][:, cohorts[i]], batches)
            for i in range(cohorts.shape[0])]
    else:
        params, batches = _problem(steps=cohorts.shape[0])
        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M,
                         resum_every=resum_every)
        cohort_batches = [
            jax.tree.map(lambda x, i=i: x[i][cohorts[i]], batches)
            for i in range(cohorts.shape[0])]
    st, pool = eng.init_cohort(params, pool_storage=pool_storage,
                               pool_path=pool_path)
    st, mets = eng.run_cohort(st, pool, cohort_batches, cohorts,
                              pipeline=pipeline,
                              metrics_every=metrics_every)
    return st, pool, mets, eng


def _assert_bit_exact(st_p, pool_p, mets_p, st_s, pool_s, mets_s, kind):
    """Pipelined vs serial: every state surface, bit for bit."""
    assert len(mets_p) == len(mets_s)
    for i, (mp, ms) in enumerate(zip(mets_p, mets_s)):
        for key in ("upload_mask", "staleness", "loss", "uploads",
                    "bytes_up"):
            np.testing.assert_array_equal(
                np.asarray(mp[key]), np.asarray(ms[key]),
                err_msg=f"{kind}: metrics[{key}] diverged at round {i}")
    np.testing.assert_array_equal(
        np.asarray(st_p.server.staleness), np.asarray(st_s.server.staleness),
        err_msg=f"{kind}: staleness diverged")
    np.testing.assert_array_equal(
        np.asarray(st_p.server.nabla), np.asarray(st_s.server.nabla),
        err_msg=f"{kind}: nabla diverged")
    np.testing.assert_array_equal(
        np.asarray(st_p.server.diff_hist),
        np.asarray(st_s.server.diff_hist),
        err_msg=f"{kind}: diff_hist diverged")
    for name in pool_s.planes:
        np.testing.assert_array_equal(
            np.asarray(pool_p.planes[name]), np.asarray(pool_s.planes[name]),
            err_msg=f"{kind}: pool plane {name!r} diverged")
    for a, b in zip(jax.tree.leaves(st_p.params),
                    jax.tree.leaves(st_s.params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=f"{kind}: params diverged")
    for name, val in st_s.server.extras.items():
        for a, b in zip(jax.tree.leaves(st_p.server.extras[name]),
                        jax.tree.leaves(val)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                err_msg=f"{kind}: server extras[{name}] diverged")


# --------------------------------- pipelined vs serial (engine, all rules)

@pytest.mark.parametrize("kind", ARMS)
def test_pipelined_matches_serial_all_rules(kind):
    """The acceptance gate: the pipeline reorders transfers, never
    arithmetic — bit-exact vs the serial oracle for all 8 grad rules,
    the true-sparse wire and both delta-payload rules. The shared
    ``sample_cohorts`` schedule has overlapping consecutive cohorts
    (C=3 of M=8), so the on-device forwarding path is genuinely hot."""
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    # meta-check: consecutive cohorts DO overlap somewhere in the
    # schedule, or the forwarding patch would be untested
    src = F.cohort_overlap_schedule(cohorts)
    assert (src >= 0).any()
    st_s, pool_s, mets_s, _ = _cohort_run(kind, cohorts, pipeline=False)
    st_p, pool_p, mets_p, _ = _cohort_run(kind, cohorts, pipeline=True)
    _assert_bit_exact(st_p, pool_p, mets_p, st_s, pool_s, mets_s, kind)


def test_pipelined_resum_drains_before_guard():
    """The ``resum_every`` drift guard reads the host pool: the pipelined
    driver must drain the deferred scatter first, making the guarded
    pipelined run bit-exact to the guarded serial run."""
    cohorts = sample_cohorts(M, C, 20, seed=3)   # 20 % resum_every == 0
    st_s, pool_s, mets_s, _ = _cohort_run("cada2", cohorts, pipeline=False,
                                          resum_every=5)
    st_p, pool_p, mets_p, _ = _cohort_run("cada2", cohorts, pipeline=True,
                                          resum_every=5)
    _assert_bit_exact(st_p, pool_p, mets_p, st_s, pool_s, mets_s,
                      "cada2+resum")
    # the run ends ON a guard round, so the invariant holds exactly
    np.testing.assert_array_equal(np.asarray(st_p.server.nabla),
                                  pool_p.resum_nabla())


def test_metrics_every_equivalence():
    """``metrics_every`` only batches the device→host fetch: the metric
    VALUES are identical whatever the stride (including one larger than
    the whole run)."""
    cohorts = sample_cohorts(M, C, STEPS, seed=7)
    runs = [_cohort_run("cada2", cohorts, pipeline=True, metrics_every=k)
            for k in (1, 5, STEPS + 10)]
    base = runs[0][2]
    for st, _, mets, _ in runs[1:]:
        assert len(mets) == len(base)
        for i, (ma, mb) in enumerate(zip(mets, base)):
            assert set(ma) == set(mb)
            for key in ma:
                np.testing.assert_array_equal(
                    np.asarray(ma[key]), np.asarray(mb[key]),
                    err_msg=f"metrics[{key}] diverged at round {i}")


def test_run_cohort_rounds_rejects_unsorted_cohorts():
    """Correctness depends on sorted-unique cohort rows (the overlap
    schedule searchsorts the previous row): the driver validates the
    schedule up front instead of silently forwarding wrong rows."""
    cohorts = sample_cohorts(M, C, 4, seed=5)
    cohorts[2] = cohorts[2][::-1]
    params, batches = _problem(steps=4)
    cohort_batches = [
        jax.tree.map(lambda x, i=i: x[i][cohorts[i]], batches)
        for i in range(4)]
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), _rule("cada2"), M)
    st, pool = eng.init_cohort(params)
    for pipeline in (False, True):
        with pytest.raises(ValueError, match="sorted"):
            eng.run_cohort(st, pool, cohort_batches, cohorts,
                           pipeline=pipeline)


def test_run_cohort_rounds_empty_schedule():
    """A (0, C) schedule is a no-op on both drivers: (state, []) with no
    pool traffic (the pipelined branch used to gather cohorts[0] before
    checking the round count)."""
    params, _ = _problem(steps=1)
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), _rule("cada2"), M)
    st, pool = eng.init_cohort(params)
    empty = np.empty((0, C), np.int32)
    for pipeline in (False, True):
        st2, mets = eng.run_cohort(st, pool, [], empty, pipeline=pipeline)
        assert mets == []
        assert st2 is st


# ------------------------------------------------ overlap schedule property

def test_cohort_overlap_schedule_property():
    """src[i, j] points at cohorts[i][j]'s row in round i-1's output
    block, -1 exactly when the worker was absent from the previous
    cohort; row 0 forwards nothing."""
    cohorts = sample_cohorts(50, 7, 40, seed=1)
    src = F.cohort_overlap_schedule(cohorts)
    assert src.shape == cohorts.shape and src.dtype == np.int32
    assert (src[0] == -1).all()
    for i in range(1, cohorts.shape[0]):
        for j, w in enumerate(cohorts[i]):
            hits = np.nonzero(cohorts[i - 1] == w)[0]
            assert src[i, j] == (hits[0] if hits.size else -1)


def test_patch_fused_rows_forwards_prev():
    """The on-device patch substitutes the previous block's rows at
    forwarded positions and keeps the gathered rows elsewhere."""
    rng = np.random.default_rng(0)
    fused = jnp.asarray(rng.normal(size=(2, 4, 6)).astype(np.float32))
    prev = jnp.asarray(rng.normal(size=(2, 5, 6)).astype(np.float32))
    src = jnp.asarray(np.array([3, -1, 0, -1], np.int32))
    out = np.asarray(F.patch_fused_rows(fused, prev, src))
    np.testing.assert_array_equal(out[:, 0], np.asarray(prev)[:, 3])
    np.testing.assert_array_equal(out[:, 1], np.asarray(fused)[:, 1])
    np.testing.assert_array_equal(out[:, 2], np.asarray(prev)[:, 0])
    np.testing.assert_array_equal(out[:, 3], np.asarray(fused)[:, 3])


# ------------------------------------------------- drain on early exit

def test_pipelined_drain_on_early_exit():
    """A pipeline interrupted mid-run (here: the batch supplier raises at
    round j) drains its deferred scatter — the pool holds exactly the
    serial oracle's state after the j completed rounds."""
    j = 9
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    params, batches = _problem()
    cohort_batches = [
        jax.tree.map(lambda x, i=i: x[i][cohorts[i]], batches)
        for i in range(STEPS)]

    class Boom(RuntimeError):
        pass

    def exploding(i, cohort):
        if i == j:
            raise Boom
        return cohort_batches[i]

    rule = _rule("cada2")
    eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
    st, pool = eng.init_cohort(params)
    with pytest.raises(Boom):
        eng.run_cohort(st, pool, exploding, cohorts, pipeline=True)

    # serial oracle truncated to the j completed rounds
    eng_s = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.05), rule, M)
    st_s, pool_s = eng_s.init_cohort(params)
    eng_s.run_cohort(st_s, pool_s, cohort_batches[:j], cohorts[:j],
                     pipeline=False)
    for name in pool_s.planes:
        np.testing.assert_array_equal(
            pool.planes[name], pool_s.planes[name],
            err_msg=f"interrupted pool plane {name!r} inconsistent")


# ------------------------------------------------------- trainer driver

@pytest.mark.parametrize("kind", ("cada2", "cada1", "laq", "topk"))
def test_trainer_pipelined_matches_serial(kind):
    """The trainer's cohort driver (run_cohort_train) through the same
    fused step: pipelined vs serial, bit-exact params/pool/masks on the
    smoke LM."""
    from repro.distributed.trainer import (init_cohort_train_state,
                                           make_cohort_train_step,
                                           run_cohort_train, worker_split)
    from tests.test_trainer_distributed import CFG, TrainHParams, _batch

    m, c, rounds = 16, 4, 5
    hp = TrainHParams(rule=_rule(kind), microbatches=2)
    cohorts = sample_cohorts(m, c, rounds, seed=0)
    batches = []
    for k in range(rounds):
        full = _batch(jax.random.PRNGKey(50 + k), b=c * 2)
        batches.append(worker_split(full, c))

    outs = {}
    for pipeline in (False, True):
        step = make_cohort_train_step(CFG, hp, m)
        st, pool = init_cohort_train_state(CFG, hp, m,
                                           jax.random.PRNGKey(3))
        st, mets = run_cohort_train(step, st, pool, batches, cohorts,
                                    pipeline=pipeline, metrics_every=3)
        outs[pipeline] = (st, pool, mets)
    st_s, pool_s, mets_s = outs[False]
    st_p, pool_p, mets_p = outs[True]
    for i, (mp, ms) in enumerate(zip(mets_p, mets_s)):
        np.testing.assert_array_equal(
            np.asarray(mp["upload_mask"]), np.asarray(ms["upload_mask"]),
            err_msg=f"trainer {kind}: masks diverged at round {i}")
        np.testing.assert_array_equal(np.asarray(mp["loss"]),
                                      np.asarray(ms["loss"]))
    np.testing.assert_array_equal(np.asarray(st_p.params_flat),
                                  np.asarray(st_s.params_flat),
                                  err_msg=f"trainer {kind}: params diverged")
    for name in pool_s.planes:
        np.testing.assert_array_equal(
            pool_p.planes[name], pool_s.planes[name],
            err_msg=f"trainer {kind}: pool plane {name!r} diverged")


# ------------------------------------------------------------- sim paths

@pytest.mark.parametrize("kind", ("cada2", "laq"))
def test_sim_barrier_cohort_pipelined_matches_serial(kind):
    """The sim's federated barrier rounds through the pipelined driver:
    pipeline on/off give identical losses, masks, staleness and final
    params (the pricing replay reads the same host metrics)."""
    from repro.sim import simulate

    params, batches = _problem(m=8, steps=10)
    rule = _rule(kind)
    runs = [simulate(logreg_loss, rule, params, batches, n_workers=8,
                     network="lan", mode="barrier", cohort_size=3,
                     pipeline=p, metrics_every=4, lr=0.01)
            for p in (False, True)]
    np.testing.assert_array_equal(runs[0].losses, runs[1].losses)
    np.testing.assert_array_equal(runs[0].upload_masks,
                                  runs[1].upload_masks)
    np.testing.assert_array_equal(runs[0].staleness, runs[1].staleness)
    assert runs[0].wall_s == runs[1].wall_s
    for a, b in zip(jax.tree.leaves(runs[0].final_params),
                    jax.tree.leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", ("cada2", "topk", "avp"))
def test_sim_async_host_pool_deferred_scatter_parity(kind):
    """The async ``host_pool`` streaming now defers each gate's writeback
    (fused one-block row up, parked device row down) — still bit-exact
    with the device (M, n_flat) plane, broadening test_sim's cada1/laq
    gate to more rule families."""
    from repro.sim import simulate

    params, batches = _problem(m=4, steps=10)
    rule = _rule(kind)
    runs = [simulate(logreg_loss, rule, params, batches, n_workers=4,
                     network="hetero", mode="async", async_tau=5,
                     host_pool=hp, lr=0.01)
            for hp in (False, True)]
    np.testing.assert_array_equal(runs[0].losses, runs[1].losses)
    assert runs[0].uploads == runs[1].uploads
    assert runs[0].wall_s == runs[1].wall_s
    for a, b in zip(jax.tree.leaves(runs[0].final_params),
                    jax.tree.leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sim_async_pending_cap_bounds_parked_rows(monkeypatch):
    """Deferred-writeback parking is BOUNDED: past ASYNC_PENDING_CAP the
    oldest parked row is flushed, so async device overhead stays a
    constant number of rows however large M gets. With cap=1 and M=8
    free-running workers the eviction path fires on nearly every gate —
    and any flush point before w's next gather is bit-exact, so parity
    with the dense (M, n_flat) plane still holds."""
    from repro.sim import runtime, simulate

    monkeypatch.setattr(runtime, "ASYNC_PENDING_CAP", 1)
    params, batches = _problem(m=8, steps=10)
    rule = _rule("cada2")
    runs = [simulate(logreg_loss, rule, params, batches, n_workers=8,
                     network="hetero", mode="async", async_tau=5,
                     host_pool=hp, lr=0.01)
            for hp in (False, True)]
    np.testing.assert_array_equal(runs[0].losses, runs[1].losses)
    assert runs[0].uploads == runs[1].uploads
    assert runs[0].wall_s == runs[1].wall_s
    for a, b in zip(jax.tree.leaves(runs[0].final_params),
                    jax.tree.leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- memmap pool

@pytest.mark.parametrize("dtype", (np.float32, jnp.bfloat16),
                         ids=("f32", "bf16"))
def test_memmap_pool_gather_scatter_roundtrip(tmp_path, dtype):
    """pool → (C, n_flat) → pool through np.memmap planes is bit-exact,
    bf16 storage included; the files back the full O(M·n) mapping while
    RAM residency is just the staging buffer."""
    rng = np.random.default_rng(0)
    m, n_flat = 32, 48
    dt = np.dtype(dtype)
    planes = {
        "worker_grads": rng.normal(size=(m, n_flat)).astype(dt),
        "residual": rng.normal(size=(m, n_flat)).astype(dt),
    }
    pool = F.WorkerPool({k: v.copy() for k, v in planes.items()},
                        storage="memmap", path=str(tmp_path))
    assert (tmp_path / "worker_grads.plane").exists()
    assert pool.nbytes == pool.mapped_nbytes == 2 * m * n_flat * dt.itemsize
    assert pool.resident_nbytes == 0          # no staging allocated yet

    cohort = np.sort(rng.choice(m, 7, replace=False)).astype(np.int32)
    rows = pool.gather(cohort)
    for name in planes:
        np.testing.assert_array_equal(np.asarray(rows[name]),
                                      planes[name][cohort])
    assert pool.resident_nbytes > 0           # the double staging buffer
    assert pool.resident_nbytes < pool.mapped_nbytes

    new_rows = {name: jnp.asarray(rng.normal(size=(7, n_flat)), dtype=dt)
                for name in planes}
    pool.scatter(cohort, new_rows)
    pool.flush()
    off = np.setdiff1d(np.arange(m), cohort)
    for name in planes:
        np.testing.assert_array_equal(np.asarray(pool.planes[name][cohort]),
                                      np.asarray(new_rows[name]))
        np.testing.assert_array_equal(np.asarray(pool.planes[name][off]),
                                      planes[name][off])


def test_memmap_pool_checkpoint_reshard_roundtrip(tmp_path):
    """checkpoint save → reshard restore → load_state_dict lands IN the
    memmap mapping (same files, new contents), bit-exact on the true
    entries."""
    import repro.checkpoint.io as ckpt
    params = logreg_init(None, 22, 2)
    lay_src = F.layout_of(params)
    lay_dst = F.layout_of(params, shards=16)
    assert lay_src.n_flat != lay_dst.n_flat
    rng = np.random.default_rng(1)
    strat = comm.strategy_for(_rule("laq"))
    _, pool = F.init_cohort_state(strat, lay_src, params, M,
                                  pool_storage="memmap",
                                  pool_path=str(tmp_path / "src"))
    for name in pool.planes:
        pool.planes[name][:, :lay_src.n] = rng.normal(
            size=(M, lay_src.n)).astype(np.float32)
    ckpt.save(str(tmp_path / "ck"), {"pool": pool.state_dict()}, step=3,
              flat_meta=lay_src)
    template = {"pool": {name: np.zeros((M, lay_dst.n_flat), np.float32)
                         for name in pool.planes}}
    restored, step_no = ckpt.restore(str(tmp_path / "ck"), template)
    assert step_no == 3
    _, pool2 = F.init_cohort_state(strat, lay_dst, params, M,
                                   pool_storage="memmap",
                                   pool_path=str(tmp_path / "dst"))
    pool2.load_state_dict(restored["pool"])
    for name in pool.planes:
        got = pool2.planes[name]
        assert isinstance(got, np.memmap)     # loaded IN PLACE, still mapped
        assert got.shape == (M, lay_dst.n_flat)
        np.testing.assert_array_equal(got[:, :lay_src.n],
                                      pool.planes[name][:, :lay_src.n])
        np.testing.assert_array_equal(got[:, lay_src.n:], 0.0)


def test_memmap_pipelined_matches_ram(tmp_path):
    """Storage backend is invisible to the numerics: a pipelined run on a
    memmap pool is bit-exact with the RAM pool run."""
    cohorts = sample_cohorts(M, C, STEPS, seed=5)
    st_r, pool_r, mets_r, _ = _cohort_run("laq", cohorts, pipeline=True)
    st_m, pool_m, mets_m, _ = _cohort_run("laq", cohorts, pipeline=True,
                                          pool_storage="memmap",
                                          pool_path=str(tmp_path))
    _assert_bit_exact(st_m, pool_m, mets_m, st_r, pool_r, mets_r,
                      "memmap-vs-ram")
    assert pool_m.mapped_nbytes == pool_r.nbytes


# ------------------------------------------- federated smokes (CI leg)

def test_federated_smoke_m_10k_pipelined():
    """The CI federated-smoke on the PIPELINED driver: M=10⁴ C=64 MLP
    rounds under the 6 GiB cap, callable batch supplier, metrics batched
    device-side — finite losses, round 0 force-uploads its cohort."""
    m, c, rounds = 10_000, 64, 6
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100)
    ds = ijcnn1_like(n=20_000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_cohort_sampler(ds.x, ds.y, mtx, 32)
    params = mlp_init(jax.random.PRNGKey(7), 22, 64, 2)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.05), rule, m)
    st, pool = eng.init_cohort(params)
    cohorts = sample_cohorts(m, c, rounds, seed=0)

    def batch_fn(i, cohort):
        return sample(jax.random.PRNGKey(200 + i), jnp.asarray(cohort))

    st, mets = eng.run_cohort(st, pool, batch_fn, cohorts, pipeline=True,
                              metrics_every=4)
    assert len(mets) == rounds
    losses = np.asarray([mm["loss"] for mm in mets])
    assert np.isfinite(losses).all()
    assert int(np.asarray(mets[0]["uploads"])) == c
    assert int(st.step) == rounds


def test_federated_smoke_memmap_pool(tmp_path):
    """The CI memmap-pool smoke: M=10⁴ C=64 pipelined rounds with the
    O(M·n) planes living in files — RAM residency is the staging buffer,
    not the plane."""
    m, c, rounds = 10_000, 64, 4
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100)
    ds = ijcnn1_like(n=20_000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_cohort_sampler(ds.x, ds.y, mtx, 32)
    params = mlp_init(jax.random.PRNGKey(7), 22, 64, 2)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.05), rule, m)
    st, pool = eng.init_cohort(params, pool_storage="memmap",
                               pool_path=str(tmp_path))
    n_flat = eng._layout.n_flat
    assert pool.mapped_nbytes == m * n_flat * 4
    cohorts = sample_cohorts(m, c, rounds, seed=0)

    def batch_fn(i, cohort):
        return sample(jax.random.PRNGKey(300 + i), jnp.asarray(cohort))

    st, mets = eng.run_cohort(st, pool, batch_fn, cohorts, pipeline=True,
                              metrics_every=4)
    assert np.isfinite([mm["loss"] for mm in mets]).all()
    # residency: staging is 2 slots × P planes × C rows — O(C·n), not O(M·n)
    assert pool.resident_nbytes == 2 * len(pool.plane_order) * c * n_flat * 4
    assert pool.resident_nbytes * 10 < pool.mapped_nbytes
