"""Paper-faithful engine behaviour: Algorithm 1 invariants, the c=0 ⇒
distributed-AMSGrad equivalence, convergence, and communication accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import dirichlet_partition, pad_to_matrix
from repro.data.synthetic import ijcnn1_like
from repro.optim.adam import adam

M = 8


def _problem():
    ds = ijcnn1_like(n=2000)
    shard = pad_to_matrix(dirichlet_partition(ds.y, m=M, alpha=0.5, seed=0))

    def loss_fn(params, batch):
        xb, yb = batch
        logits = xb @ params["w"] + params["b"]
        lp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(
            lp, yb[:, None].astype(jnp.int32), axis=1).mean()
        return nll + 1e-5 * jnp.sum(params["w"] ** 2)

    params = {"w": jnp.zeros((22, 2)), "b": jnp.zeros((2,))}
    sample = make_sampler(ds.x, ds.y, shard, 32)
    return loss_fn, params, sample


def _run(kind, c, steps=150, seed=1, max_delay=100, lr=0.02):
    loss_fn, params, sample = _problem()
    eng = CADAEngine(loss_fn, adam(lr=lr),
                     CommRule(kind=kind, c=c, d_max=10, max_delay=max_delay),
                     M)
    st = eng.init(params)
    batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(seed),
                                                steps))
    st, mets = jax.jit(eng.run)(st, batches)
    return st, mets


def test_always_equals_distributed_amsgrad_baseline():
    """rule=always uploads everything, every step."""
    _, mets = _run("always", c=0.0, steps=50)
    assert int(mets["uploads"].sum()) == 50 * M
    assert float(mets["skip_rate"].max()) == 0.0


@pytest.mark.parametrize("kind", ["cada1", "cada2"])
def test_c0_recovers_amsgrad(kind):
    """c=0 makes the rule threshold 0: every worker uploads (fresh grads),
    so the trajectory equals distributed AMSGrad exactly (paper eq. 2)."""
    st_c, mets_c = _run(kind, c=0.0, steps=40)
    st_a, mets_a = _run("always", c=0.0, steps=40)
    np.testing.assert_allclose(np.asarray(st_c.params["w"]),
                               np.asarray(st_a.params["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mets_c["loss"]),
                               np.asarray(mets_a["loss"]), rtol=1e-5)


@pytest.mark.parametrize("kind", ["cada1", "cada2"])
def test_cada_converges_and_saves_uploads(kind):
    st, mets = _run(kind, c=0.6, steps=300)
    final = float(np.mean(np.asarray(mets["loss"])[-20:]))
    first = float(np.mean(np.asarray(mets["loss"])[:20]))
    assert final < first * 0.5, (first, final)
    # CADA's raison d'être: strictly fewer uploads than distributed Adam.
    assert int(mets["uploads"].sum()) < 300 * M * 0.9


def test_staleness_capped_by_max_delay():
    D = 5
    _, mets = _run("cada2", c=1e9, steps=60, max_delay=D)
    assert int(mets["max_staleness"].max()) <= D


def test_upload_counters_consistent():
    _, mets = _run("cada2", c=0.6, steps=100)
    up = np.asarray(mets["uploads"])
    skip = np.asarray(mets["skip_rate"])
    np.testing.assert_allclose(skip, 1.0 - up / M, atol=1e-6)
    assert (up >= 0).all() and (up <= M).all()
    # 2 gradient evaluations per worker per iteration for CADA (§2.2)
    assert int(mets["grad_evals"][0]) == 2 * M


def test_lag_skips_less_than_cada_late_in_training():
    """§2.1: the stochastic-LAG rule's LHS keeps a non-vanishing variance
    term, so late in training it skips (much) less than CADA2."""
    _, mets_lag = _run("lag", c=0.6, steps=300)
    _, mets_cada = _run("cada2", c=0.6, steps=300)
    tail = slice(-100, None)
    lag_skip = float(np.mean(np.asarray(mets_lag["skip_rate"])[tail]))
    cada_skip = float(np.mean(np.asarray(mets_cada["skip_rate"])[tail]))
    assert cada_skip > lag_skip + 0.2, (cada_skip, lag_skip)
