"""Optimizer tests: paper eq. (2a)-(2c) semantics, AMSGrad invariants
(hypothesis), fused-kernel equivalence, schedules, weight decay."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim.adam import adam, amsgrad
from repro.optim.base import apply_updates, chain_weight_decay
from repro.optim.fused import FusedAMSGrad, as_optimizer
from repro.optim.schedules import (constant, cosine, inv_sqrt_horizon,
                                   pl_schedule)
from repro.optim.sgd import momentum, sgd


def _tree(rng, shape=(37,)):
    return {"w": jnp.asarray(rng.normal(size=shape), jnp.float32)}


def test_paper_update_semantics(rng):
    """One hand-computed step of eq. (2a)-(2c)."""
    opt = adam(lr=0.1, b1=0.5, b2=0.5, eps=0.01, amsgrad=True,
               eps_inside_sqrt=True)
    params = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([2.0])}
    state = opt.init(params)
    upd, state = opt.update(g, state, params)
    h = 0.5 * 0.0 + 0.5 * 2.0          # = 1
    v = 0.5 * 0.0 + 0.5 * 4.0          # = 2
    expected = -0.1 * h / np.sqrt(0.01 + v)
    np.testing.assert_allclose(float(upd["w"][0]), expected, rtol=1e-6)


def test_v_recursion_uses_vhat(rng):
    """Paper (2b): v^{k+1} = β2·v̂^k + ... — the AMSGrad max feeds back."""
    opt = adam(lr=0.0, b1=0.0, b2=0.5, eps=0.0, amsgrad=True)
    params = {"w": jnp.array([0.0])}
    state = opt.init(params)
    _, state = opt.update({"w": jnp.array([2.0])}, state, params)  # v̂ = 2
    _, state = opt.update({"w": jnp.array([0.0])}, state, params)
    # v = 0.5·v̂ + 0 = 1 (from v̂=2, not from v)
    np.testing.assert_allclose(float(state.v["w"][0]), 1.0)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=1,
                max_size=8))
def test_amsgrad_vhat_monotone_property(gs):
    """Property: v̂ is nondecreasing along any gradient sequence."""
    opt = amsgrad(lr=0.01)
    params = {"w": jnp.zeros((1,))}
    state = opt.init(params)
    prev = float(state.vhat["w"][0])
    for g in gs:
        _, state = opt.update({"w": jnp.array([g])}, state, params)
        cur = float(state.vhat["w"][0])
        assert cur >= prev - 1e-9
        prev = cur


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
def test_fused_optimizer_equals_jnp_adam(seed, steps):
    """The Pallas-backed FusedAMSGrad tracks optim/adam.py exactly."""
    rng = np.random.default_rng(seed)
    params = _tree(rng)
    ref_opt = adam(lr=0.05)
    fus = FusedAMSGrad(lr=0.05)
    ref_state = ref_opt.init(params)
    fus_state = fus.init(params)
    p_ref, p_fus = params, params
    for _ in range(steps):
        g = _tree(rng)
        upd, ref_state = ref_opt.update(g, ref_state, p_ref)
        p_ref = apply_updates(p_ref, upd)
        p_fus, fus_state, _ = fus.apply(p_fus, fus_state, g)
    np.testing.assert_allclose(np.asarray(p_fus["w"]),
                               np.asarray(p_ref["w"]), rtol=1e-5, atol=1e-6)


def test_fused_as_optimizer_protocol(rng):
    opt = as_optimizer(FusedAMSGrad(lr=0.1))
    params = _tree(rng)
    state = opt.init(params)
    upd, state = opt.update(_tree(rng), state, params)
    assert upd["w"].shape == params["w"].shape


def test_sgd_momentum(rng):
    opt = momentum(lr=0.1, beta=0.9)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    upd, state = opt.update({"w": jnp.array([1.0])}, state, params)
    np.testing.assert_allclose(float(upd["w"][0]), -0.1)
    upd, state = opt.update({"w": jnp.array([1.0])}, state, params)
    np.testing.assert_allclose(float(upd["w"][0]), -0.1 * 1.9, rtol=1e-6)


def test_weight_decay_decoupled(rng):
    opt = chain_weight_decay(sgd(lr=1.0), 0.1)
    params = {"w": jnp.array([2.0])}
    upd, _ = opt.update({"w": jnp.array([0.0])}, opt.init(params), params)
    np.testing.assert_allclose(float(upd["w"][0]), -0.2)


def test_schedules():
    step = jnp.asarray(100)
    assert float(constant(0.5)(step)) == 0.5
    assert abs(float(inv_sqrt_horizon(1.0, 100)(step)) - 0.1) < 1e-6
    s = pl_schedule(mu=2.0, k0=10)
    assert float(s(jnp.asarray(0))) > float(s(jnp.asarray(100)))
    c = cosine(1.0, total_steps=100, warmup=10)
    assert float(c(jnp.asarray(5))) < 1.0            # warming up
    assert float(c(jnp.asarray(100))) < 1e-6         # decayed


def test_schedule_into_adam(rng):
    opt = adam(lr=lambda k: 0.1 / (1 + k))
    params = _tree(rng)
    state = opt.init(params)
    u1, state = opt.update({"w": jnp.ones(37)}, state, params)
    u2, state = opt.update({"w": jnp.ones(37)}, state, params)
    assert float(jnp.abs(u2["w"]).max()) < float(jnp.abs(u1["w"]).max())
