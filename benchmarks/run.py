"""Benchmark aggregator: one entry per paper table/figure + the beyond-paper
benches. Prints a CSV summary and writes per-bench JSON under results/.

  python -m benchmarks.run            # fast settings (CI-sized)
  python -m benchmarks.run --full     # paper-sized iteration counts
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-list: logreg,nn,lag,hier,roofline")
    args = ap.parse_args()
    full = args.full
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def emit(bench, r):
        r = dict(r)
        r["bench"] = bench
        rows.append(r)

    if only is None or "logreg" in only:
        from benchmarks import paper_logreg
        t0 = time.time()
        for ds in ("covtype", "ijcnn1"):
            for r in paper_logreg.run(ds, iters=1000 if full else 500,
                                      monte_carlo=3 if full else 1):
                emit("paper_logreg(Fig2-3)", r)
        print(f"[logreg done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "nn" in only:
        from benchmarks import paper_nn
        t0 = time.time()
        for model in (("cnn", "mlp") if full else ("mlp",)):
            for r in paper_nn.run(model=model,
                                  iters=800 if full else 300):
                emit("paper_nn(Fig4)", r)
        print(f"[nn done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "lag" in only:
        from benchmarks import lag_ineffectiveness
        for r in lag_ineffectiveness.run(iters=800 if full else 400):
            emit("lag_ineffectiveness(§2.1)", r)

    if only is None or "hier" in only:
        from benchmarks import hierarchical_cada
        for r in hierarchical_cada.run(steps=80 if full else 40):
            emit("hierarchical_cada(beyond-paper)", r)

    if only is None or "ablations" in only:
        from benchmarks import ablations
        iters = 600 if full else 300
        for r in (ablations.sweep_c(iters) + ablations.sweep_D(iters)
                  + ablations.sweep_bits(iters) + ablations.sweep_H(iters)):
            emit("ablations(supplement)", r)

    if only is None or "roofline" in only:
        from benchmarks import roofline
        rl = roofline.load(["results/dryrun_single.jsonl",
                            "results/dryrun_multi.jsonl"])
        for r in rl:
            emit("roofline(§Dry-run)", {
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "dominant": r["dominant"],
                "t_compute_s": r["t_compute_s"],
                "t_memory_s": r["t_memory_s"],
                "t_collective_s": r["t_collective_s"],
                "useful": r["useful_flops_ratio"]})

    # ------------------------------------------------------------- CSV out
    keys = ["bench"]
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


if __name__ == "__main__":
    main()
