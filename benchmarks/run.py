"""Benchmark aggregator: one entry per paper table/figure + the beyond-paper
benches. Prints a CSV summary and writes per-bench JSON under results/.

  python -m benchmarks.run            # fast settings (CI-sized)
  python -m benchmarks.run --full     # paper-sized iteration counts
  python -m benchmarks.run --only cada   # just the BENCH_cada.json tracker

Every run also refreshes ``BENCH_cada.json`` (steps/sec of the jitted
engine + uploads saved by CADA2 vs distributed Adam on the logreg problem)
so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCH_PATH = "BENCH_cada.json"
SIM_BENCH_PATH = "BENCH_sim.json"
HIER_BENCH_PATH = "BENCH_hierarchical.json"


def _load_baseline() -> dict | None:
    try:
        with open(BENCH_PATH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _warn_if_regressed(name: str, new_sps: float, old: dict | None) -> None:
    """Warn (stderr) when steps/sec drops >10% vs the committed baseline."""
    if not old:
        return
    old_sps = old.get("steps_per_sec")
    if old_sps and new_sps < 0.9 * old_sps:
        print(f"[cada] WARNING: {name} steps/sec regressed "
              f"{old_sps} -> {new_sps} (>{10}% below the committed "
              f"baseline in {BENCH_PATH})", file=sys.stderr)


def _comm_state_bytes(comm) -> tuple[int, int]:
    """(total comm-state bytes, eval-point-extras bytes) of an engine's
    flat comm state — the ring-vs-dense memory story per arm."""
    import jax
    if comm is None:
        return 0, 0
    total = sum(int(l.size * l.dtype.itemsize)
                for l in jax.tree.leaves(comm))
    extras = sum(int(l.size * l.dtype.itemsize)
                 for l in jax.tree.leaves(comm.extras))
    return total, extras


def _second_eval_frac(eng, st, batches, step_s: float) -> float:
    """Fraction of a measured engine step spent in the rule's SECOND
    gradient evaluation: (jitted two-point eval − jitted fresh-only eval)
    per call, over the arm's measured seconds per step. 0.0 for
    single-eval rules."""
    import jax

    from repro.core import flat as F

    if eng.strategy.grad_evals_per_iter < 2 or step_s <= 0:
        return 0.0
    b0 = jax.tree.map(lambda x: x[0], batches)
    layout, extras = eng._layout, st.comm.extras
    f2 = jax.jit(lambda p, b: F.eval_two_point(
        eng.strategy, layout, extras, p, b, eng.m, vgrad=eng._vgrad,
        vgrad_per=eng._vgrad_per, fuse_evals=eng._fuse_evals,
        group_evals=eng._group_evals))
    f1 = jax.jit(lambda p, b: eng._vgrad(p, b))
    ts = {}
    for name, f in (("two", f2), ("one", f1)):
        jax.block_until_ready(f(st.params, b0))
        best = float("inf")
        for _ in range(5):
            t0 = time.time()
            for _ in range(50):
                out = f(st.params, b0)
            jax.block_until_ready(out)
            best = min(best, (time.time() - t0) / 50)
        ts[name] = best
    return round(min(1.0, max(0.0, ts["two"] - ts["one"]) / step_s), 4)


def bench_cada(iters: int = 300, lm_steps: int = 30) -> dict:
    """Headline perf numbers, tracked across PRs in ``BENCH_cada.json``:

      * engine throughput + communication saved, logreg-CADA2 vs always
        (distributed Adam), matched hyper-parameters, on the fused
        flat-plane hot path with donated state buffers. The cada2 arm
        runs the DEFAULT eval dispatch (stale-iterate ring + stacked
        ``fuse_evals`` two-point eval); ``cada2_unfused`` pins the
        two-call dispatch so the stacked win stays measured;
      * ``gating_overhead_frac`` = 1 − cada2/always steps/sec — what the
        adaptive rule COSTS per iteration (its savings are the uploads);
      * per arm: ``second_eval_frac`` (measured share of a step spent in
        the second gradient evaluation) and worker-state bytes (total
        comm state + the eval-point extras — the ring-vs-dense story);
      * an interleaved M-sweep micro-arm (M=10/256/2048) showing the
        ring's memory and steps/sec scaling (``m_sweep``);
      * an ``obs_overhead`` arm (interleaved best-of-N per-step loops)
        asserting the telemetry plane's contract: the disabled path
        (NULL tracer + unfed ledger) costs <2% steps/sec and the enabled
        path (real spans + every-8 metric fetch into a ledger) <10%;
      * trainer steps/sec on the LM path (ROADMAP's named next metric).

    Warns on stderr when any steps/sec regresses >10% vs the committed
    baseline or when the donated state fails to alias in the compiled
    module (a "donation" that silently copies); the alias count is also
    recorded per arm in the JSON.
    """
    import jax
    import numpy as np

    from repro.core.engine import CADAEngine, make_sampler
    from repro.core.rules import CommRule
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.models.small import logreg_init, logreg_loss
    from repro.optim.fused import FusedAMSGrad
    from repro.utils.hlo_cost import donation_aliases

    prev = _load_baseline()
    m = 10
    ds = ijcnn1_like(n=4000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 32)
    params = logreg_init(None, 22, 2)
    out = {"iters": iters, "workers": m}

    # compile all arms first, then INTERLEAVE the timed runs (best-of-N):
    # the gating_overhead_frac is a ratio, and sequential phases would
    # fold machine drift into it on shared boxes.
    variants = {
        "always": dict(kind="always"),
        "cada2": dict(kind="cada2"),
        "cada2_unfused": dict(kind="cada2", fuse_evals=False),
    }
    arms = {}
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(1), iters))
    for name, spec in variants.items():
        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.01),
                         CommRule(kind=spec["kind"], c=0.6, d_max=10,
                                  max_delay=100), m,
                         fuse_evals=spec.get("fuse_evals"))
        st = eng.init(params)
        compiled = jax.jit(eng.run, donate_argnums=(0,)).lower(
            st, batches).compile()
        aliased = donation_aliases(compiled.as_text())
        if aliased == 0:
            print("[cada] WARNING: donated engine state did not alias — "
                  "every run copies the full state", file=sys.stderr)
        st1, mets = compiled(jax.tree.map(lambda x: x.copy(), st),
                             batches)           # steady-state warmup
        jax.block_until_ready(st1.params)
        arms[name] = {"compiled": compiled, "st": st, "mets": mets,
                      "eng": eng, "aliased": aliased, "dt": float("inf")}
    for _ in range(5):
        for name, arm in arms.items():
            fresh = jax.tree.map(lambda x: x.copy(), arm["st"])
            t0 = time.time()
            st2, arm["mets"] = arm["compiled"](fresh, batches)
            jax.block_until_ready(st2.params)
            arm["dt"] = min(arm["dt"], time.time() - t0)
    for name, arm in arms.items():
        mets = arm["mets"]
        state_b, eval_b = _comm_state_bytes(arm["st"].comm)
        out[name] = {
            "steps_per_sec": round(iters / arm["dt"], 1),
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "uploads": int(np.asarray(mets["uploads"]).sum()),
            "mbytes_up": float(np.asarray(mets["bytes_up"]).sum() / 1e6),
            "donation_aliases": arm["aliased"],
            "worker_state_bytes": state_b,
            "eval_point_bytes": eval_b,
            "second_eval_frac": _second_eval_frac(
                arm["eng"], arm["st"], batches, arm["dt"] / iters),
        }
        _warn_if_regressed(f"engine-{name}", out[name]["steps_per_sec"],
                           (prev or {}).get(name))
    out["uploads_saved_frac"] = round(
        1.0 - out["cada2"]["uploads"] / out["always"]["uploads"], 3)
    out["gating_overhead_frac"] = round(
        1.0 - out["cada2"]["steps_per_sec"]
        / out["always"]["steps_per_sec"], 4)
    out["gating_overhead_frac_unfused"] = round(
        1.0 - out["cada2_unfused"]["steps_per_sec"]
        / out["always"]["steps_per_sec"], 4)
    out["m_sweep"] = _bench_m_sweep()
    out["obs_overhead"] = _bench_obs_overhead()

    lm = bench_trainer_lm(lm_steps)
    out.update(lm)
    for name in ("trainer_lm", "sharded_flat", "sharded_perleaf_ref"):
        _warn_if_regressed(f"trainer-{name}", lm[name]["steps_per_sec"],
                           (prev or {}).get(name))

    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[cada] {out['cada2']['steps_per_sec']} steps/s "
          f"(gating overhead {out['gating_overhead_frac']:.1%}), "
          f"{out['uploads_saved_frac']:.0%} uploads saved, "
          f"trainer-LM {out['trainer_lm']['steps_per_sec']} steps/s "
          f"(sharded-state hparams: flat "
          f"{out['sharded_flat']['steps_per_sec']} vs old per-leaf "
          f"fallback {out['sharded_perleaf_ref']['steps_per_sec']}) "
          f"-> {BENCH_PATH}", file=sys.stderr)
    return out


def _bench_obs_overhead(iters: int = 200, reps: int = 4) -> dict:
    """The telemetry plane's overhead contract, measured on the per-step
    host loop (the only place obs code runs — the scanned ``eng.run``
    path has no per-round host hook to instrument). The workload is the
    MNIST-like MLP at trainer scale (several ms/step): that is the loop
    ``launch/train.py --trace/--metrics-out`` instruments, and the obs
    costs are fixed per step (a ~1µs span, one 11-leaf metric fetch per
    ``metrics_every`` window), so a sub-ms microbenchmark step would
    measure jax dispatch overhead rather than the telemetry plane.

    Three arms over the same jitted single-step engine call, compiled
    first then interleaved chunk by chunk, best-of-many chunks:

      * ``baseline`` — bare loop, no obs code at all;
      * ``disabled`` — the instrumented loop with tracing off: a
        ``NULL`` tracer span per step plus the ledger-feed branch not
        taken. This is the path every untraced run pays;
      * ``enabled`` — a real :class:`~repro.obs.trace.Tracer` span per
        step and round metrics buffered on device, fetched every 8 steps
        into a :class:`~repro.obs.metrics.CommLedger`.

    Asserts ``obs_overhead_frac_disabled < 0.02`` and
    ``obs_overhead_frac_enabled < 0.10`` (fractions clamp at 0 — arms
    faster than baseline are machine noise, not negative overhead).
    """
    import jax

    from repro.core.engine import CADAEngine, make_sampler
    from repro.core.rules import CommRule
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import mnist_like
    from repro.models.small import mlp_init, mlp_loss
    from repro.obs import NULL, CommLedger, Tracer
    from repro.optim.fused import FusedAMSGrad

    m = 10
    ds = mnist_like(n=2048)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x.reshape(len(ds.x), -1), ds.y, mtx, 32)
    eng = CADAEngine(mlp_loss, FusedAMSGrad(lr=0.01),
                     CommRule(kind="cada2", c=0.6, d_max=10,
                              max_delay=100), m)
    st0 = eng.init(mlp_init(jax.random.PRNGKey(0), 784, 64, 10))
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(2), iters))
    step = jax.jit(eng.step, donate_argnums=(0,))

    def make_plain():
        st = [jax.tree.map(lambda x: x.copy(), st0)]

        def go(lo: int, hi: int) -> None:
            s = st[0]
            for i in range(lo, hi):
                s, _ = step(s, jax.tree.map(lambda x: x[i], batches))
            jax.block_until_ready(s.params)
            st[0] = s
        return go

    def make_obs(tracer, ledger):
        st = [jax.tree.map(lambda x: x.copy(), st0)]
        buf: list = []

        def go(lo: int, hi: int) -> None:
            s = st[0]
            for i in range(lo, hi):
                b = jax.tree.map(lambda x: x[i], batches)
                with tracer.span("train_step", track="train",
                                 args={"step": i}):
                    s, met = step(s, b)
                if ledger is not None:
                    buf.append(met)
                    if len(buf) >= 8:
                        for row in jax.device_get(buf):
                            ledger.observe_round(row)
                        buf.clear()
            jax.block_until_ready(s.params)
            st[0] = s
        return go

    arms = {
        "baseline": make_plain(),
        "disabled": make_obs(NULL, None),
        "enabled": make_obs(Tracer(),
                            CommLedger.for_strategy(eng.strategy)),
    }
    # Each sample times one CHUNK of steps, arms alternating chunk by
    # chunk; best-of-many chunks per arm. Fine-grained interleaving is
    # what makes a <2% assertion tenable on a noisy shared box — timing
    # whole loops back to back folds multi-percent machine drift into
    # the ratio (observed: spurious 2-4% on identical code paths).
    chunk = 25
    for go in arms.values():             # compile + steady-state warmup
        go(0, chunk)
    best = {k: float("inf") for k in arms}
    windows = [(lo, lo + chunk)
               for lo in range(chunk, iters - chunk + 1, chunk)]
    for _ in range(reps):
        for lo, hi in windows:
            for name, go in arms.items():
                t0 = time.time()
                go(lo, hi)
                best[name] = min(best[name], time.time() - t0)
    sps = {k: chunk / v for k, v in best.items()}
    dis = max(0.0, 1.0 - sps["disabled"] / sps["baseline"])
    ena = max(0.0, 1.0 - sps["enabled"] / sps["baseline"])
    assert dis < 0.02, (
        f"disabled obs path costs {dis:.1%} steps/sec (contract: <2%)")
    assert ena < 0.10, (
        f"enabled obs path costs {ena:.1%} steps/sec (contract: <10%)")
    return {
        "iters": iters,
        "steps_per_sec": {k: round(v, 1) for k, v in sps.items()},
        "obs_overhead_frac_disabled": round(dis, 4),
        "obs_overhead_frac_enabled": round(ena, 4),
    }


def _bench_m_sweep(ms=(10, 256, 2048), iters=(300, 100, 15),
                   cohort_c=64) -> dict:
    """The federated-magnitude micro-arm: cada2 (default eval dispatch) at
    M = 10 / 256 / 2048 on logreg, arms compiled first then INTERLEAVED
    best-of-3 — per M: steps/sec, the ring's eval-point bytes, and the
    dense O(M·n) plane it replaced. The ring holds R = min(M, D)+1 rows,
    so eval-point state saturates at (D+1)·n while the dense equivalent
    grows with M.

    The ``{M}/cohort{C}`` arm runs the SAME largest-M problem on the
    cohort-virtualized plane (host :class:`repro.core.flat.WorkerPool`,
    C sampled rows gathered per round): per-round compute drops from M
    gradient evaluations + an M-row aggregate to C of each, so its
    steps/sec over the dense arm is the tentpole's measured win. Every
    arm records the device/host byte split: the dense plane keeps the
    whole O(M·n) worker plane device-resident (``host_pool_bytes`` = 0),
    the cohort arm keeps O(C·n) on device and parks O(M·n) on the host.

    ``{M}/cohort{C}`` is the serial transfer oracle (``pipeline=False``);
    ``.../pipelined`` double-buffers the pool traffic under device
    compute and ``.../pipelined/memmap`` runs the same pipeline over a
    disk-backed pool. All three ride the same jitted step, so
    ``speedup_vs_serial`` isolates the transfer time the overlap hides;
    each arm also reports its per-round ``gather_ms/step_ms/scatter_ms``
    host-side phase breakdown, read from the obs trace recorder's
    ``"pipeline"``-track span aggregates (the one home for per-round
    phase timing — no bench-side clock arithmetic).
    """
    import jax
    import numpy as np

    from repro.core.engine import CADAEngine, make_sampler, sample_cohorts
    from repro.core.flat import layout_of
    from repro.core.rules import CommRule
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.models.small import logreg_init, logreg_loss
    from repro.obs.trace import Tracer
    from repro.optim.fused import FusedAMSGrad

    d = 100
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=d)
    params = logreg_init(None, 22, 2)
    n_flat = layout_of(params).n_flat
    arms = {}
    for m, its in zip(ms, iters):
        ds = ijcnn1_like(n=max(4000, 2 * m))
        mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
        sample = make_sampler(ds.x, ds.y, mtx, 8)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), its))
        eng = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.01), rule, m)
        st = eng.init(params)
        compiled = jax.jit(eng.run, donate_argnums=(0,)).lower(
            st, batches).compile()
        st1, _ = compiled(jax.tree.map(lambda x: x.copy(), st), batches)
        jax.block_until_ready(st1.params)
        arms[m] = {"compiled": compiled, "st": st, "batches": batches,
                   "iters": its, "dt": float("inf")}

    # cohort arms: same rule/problem/batch stream as the largest dense M,
    # only the C sampled rows exist on device per round. Three variants,
    # interleaved with the dense arms: the serial oracle
    # (pipeline=False), the double-buffered pipeline, and the pipeline
    # over a disk-backed memmap pool — the pipelined-vs-serial delta is
    # the transfer time the overlap hides, measured within ONE run.
    import shutil
    import tempfile

    m_big, its_big = ms[-1], iters[-1]
    eng_c = CADAEngine(logreg_loss, FusedAMSGrad(lr=0.01), rule, m_big)
    cohorts = sample_cohorts(m_big, cohort_c, its_big, seed=1)
    cohort_batches = [
        jax.tree.map(lambda x, i=i: x[i][cohorts[i]],
                     arms[m_big]["batches"]) for i in range(its_big)]
    memmap_dir = tempfile.mkdtemp(prefix="bench_pool_")
    variants = {
        "serial": {"pipeline": False, "storage": "ram", "path": None},
        "pipelined": {"pipeline": True, "storage": "ram", "path": None},
        "pipelined/memmap": {"pipeline": True, "storage": "memmap",
                             "path": memmap_dir},
    }

    def fresh_cohort(v):
        st, pool = eng_c.init_cohort(params, pool_storage=v["storage"],
                                     pool_path=v["path"])
        jax.block_until_ready(st.params_flat)
        return st, pool

    for v in variants.values():                         # compile + warmup
        st_w, pool_w = fresh_cohort(v)
        st_w, _ = eng_c.run_cohort(st_w, pool_w, cohort_batches, cohorts,
                                   pipeline=v["pipeline"])
        jax.block_until_ready(st_w.params_flat)
        v.update(dt=float("inf"), trace=Tracer(), pool=pool_w)

    for _ in range(3):
        for m, arm in arms.items():
            fresh = jax.tree.map(lambda x: x.copy(), arm["st"])
            t0 = time.time()
            st2, _ = arm["compiled"](fresh, arm["batches"])
            jax.block_until_ready(st2.params)
            arm["dt"] = min(arm["dt"], time.time() - t0)
        for v in variants.values():
            st_c, pool_c = fresh_cohort(v)
            tr = Tracer()
            t0 = time.time()
            st_c, _ = eng_c.run_cohort(st_c, pool_c, cohort_batches,
                                       cohorts, pipeline=v["pipeline"],
                                       trace=tr)
            jax.block_until_ready(st_c.params_flat)
            dt = time.time() - t0
            if dt < v["dt"]:
                v.update(dt=dt, trace=tr, pool=pool_c)
    shutil.rmtree(memmap_dir, ignore_errors=True)
    sweep = {}
    for m, arm in arms.items():
        _, eval_b = _comm_state_bytes(arm["st"].comm)
        sweep[str(m)] = {
            "workers": m,
            "iters": arm["iters"],
            "steps_per_sec": round(arm["iters"] / arm["dt"], 1),
            "ring_rows": min(m, d) + 1,
            "eval_point_bytes": eval_b,
            "dense_equiv_bytes": m * n_flat * 4,
            "device_worker_plane_bytes": m * n_flat * 4,
            "host_pool_bytes": 0,
        }
    sps_serial = round(its_big / variants["serial"]["dt"], 1)
    if sps_serial < 5 * sweep[str(m_big)]["steps_per_sec"]:
        print(f"[cada] WARNING: cohort arm at M={m_big} C={cohort_c} is "
              f"{sps_serial} steps/s vs dense "
              f"{sweep[str(m_big)]['steps_per_sec']} — below the 5x the "
              f"O(C·n) plane is supposed to buy", file=sys.stderr)
    for name, v in variants.items():
        sps = round(its_big / v["dt"], 1)
        pool_v = v["pool"]
        # per-round phase breakdown straight off the trace recorder's
        # span aggregates: {phase: {count, total_s, max_s}}
        agg = v["trace"].aggregate("pipeline")
        rounds = max(1, agg.get("step", {}).get("count", its_big))

        def phase_ms(phase, agg=agg, rounds=rounds):
            return round(agg.get(phase, {}).get("total_s", 0.0)
                         / rounds * 1e3, 3)

        key = (f"{m_big}/cohort{cohort_c}" if name == "serial"
               else f"{m_big}/cohort{cohort_c}/{name}")
        sweep[key] = {
            "workers": m_big,
            "cohort": cohort_c,
            "iters": its_big,
            "pipeline": v["pipeline"],
            "pool_storage": v["storage"],
            "steps_per_sec": sps,
            "gather_ms": phase_ms("gather"),
            "step_ms": phase_ms("step"),
            "scatter_ms": phase_ms("scatter"),
            "patch_ms": phase_ms("patch"),
            "device_worker_plane_bytes": pool_v.device_row_bytes(cohort_c),
            "host_pool_bytes": pool_v.nbytes,
            "host_pool_mapped_bytes": pool_v.mapped_nbytes,
            "host_pool_resident_bytes": pool_v.resident_nbytes,
            "speedup_vs_dense": round(
                sps / sweep[str(m_big)]["steps_per_sec"], 2),
        }
        if name != "serial":
            sweep[key]["speedup_vs_serial"] = round(sps / sps_serial, 2)
    if sweep[f"{m_big}/cohort{cohort_c}/pipelined"]["speedup_vs_serial"] \
            < 1.0:
        print(f"[cada] WARNING: pipelined cohort arm did not beat the "
              f"serial oracle within this run "
              f"({sweep[f'{m_big}/cohort{cohort_c}/pipelined']})",
              file=sys.stderr)
    return sweep


def bench_trainer_lm(steps: int = 30) -> dict:
    """Hierarchical-CADA trainer throughput on the (smoke) LM path.

    Three arms, INTERLEAVED per the 2-core caution (sequential phases
    fold machine drift into the comparison):

      * ``trainer_lm``       — the default hparams (fused flat plane);
      * ``sharded_flat``     — the same rule at
        ``state_fsdp_axes=("data",)``: the hparams that USED to force the
        per-leaf fallback (``_flat_enabled``) and now run the fused flat
        plane (mesh-free here, so same program as ``trainer_lm`` — a
        same-program control for the entry below);
      * ``sharded_perleaf_ref`` — those hparams on the per-leaf pytree
        path (``fused=False``), i.e. what the deleted fallback actually
        ran. ``sharded_flat`` vs ``sharded_perleaf_ref`` IS the
        fork-deletion perf trace: the speedup these policies gained by
        moving onto the flat plane.
    """
    import jax
    import numpy as np

    import repro.configs as C
    from repro.core.rules import CommRule
    from repro.distributed.trainer import (TrainHParams, init_train_state,
                                           make_train_step, worker_split)

    arch = "stablelm-1.6b"
    cfg = C.get_smoke_config(arch)
    m = 2
    rule = CommRule(kind="cada2", c=0.6, d_max=10, max_delay=50)
    variants = {
        "trainer_lm": TrainHParams(rule=rule, lr=1e-3),
        "sharded_flat": TrainHParams(rule=rule, lr=1e-3,
                                     state_fsdp_axes=("data",)),
        "sharded_perleaf_ref": TrainHParams(rule=rule, lr=1e-3,
                                            state_fsdp_axes=("data",),
                                            fused=False),
    }
    batch = worker_split(
        {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 65), 0,
                                      cfg.vocab)}, m)

    arms = {}
    for name, hp in variants.items():
        step = jax.jit(make_train_step(cfg, hp, m), donate_argnums=(0,))
        st0 = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))

        def fresh(st0=st0):
            # the step donates its state, so each rep gets copies of st0
            return jax.tree.map(lambda x: x.copy(), st0)

        st, mets = step(fresh(), batch)      # compile + warmup
        jax.block_until_ready(st.params)
        arms[name] = {"step": step, "fresh": fresh, "mets": mets,
                      "dt": float("inf")}
    for _ in range(3):                       # best-of-3, arms interleaved
        for name, arm in arms.items():
            # re-init per rep: continuing one trajectory across reps would
            # time DIFFERENT upload regimes (CADA uploads thin out as
            # training advances), making later reps incomparably cheaper
            st = arm["fresh"]()
            jax.block_until_ready(st)  # async state copy off the clock
            t0 = time.time()
            for _ in range(steps):
                st, arm["mets"] = arm["step"](st, batch)
            jax.block_until_ready(st.params)
            arm["dt"] = min(arm["dt"], time.time() - t0)
    return {name: {"arch": f"{arch}(smoke)", "workers": m, "rule": "cada2",
                   "state_fsdp_axes": list(variants[name].state_fsdp_axes),
                   "fused": variants[name].fused,
                   "steps_per_sec": round(steps / arm["dt"], 1),
                   "final_loss": float(np.asarray(arm["mets"]["loss"]))}
            for name, arm in arms.items()}


def bench_sim(iters: int = 300) -> dict:
    """Wall-clock CADA tracker, written to ``BENCH_sim.json``: the
    discrete-event runtime (repro.sim) prices the logreg trajectories
    under a zero-latency LAN and a WAN profile.

    The two committed claims (asserted here, so the JSON always records a
    state where they hold):

      * **WAN**: at least one compressed-upload rule (laq 8-bit / topk
        sparse-wire) beats ``always`` on simulated time-to-target-loss —
        skipping rounds AND shrinking wires earns wall-clock when uploads
        are expensive;
      * **zero-latency LAN**: ``always`` wins — when communication is
        free, the per-iteration-best rule is the wall-clock-best rule,
        and gating buys nothing.

    Plus the ``federated`` arm: the same MLP at **M = 10⁴ workers**,
    C = 64 cohort rounds on the cohort-virtualized plane
    (``cohort_size=``). The O(M·n) worker planes live in the host
    :class:`repro.core.flat.WorkerPool`; the device sees O(C·n) rows
    per round, so the scenario fits where a dense plane (which would
    materialize the (M, n_flat) plane AND an (iters, M, b, ...) batch
    stream on device) cannot — the CI ``federated-smoke`` leg re-runs
    this magnitude under a 6 GiB ``ulimit -v`` to pin that.

    Deterministic: fixed seeds, deterministic compute/link models — the
    committed file reproduces exactly (steps/sec caveats of BENCH_cada
    don't apply; simulated seconds are computed, not measured).
    """
    import jax

    # the problem (the ~1.6k-param MLP — on the 1 Mbit/s WAN uplink the
    # dense plane costs ~51 ms/upload, so the wire width is a first-order
    # wall-clock term) and the rule table are SHARED with
    # ablations.sweep_network: BENCH_sim.json and the sweep always
    # describe the same scenario
    from benchmarks.ablations import M as m, _mlp_problem, network_rules
    from repro.core.rules import CommRule
    from repro.models.small import mlp_loss
    from repro.sim import network_profile, simulate, summarize

    target = 0.05
    sample, params = _mlp_problem()
    loss_fn = mlp_loss
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(1), iters))
    rules = network_rules()

    # the adaptive local-steps arm: same problem, batches carrying a
    # (rounds, H, M, b, ·) local axis padded to the adaptation cap. Each
    # worker's H_m follows comm-vs-compute time (avp's period rule
    # generalized to local steps), so on the WAN H rides the cap (~16
    # local steps amortize one ~98 ms round trip) while on the free LAN
    # it shrinks to per-iteration rounds.
    h_pad, lrounds = 16, 120
    lbatches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(2), lrounds * h_pad))
    lbatches = jax.tree.map(
        lambda x: x.reshape((lrounds, h_pad) + x.shape[1:]), lbatches)
    local_rule = CommRule(kind="local_momentum", c=0.6, d_max=10,
                          max_delay=100, adapt_local_steps=True,
                          local_steps_max=h_pad, local_lr=0.05)

    # the fused second-eval discount (ComputeModel.second_eval_factor):
    # cada2's stacked two-point eval was measured (BENCH_cada,
    # second_eval_frac / gating_overhead) at roughly HALF the cost of a
    # full second pass, so the ``cada2/fused-eval`` arm prices eval_idx≥1
    # at 0.5 — wall-clock stops double-charging the optimization while
    # the plain ``cada2`` row keeps the paper's flat 2-evals pricing.
    fused_factor = 0.5
    out = {"iters": iters, "workers": m, "target_loss": target,
           "second_eval_factor_fused": fused_factor,
           "profiles": {}}
    for profile in ("zero", "wan"):
        prows = {}
        for name, rule in rules.items():
            res = simulate(loss_fn, rule, params, batches,
                           n_workers=m, network=profile, mode="barrier",
                           lr=0.01)
            prows[name] = summarize(res, target)
        # one bounded-staleness async arm on the same scenario (M× the
        # server versions: an async step carries 1/M of a sync round)
        res = simulate(loss_fn, rules["laq"], params, batches,
                       n_workers=m, network=profile, mode="async",
                       async_tau=20, lr=0.01)
        prows["laq/async"] = summarize(res, target)
        # cada2 with the second eval priced at the measured stacked cost
        # (same trajectory as the plain cada2 row — only compute pricing
        # differs, so the delta is pure second-eval wall-clock)
        prof_fused = network_profile(profile, m,
                                     second_eval_factor=fused_factor)
        res = simulate(loss_fn, rules["cada2"], params, batches,
                       n_workers=m, network=prof_fused, mode="barrier",
                       lr=0.01)
        prows["cada2/fused-eval"] = summarize(res, target)
        # adaptive local steps on this profile; the realized per-round
        # mean H is recorded so the JSON shows WHERE the cadence landed
        res = simulate(loss_fn, local_rule, params, lbatches,
                       n_workers=m, network=profile, mode="barrier",
                       lr=0.01)
        prows["local/adapt"] = {
            **summarize(res, target),
            "mean_local_steps": round(
                float(res.metrics["local_steps"].mean()), 2),
            "final_local_steps": round(
                float(res.metrics["local_steps"][-1].mean()), 2)}
        times = {k: v["time_to_target_s"] for k, v in prows.items()
                 if v["time_to_target_s"] is not None}
        winner = min(times, key=times.get) if times else None
        out["profiles"][profile] = {"rules": prows,
                                    "time_to_target_s": times,
                                    "winner": winner}
        print(f"[sim] {profile}: winner {winner} "
              f"({ {k: round(v, 4) for k, v in times.items()} })",
              file=sys.stderr)

    # the subsystem's acceptance claims, pinned: compressed wires win
    # wall-clock where uploads are expensive, never where they are free.
    # (A rule that never settles at the target is absent from `times` —
    # it loses against any rule that did.)
    wan = out["profiles"]["wan"]["time_to_target_s"]
    zero = out["profiles"]["zero"]["time_to_target_s"]
    compressed = [wan[k] for k in ("laq", "topk") if k in wan]
    assert compressed, f"no compressed rule reached the target on wan: {wan}"
    assert "always" not in wan or min(compressed) < wan["always"], wan
    assert "always" in zero, f"always never reached the target on zero: " \
        f"{zero}"
    assert zero["always"] <= min((zero[k] for k in ("laq", "topk")
                                  if k in zero), default=float("inf")), zero
    # the local-steps axis's claim: on the WAN, adapting the PAYLOAD
    # CADENCE (H local steps per delta upload) beats the best
    # per-iteration gating rule outright — rounds amortize the link
    # latency instead of merely skipping some uploads. On the free LAN
    # the ordering flips (H shrinks to 1 and the sgd(1.0)-server
    # averaging loses to gated Adam); recorded above, not asserted.
    gating = [wan[k] for k in ("always", "cada2", "laq", "topk")
              if k in wan]
    assert "local/adapt" in wan, \
        f"adaptive local steps never reached the target on wan: " \
        f"{out['profiles']['wan']['rules']['local/adapt']}"
    assert wan["local/adapt"] < min(gating), wan

    out["federated"] = _bench_sim_federated(params, loss_fn, rules)

    with open(SIM_BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[sim] -> {SIM_BENCH_PATH}", file=sys.stderr)
    return out


def _bench_sim_federated(params, loss_fn, rules,
                         m=10_000, c=64, rounds=60) -> dict:
    """The federated-magnitude arm of ``BENCH_sim.json``: the bench_sim
    MLP at M = 10⁴ workers, C-worker cohort rounds over the WAN profile.
    Batches come from :func:`repro.core.engine.make_cohort_sampler`
    (O(C·b) per round, never the (rounds, M, b, ...) dense stream), the
    worker planes from the host pool. The recorded byte split IS the
    tentpole claim: ``host_pool_bytes`` is the O(M·n) plane a dense run
    would hold on device, ``device_worker_plane_bytes`` the O(C·n) the
    cohort run actually does.

    lr is 1e-3 (not the LAN/WAN rows' 0.01): the eq. (3) aggregate
    divides the C uploaded rows by M, so at C/M = 0.64% the server's
    Adam direction is far noisier than at full participation and 0.01
    oscillates. Per-round losses stay noisy regardless — every worker
    holds 2 samples, and each round evaluates a fresh cohort."""
    import jax
    import numpy as np

    from repro.core.engine import make_cohort_sampler
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.sim import simulate, summarize

    ds = ijcnn1_like(n=2 * m)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    csample = make_cohort_sampler(ds.x, ds.y, mtx, 32)

    def batches(k, cohort):
        return csample(jax.random.PRNGKey(k), cohort)

    res = simulate(loss_fn, rules["cada2"], params, batches,
                   n_workers=m, network="wan", mode="barrier", lr=1e-3,
                   cohort_size=c, rounds=rounds)
    row = {"workers": m, "cohort_size": c, "rounds": rounds,
           "rule": "cada2",
           "host_pool_bytes": int(res.metrics["host_pool_bytes"]),
           "device_worker_plane_bytes": int(
               res.metrics["device_worker_plane_bytes"]),
           **summarize(res)}
    # the cohort plane's point, pinned in the committed JSON: device
    # worker-plane bytes are C/M of the pool (>100x smaller here), and
    # the run still LEARNS (deterministic seeds, so not flaky)
    assert row["device_worker_plane_bytes"] * (m // c) \
        <= row["host_pool_bytes"], row
    assert row["final_loss"] < float(np.asarray(res.losses)[0]), row
    print(f"[sim] federated M={m} C={c}: "
          f"{row['device_worker_plane_bytes']} device B vs "
          f"{row['host_pool_bytes']} host-pool B, "
          f"final_loss={row['final_loss']:.4f}", file=sys.stderr)
    return row


def bench_hierarchical(steps: int = 40) -> dict:
    """Hierarchical-CADA DCN-savings tracker, written to
    ``BENCH_hierarchical.json`` (previously its numbers only landed in
    the orphaned ``results/hierarchical_cada.json``)."""
    from benchmarks import hierarchical_cada

    rows = hierarchical_cada.run(steps=steps)
    by_rule = {r["rule"]: r for r in rows}
    always, cada = by_rule["always"], by_rule["cada2"]
    out = {
        "steps": steps,
        "rows": rows,
        "dcn_saved_frac": round(
            1.0 - cada["dcn_gbytes"] / always["dcn_gbytes"], 3),
        "delta_final_loss": round(
            cada["final_loss"] - always["final_loss"], 4),
    }
    with open(HIER_BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[hier] DCN saved {out['dcn_saved_frac']:.0%} at "
          f"dloss={out['delta_final_loss']:+.4f} -> {HIER_BENCH_PATH}",
          file=sys.stderr)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-list: logreg,nn,lag,hierarchical,"
                         "ablations,roofline,cada,sim")
    args = ap.parse_args()
    full = args.full
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def emit(bench, r):
        r = dict(r)
        r["bench"] = bench
        rows.append(r)

    if only is None or "cada" in only:
        b = bench_cada(iters=600 if full else 300)
        for kind in ("always", "cada2"):
            emit("bench_cada(BENCH_cada.json)",
                 {"rule": kind, **b[kind]})

    if only is None or "logreg" in only:
        from benchmarks import paper_logreg
        t0 = time.time()
        for ds in ("covtype", "ijcnn1"):
            for r in paper_logreg.run(ds, iters=1000 if full else 500,
                                      monte_carlo=3 if full else 1):
                emit("paper_logreg(Fig2-3)", r)
        print(f"[logreg done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "nn" in only:
        from benchmarks import paper_nn
        t0 = time.time()
        for model in (("cnn", "mlp") if full else ("mlp",)):
            for r in paper_nn.run(model=model,
                                  iters=800 if full else 300):
                emit("paper_nn(Fig4)", r)
        print(f"[nn done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "lag" in only:
        from benchmarks import lag_ineffectiveness
        for r in lag_ineffectiveness.run(iters=800 if full else 400):
            emit("lag_ineffectiveness(§2.1)", r)

    if only is None or "sim" in only:
        b = bench_sim(iters=600 if full else 300)
        for profile, p in b["profiles"].items():
            for rule, r in p["rules"].items():
                emit("bench_sim(BENCH_sim.json)",
                     {"rule": rule, "profile": profile, **r})

    if only is None or {"hier", "hierarchical"} & only:
        b = bench_hierarchical(steps=80 if full else 40)
        for r in b["rows"]:
            emit("hierarchical_cada(BENCH_hierarchical.json)", r)

    if only is None or "ablations" in only:
        from benchmarks import ablations
        iters = 600 if full else 300
        for r in (ablations.sweep_c(iters) + ablations.sweep_D(iters)
                  + ablations.sweep_bits(iters)
                  + ablations.sweep_rules(iters)
                  + ablations.sweep_avp(iters)
                  + ablations.sweep_network(min(iters, 300))
                  + ablations.sweep_H(iters)):
            emit("ablations(supplement)", r)

    if only is None or "roofline" in only:
        from benchmarks import roofline
        rl = roofline.load(["results/dryrun_single.jsonl",
                            "results/dryrun_multi.jsonl"])
        for r in rl:
            emit("roofline(§Dry-run)", {
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "dominant": r["dominant"],
                "t_compute_s": r["t_compute_s"],
                "t_memory_s": r["t_memory_s"],
                "t_collective_s": r["t_collective_s"],
                "useful": r["useful_flops_ratio"]})

    # ------------------------------------------------------------- CSV out
    keys = ["bench"]
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


if __name__ == "__main__":
    main()
