"""Benchmark aggregator: one entry per paper table/figure + the beyond-paper
benches. Prints a CSV summary and writes per-bench JSON under results/.

  python -m benchmarks.run            # fast settings (CI-sized)
  python -m benchmarks.run --full     # paper-sized iteration counts
  python -m benchmarks.run --only cada   # just the BENCH_cada.json tracker

Every run also refreshes ``BENCH_cada.json`` (steps/sec of the jitted
engine + uploads saved by CADA2 vs distributed Adam on the logreg problem)
so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCH_PATH = "BENCH_cada.json"


def bench_cada(iters: int = 300) -> dict:
    """Headline perf numbers: engine throughput and communication saved,
    logreg-CADA2 vs always (distributed Adam), matched hyper-parameters."""
    import jax
    import numpy as np

    from repro.core.engine import CADAEngine, make_sampler
    from repro.core.rules import CommRule
    from repro.data.partition import pad_to_matrix, uniform_partition
    from repro.data.synthetic import ijcnn1_like
    from repro.models.small import logreg_init, logreg_loss
    from repro.optim.adam import adam

    m = 10
    ds = ijcnn1_like(n=4000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 32)
    params = logreg_init(None, 22, 2)
    out = {"iters": iters, "workers": m}
    for kind in ("always", "cada2"):
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind=kind, c=0.6, d_max=10,
                                  max_delay=100), m)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        run = jax.jit(eng.run)
        st1, mets = run(st, batches)          # compile + first run
        jax.block_until_ready(st1.params)
        t0 = time.time()
        st2, mets = run(st, batches)          # timed steady-state run
        jax.block_until_ready(st2.params)
        dt = time.time() - t0
        out[kind] = {
            "steps_per_sec": round(iters / dt, 1),
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "uploads": int(np.asarray(mets["uploads"]).sum()),
            "mbytes_up": float(np.asarray(mets["bytes_up"]).sum() / 1e6),
        }
    out["uploads_saved_frac"] = round(
        1.0 - out["cada2"]["uploads"] / out["always"]["uploads"], 3)
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[cada] {out['cada2']['steps_per_sec']} steps/s, "
          f"{out['uploads_saved_frac']:.0%} uploads saved "
          f"-> {BENCH_PATH}", file=sys.stderr)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-list: logreg,nn,lag,hier,ablations,"
                         "roofline,cada")
    args = ap.parse_args()
    full = args.full
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def emit(bench, r):
        r = dict(r)
        r["bench"] = bench
        rows.append(r)

    if only is None or "cada" in only:
        b = bench_cada(iters=600 if full else 300)
        for kind in ("always", "cada2"):
            emit("bench_cada(BENCH_cada.json)",
                 {"rule": kind, **b[kind]})

    if only is None or "logreg" in only:
        from benchmarks import paper_logreg
        t0 = time.time()
        for ds in ("covtype", "ijcnn1"):
            for r in paper_logreg.run(ds, iters=1000 if full else 500,
                                      monte_carlo=3 if full else 1):
                emit("paper_logreg(Fig2-3)", r)
        print(f"[logreg done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "nn" in only:
        from benchmarks import paper_nn
        t0 = time.time()
        for model in (("cnn", "mlp") if full else ("mlp",)):
            for r in paper_nn.run(model=model,
                                  iters=800 if full else 300):
                emit("paper_nn(Fig4)", r)
        print(f"[nn done in {time.time() - t0:.0f}s]", file=sys.stderr)

    if only is None or "lag" in only:
        from benchmarks import lag_ineffectiveness
        for r in lag_ineffectiveness.run(iters=800 if full else 400):
            emit("lag_ineffectiveness(§2.1)", r)

    if only is None or "hier" in only:
        from benchmarks import hierarchical_cada
        for r in hierarchical_cada.run(steps=80 if full else 40):
            emit("hierarchical_cada(beyond-paper)", r)

    if only is None or "ablations" in only:
        from benchmarks import ablations
        iters = 600 if full else 300
        for r in (ablations.sweep_c(iters) + ablations.sweep_D(iters)
                  + ablations.sweep_bits(iters)
                  + ablations.sweep_rules(iters)
                  + ablations.sweep_H(iters)):
            emit("ablations(supplement)", r)

    if only is None or "roofline" in only:
        from benchmarks import roofline
        rl = roofline.load(["results/dryrun_single.jsonl",
                            "results/dryrun_multi.jsonl"])
        for r in rl:
            emit("roofline(§Dry-run)", {
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "dominant": r["dominant"],
                "t_compute_s": r["t_compute_s"],
                "t_memory_s": r["t_memory_s"],
                "t_collective_s": r["t_collective_s"],
                "useful": r["useful_flops_ratio"]})

    # ------------------------------------------------------------- CSV out
    keys = ["bench"]
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


if __name__ == "__main__":
    main()
