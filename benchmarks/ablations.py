"""Hyper-parameter ablations mirroring the paper's supplementary studies.

  * CADA threshold c sweep     — skip rate / final loss trade-off (the
    paper's per-algorithm grid, Figs 2-5 setup).
  * max-delay D sweep          — staleness cap vs convergence (paper uses
    D=100 logreg / D=50 NN).
  * averaging-period H sweep   — FedAdam / local momentum under H ∈
    {1, 8, 16} (paper supplementary Figs 6-7: larger H converges faster
    early but plateaus higher).
  * rule-strategy sweep        — every strategy registered in
    repro.core.comm (the four paper rules + beyond-paper ones such as the
    compressed-innovation rule) at matched hyper-parameters: final loss vs
    uploads vs bytes actually sent. New strategies appear here with no
    benchmark change.
  * network sweep              — the sim runtime (repro.sim) prices the
    same trajectories under LAN/WAN/heterogeneous-cluster profiles:
    simulated time-to-target-loss, bytes on wire, worker utilization per
    (profile, rule). This is where the compressed-upload rules' byte
    savings become WALL-CLOCK savings (and where they cost, on free
    links).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import run_engine_algo, save_rows
from repro.core.comm import STRATEGIES, strategy_kinds
from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss, mlp_init, mlp_loss
from repro.optim.adam import adam

M = 10


def _problem():
    ds = ijcnn1_like(n=4000)
    mtx = pad_to_matrix(uniform_partition(ds.n, M, seed=0))
    return (make_sampler(ds.x, ds.y, mtx, 32),
            logreg_init(None, 22, 2))


def _mlp_problem():
    """The wall-clock benches' problem (shared with run.py's bench_sim):
    the ~1.6k-param MLP, big enough that the dense plane costs ~51 ms on
    the WAN's 1 Mbit/s uplink — the wire width is a first-order
    wall-clock term (logreg's 184 B disappears under the 20 ms
    latency)."""
    ds = ijcnn1_like(n=4000)
    mtx = pad_to_matrix(uniform_partition(ds.n, M, seed=0))
    return (make_sampler(ds.x, ds.y, mtx, 32),
            mlp_init(jax.random.PRNGKey(7), 22, 64, 2))


def network_rules() -> dict:
    """The rule table the wall-clock benches compare (shared with run.py's
    bench_sim, so BENCH_sim.json and the ablations sweep always describe
    the SAME scenario): the upload-everything baseline, the paper rule,
    and the two compressed wires."""
    return {
        "always": CommRule(kind="always", c=0.6, d_max=10, max_delay=100),
        "cada2": CommRule(kind="cada2", c=0.6, d_max=10, max_delay=100),
        "laq": CommRule(kind="laq", c=0.6, d_max=10, max_delay=100),
        "topk": CommRule(kind="topk", c=0.6, d_max=10, max_delay=100,
                         topk_frac=0.1, sparse_wire=True),
    }


def sweep_c(iters=400, cs=(0.0, 0.1, 0.3, 1.0, 3.0, 10.0)) -> list[dict]:
    sample, params = _problem()
    rows = []
    for c in cs:
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind="cada2", c=c, d_max=10,
                                  max_delay=100), M)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        rows.append({
            "sweep": "c", "c": c,
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "skip_rate": float(np.asarray(mets["skip_rate"]).mean()),
            "uploads": int(np.asarray(mets["uploads"]).sum()),
        })
        print(f"  c={c:<6} loss={rows[-1]['final_loss']:.4f} "
              f"skip={rows[-1]['skip_rate']:.2f}")
    return rows


def sweep_D(iters=400, ds_=(5, 20, 50, 100, 400)) -> list[dict]:
    sample, params = _problem()
    rows = []
    for D in ds_:
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind="cada2", c=1.0, d_max=10,
                                  max_delay=D), M)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        rows.append({
            "sweep": "D", "D": D,
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "skip_rate": float(np.asarray(mets["skip_rate"]).mean()),
            "max_staleness": int(np.asarray(mets["max_staleness"]).max()),
        })
        print(f"  D={D:<4} loss={rows[-1]['final_loss']:.4f} "
              f"skip={rows[-1]['skip_rate']:.2f} "
              f"max_tau={rows[-1]['max_staleness']}")
    return rows


def sweep_bits(iters=400, bits_list=(0, 8, 4)) -> list[dict]:
    """Beyond-paper: LAQ-style quantized innovations composed with the
    CADA2 rule — bytes uploaded vs final loss."""
    sample, params = _problem()
    rows = []
    for bits in bits_list:
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind="cada2", c=0.6, d_max=10,
                                  max_delay=100, quantize_bits=bits), M)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        rows.append({
            "sweep": "bits", "bits": bits,
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "mbytes_up": float(np.asarray(mets["bytes_up"]).sum() / 1e6),
        })
        print(f"  bits={bits or 32:<3} loss={rows[-1]['final_loss']:.4f} "
              f"upload={rows[-1]['mbytes_up']:.3f} MB")
    return rows


def sweep_rules(iters=400) -> list[dict]:
    """Every registered communication strategy on one problem: the
    loss/uploads/bytes trade-off surface of the whole rule family.
    ``bytes_per_upload`` makes the compressed-upload rules (cinn/laq/topk)
    comparable to the skip-only rules at EQUAL upload counts."""
    sample, params = _problem()
    rows = []
    for kind in strategy_kinds():
        rule = CommRule(kind=kind, c=0.6, d_max=10, max_delay=100,
                        local_lr=0.05, server_lr=0.01)
        # delta-payload rules prescribe their own server (sgd(1.0) /
        # server Adam); optimizer=None lets the engine resolve it. At the
        # default H=1 they consume the same (M, b, ·) batch stream.
        opt = None if STRATEGIES[kind].delta_payload else adam(lr=0.01)
        eng = CADAEngine(logreg_loss, opt, rule, M)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        uploads = int(np.asarray(mets["uploads"]).sum())
        mbytes = float(np.asarray(mets["bytes_up"]).sum() / 1e6)
        rows.append({
            "sweep": "rule", "rule": kind,
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "skip_rate": float(np.asarray(mets["skip_rate"]).mean()),
            "uploads": uploads,
            "mbytes_up": mbytes,
            "bytes_per_upload": round(mbytes * 1e6 / max(uploads, 1), 2),
            "grad_evals": int(np.asarray(mets["grad_evals"]).sum()),
        })
        print(f"  rule={kind:7s} loss={rows[-1]['final_loss']:.4f} "
              f"skip={rows[-1]['skip_rate']:.2f} "
              f"upload={rows[-1]['mbytes_up']:.3f} MB "
              f"({rows[-1]['bytes_per_upload']} B/upload)")
    # the compressed-upload rules must beat full-width fp32 uploads at
    # equal upload counts — the whole point of shrinking the wire
    per_up = {r["rule"]: r["bytes_per_upload"] for r in rows}
    for kind in ("cinn", "laq", "topk"):
        assert per_up[kind] < per_up["always"], (kind, per_up)
    return rows


def sweep_avp(iters=400) -> list[dict]:
    """avp's period gate alone vs composed with the CADA LHS check
    (``avp_compose``: upload only when due AND the innovation energy
    clears the RHS). Pointwise (same state) the composed gate is a
    SUBSET of the plain one, but over a full run the veto changes the
    period dynamics (skipped uploads keep staleness high, so shrunken
    periods fire more often) — total uploads can land on either side;
    this sweep records the realized loss/communication trade."""
    sample, params = _problem()
    rows = []
    for compose in (False, True):
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind="avp", c=0.6, d_max=10,
                                  max_delay=100, period_min=1,
                                  period_max=8, avp_compose=compose), M)
        st = eng.init(params)
        batches = jax.vmap(sample)(
            jax.random.split(jax.random.PRNGKey(1), iters))
        _, mets = jax.jit(eng.run)(st, batches)
        rows.append({
            "sweep": "avp", "avp_compose": compose,
            "final_loss": float(np.asarray(mets["loss"])[-20:].mean()),
            "skip_rate": float(np.asarray(mets["skip_rate"]).mean()),
            "uploads": int(np.asarray(mets["uploads"]).sum()),
        })
        print(f"  avp compose={compose!s:5} "
              f"loss={rows[-1]['final_loss']:.4f} "
              f"skip={rows[-1]['skip_rate']:.2f} "
              f"uploads={rows[-1]['uploads']}")
    assert all(r["uploads"] > 0 for r in rows), rows  # cap still forces
    return rows


def sweep_network(iters=300, profiles=("lan", "wan", "hetero"),
                  target_loss=0.05) -> list[dict]:
    """Wall-clock CADA: one problem, one batch stream, every (network
    profile × rule) pair through the discrete-event runtime. The WAN rows
    are the subsystem's point: a compressed rule (laq 8-bit or topk
    sparse-wire) must beat ``always`` on simulated time-to-target-loss
    when uploads are expensive — while on a (near-)free LAN the
    per-iteration-best rule wins. One async bounded-staleness row per
    profile records the barrier-free runtime on the same scenario."""
    from repro.sim import simulate, summarize

    sample, params = _mlp_problem()
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(1), iters))
    rules = network_rules()
    rows = []
    for profile in profiles:
        for name, rule in rules.items():
            res = simulate(mlp_loss, rule, params, batches,
                           n_workers=M, network=profile, mode="barrier",
                           lr=0.01)
            rows.append({"sweep": "network", "profile": profile,
                         "rule": name, **summarize(res, target_loss)})
            r = rows[-1]
            print(f"  {profile:6s} {name:7s} t_target="
                  f"{r['time_to_target_s']} s  wall={r['sim_wall_s']:.3f}s "
                  f"up={r['mbytes_up']:.4f}MB util={r['utilization_mean']}")
        res = simulate(mlp_loss, rules["cada2"], params, batches,
                       n_workers=M, network=profile, mode="async",
                       async_tau=20, lr=0.01)
        rows.append({"sweep": "network", "profile": profile,
                     "rule": "cada2/async", **summarize(res, target_loss)})
        r = rows[-1]
        print(f"  {profile:6s} cada2/async t_target="
              f"{r['time_to_target_s']} s  wall={r['sim_wall_s']:.3f}s "
              f"util={r['utilization_mean']}")
        # the local-steps cadence on the SAME batch stream, reshaped to
        # (rounds, H, M, b, ·): where rounds are priced at H local steps
        # per download/upload, delta payloads buy wall-clock on expensive
        # links and lose it on free ones (recorded; run.py's bench_sim
        # arm asserts the WAN win)
        h_pad = 8
        rounds = iters // h_pad
        lb = jax.tree.map(
            lambda x: x[:rounds * h_pad].reshape(
                (rounds, h_pad) + x.shape[1:]), batches)
        for name, lrule in (
                ("local/H8", CommRule(
                    kind="local_momentum", c=0.6, d_max=10, max_delay=100,
                    local_steps=h_pad, local_lr=0.05)),
                ("local/adapt", CommRule(
                    kind="local_momentum", c=0.6, d_max=10, max_delay=100,
                    adapt_local_steps=True, local_steps_max=h_pad,
                    local_lr=0.05)),
        ):
            res = simulate(mlp_loss, lrule, params, lb, n_workers=M,
                           network=profile, mode="barrier", lr=0.01)
            rows.append({"sweep": "network", "profile": profile,
                         "rule": name, **summarize(res, target_loss)})
            r = rows[-1]
            print(f"  {profile:6s} {name:11s} t_target="
                  f"{r['time_to_target_s']} s  wall={r['sim_wall_s']:.3f}s "
                  f"up={r['mbytes_up']:.4f}MB")
    # the subsystem's raison d'être, asserted: expensive uploads (WAN) make
    # a compressed wire a WALL-CLOCK win over always-upload (checkable
    # only when the wan profile was part of this sweep)
    if "wan" in profiles:
        wan = {r["rule"]: r for r in rows if r["profile"] == "wan"}
        compressed = [wan[k]["time_to_target_s"] for k in ("laq", "topk")
                      if wan[k]["time_to_target_s"] is not None]
        assert compressed, \
            f"no compressed rule reached the target on wan: {wan}"
        t_always = wan["always"]["time_to_target_s"]
        # an 'always' that never settles at the target loses trivially
        assert t_always is None or min(compressed) < t_always, wan
    return rows


def sweep_H(iters=400, hs=(1, 8, 16)) -> list[dict]:
    sample, params = _problem()
    rows = []
    for algo in ("local_momentum", "fedadam"):
        for h in hs:
            res = run_engine_algo(algo, logreg_loss, params, sample, m=M,
                                  iters=iters, lr=0.01, h_period=h,
                                  lag_lr=0.05)
            first = float(np.mean(res.loss[:40]))
            rows.append({
                "sweep": "H", "algo": algo, "H": h,
                "early_loss": first,
                "final_loss": float(np.mean(res.loss[-40:])),
            })
            print(f"  {algo:15s} H={h:<3} early={first:.4f} "
                  f"final={rows[-1]['final_loss']:.4f}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--iters", type=int, default=400)
    args = p.parse_args()
    rows = (sweep_c(args.iters) + sweep_D(args.iters)
            + sweep_bits(args.iters) + sweep_rules(args.iters)
            + sweep_avp(args.iters) + sweep_network(min(args.iters, 300))
            + sweep_H(args.iters))
    # paper supplement claims, asserted:
    c_rows = [r for r in rows if r["sweep"] == "c"]
    assert c_rows[0]["skip_rate"] < 0.02          # c=0 => no skipping
    assert c_rows[-1]["skip_rate"] > 0.5          # large c => heavy skipping
    h_rows = [r for r in rows if r["sweep"] == "H"
              and r["algo"] == "local_momentum"]
    h1 = next(r for r in h_rows if r["H"] == 1)
    h16 = next(r for r in h_rows if r["H"] == 16)
    print(f"[supp] local momentum: H=16 final {h16['final_loss']:.4f} vs "
          f"H=1 {h1['final_loss']:.4f} (larger H plateaus higher: "
          f"{h16['final_loss'] > h1['final_loss']})")
    print(f"saved {save_rows('ablations', rows)}")


if __name__ == "__main__":
    main()
