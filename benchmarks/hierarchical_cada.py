"""Beyond-paper: hierarchical CADA across pods — DCN bytes actually saved.

Runs the distributed trainer (smoke-scale arch, host mesh standing in for
the pod axis) and converts the measured skip rate into cross-pod DCN bytes:
every skipped round removes one full-gradient innovation transfer
(≈ cada_dtype_bytes × P per worker). Reports bytes saved vs distributed
AMSGrad at matched loss.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.configs as C
from benchmarks.common import save_rows
from repro.core.rules import CommRule
from repro.distributed.trainer import (TrainHParams, init_train_state,
                                       make_train_step, worker_split)
from repro.models.config import param_count


def run(arch: str = "internlm2-1.8b", steps: int = 60, m: int = 4,
        c: float = 1.0) -> list[dict]:
    cfg = C.get_smoke_config(arch)
    p = param_count(cfg)
    rows = []
    for kind in ("always", "cada2"):
        hp = TrainHParams(rule=CommRule(kind=kind, c=c, d_max=5,
                                        max_delay=20), lr=1e-3)
        step = jax.jit(make_train_step(cfg, hp, m))
        st = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
        losses, uploads = [], 0
        for i in range(steps):
            key = jax.random.PRNGKey(100 + i)
            batch = worker_split(
                {"tokens": jax.random.randint(key, (8, 65), 0, cfg.vocab)},
                m)
            st, mets = step(st, batch)
            losses.append(float(mets["loss"]))
            uploads += int(mets["uploads"])
        bytes_per_upload = 4 * p  # fp32 innovation tree over DCN
        row = {
            "rule": kind, "arch": arch, "steps": steps, "workers": m,
            "final_loss": float(np.mean(losses[-10:])),
            "uploads": uploads,
            "dcn_gbytes": uploads * bytes_per_upload / 1e9,
        }
        rows.append(row)
        print(f"  {kind:7s} loss={row['final_loss']:.3f} "
              f"uploads={uploads}/{steps * m} "
              f"DCN={row['dcn_gbytes']:.2f} GB")
    always, cada = rows
    saving = 1 - cada["dcn_gbytes"] / always["dcn_gbytes"]
    print(f"[hier-cada] DCN bytes saved {saving:.0%} at Δloss="
          f"{cada['final_loss'] - always['final_loss']:+.3f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--c", type=float, default=1.0)
    args = ap.parse_args()
    rows = run(steps=args.steps, c=args.c)
    print(f"saved {save_rows('hierarchical_cada', rows)}")


if __name__ == "__main__":
    main()
