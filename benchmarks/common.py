"""Shared benchmark harness for the paper's experiments (§4).

Each figure-bench runs the same protocol: M workers, a loss, a sampler,
one engine per algorithm {adam, cada1, cada2, lag, local_momentum, fedadam},
recording loss / cumulative uploads / cumulative gradient evaluations per
iteration — the three x-axes of the paper's Figures 2-5.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.optim.adam import adam
from repro.optim.sgd import sgd

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


@dataclass
class RunResult:
    algo: str
    loss: np.ndarray        # (iters,)
    uploads: np.ndarray     # (iters,) cumulative
    grad_evals: np.ndarray  # (iters,) cumulative
    wall_s: float

    def row(self) -> dict:
        return {
            "algo": self.algo,
            "final_loss": float(np.mean(self.loss[-10:])),
            "total_uploads": int(self.uploads[-1]),
            "total_grad_evals": int(self.grad_evals[-1]),
            "iters": len(self.loss),
            "wall_s": round(self.wall_s, 2),
        }


def run_engine_algo(algo: str, loss_fn, params, sample, *, m: int,
                    iters: int, lr: float, c: float = 0.6, d_max: int = 10,
                    max_delay: int = 100, h_period: int = 10,
                    lag_lr: float = 0.1, seed: int = 1,
                    monte_carlo: int = 1) -> RunResult:
    """One algorithm on one problem; averaged over ``monte_carlo`` runs."""
    losses, ups, evals = [], [], []
    t0 = time.time()
    for mc in range(monte_carlo):
        key = jax.random.PRNGKey(seed + 1000 * mc)
        if algo in ("adam", "cada1", "cada2", "lag"):
            kind = "always" if algo == "adam" else algo
            opt = (adam(lr=lr) if algo != "lag" else sgd(lr=lag_lr))
            eng = CADAEngine(loss_fn, opt,
                             CommRule(kind=kind, c=c, d_max=d_max,
                                      max_delay=max_delay), m)
            st = eng.init(params)
            batches = jax.vmap(sample)(jax.random.split(key, iters))
            _, mets = jax.jit(eng.run)(st, batches)
            losses.append(np.asarray(mets["loss"]))
            ups.append(np.cumsum(np.asarray(mets["uploads"])))
            evals.append(np.cumsum(np.asarray(mets["grad_evals"])))
        elif algo in ("local_momentum", "fedadam"):
            # strategy-layer delta-payload rules (core/local_update.py);
            # the seed LocalUpdateEngine survives only as the parity
            # oracle (tests/test_local_steps.py pins the trajectories)
            eng = CADAEngine(
                loss_fn, None,  # None = the rule's prescribed server
                CommRule(kind=algo, c=c, d_max=d_max, max_delay=max_delay,
                         local_steps=h_period, local_lr=lag_lr,
                         server_lr=lr), m)
            st = eng.init(params)
            rounds = iters // h_period
            batches = jax.vmap(sample)(jax.random.split(key,
                                                        rounds * h_period))
            batches = jax.tree.map(
                lambda x: x.reshape((rounds, h_period) + x.shape[1:]),
                batches)
            _, mets = jax.jit(eng.run)(st, batches)
            # per-round loss spread back to the per-iteration x-axis
            losses.append(np.repeat(np.asarray(mets["loss"]), h_period))
            ups.append(np.cumsum(
                np.repeat(np.asarray(mets["uploads"]), h_period)
                / h_period))
            evals.append(np.cumsum(
                np.repeat(np.asarray(mets["grad_evals"]), h_period)
                / h_period))
        else:
            raise ValueError(algo)
    return RunResult(algo, np.mean(losses, axis=0), np.mean(ups, axis=0),
                     np.mean(evals, axis=0), time.time() - t0)


def uploads_to_target(res: RunResult, target_loss: float) -> int | None:
    """Communication complexity: cumulative uploads at the first iteration
    after which the (smoothed) loss stays at/below ``target_loss`` for the
    rest of the run — the paper's headline metric, made transient-proof."""
    w = 10
    smooth = np.convolve(res.loss, np.ones(w) / w, mode="valid")
    # suffix max: smallest i with max(smooth[i:]) <= target
    suffix_max = np.maximum.accumulate(smooth[::-1])[::-1]
    ok = suffix_max <= target_loss * 1.02
    if not ok.any():
        return None
    hit = int(np.argmax(ok))
    return int(res.uploads[min(hit + w - 1, len(res.uploads) - 1)])


def save_rows(name: str, rows: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path
