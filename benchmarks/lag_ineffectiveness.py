"""Paper §2.1 / eq. (6): why stochastic LAG stops skipping.

The LAG rule compares gradients at DIFFERENT samples, so its LHS is lower-
bounded by the (non-vanishing) gradient variance while its RHS → 0 as the
iterates converge. CADA's variance-reduced innovations keep the LHS
commensurate with the RHS. We measure, per rule, the skip rate over time
and the LHS/RHS trajectories — the skip rate of LAG must collapse while
CADA2's stays high late in training.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import save_rows
from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss
from repro.optim.adam import adam


def run(iters: int = 800, m: int = 10, c: float = 1.0) -> list[dict]:
    ds = ijcnn1_like(n=4000)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    sample = make_sampler(ds.x, ds.y, mtx, 32)
    params = logreg_init(None, 22, 2)

    # LAG and CADA2 share the same c (their LHS are commensurate gradient-
    # difference norms — the comparison eq. (6) makes); CADA1's snapshot
    # innovation lives on a different scale (Fig-2/3 grid: ~10x).
    per_rule_c = {"lag": c, "cada1": 10.0 * c, "cada2": c}
    rows = []
    for kind in ("lag", "cada1", "cada2"):
        eng = CADAEngine(logreg_loss, adam(lr=0.01),
                         CommRule(kind=kind, c=per_rule_c[kind], d_max=10,
                                  max_delay=100), m)
        st = eng.init(params)
        batches = jax.vmap(sample)(jax.random.split(jax.random.PRNGKey(1),
                                                    iters))
        _, mets = jax.jit(eng.run)(st, batches)
        skip = np.asarray(mets["skip_rate"])
        lhs = np.asarray(mets["mean_lhs"])
        rhs = np.asarray(mets["rhs"])
        q = iters // 4
        row = {
            "rule": kind,
            "skip_rate_q1": float(skip[:q].mean()),
            "skip_rate_q4": float(skip[-q:].mean()),
            "lhs_over_rhs_q4": float((lhs[-q:] / np.maximum(rhs[-q:],
                                                            1e-12)).mean()),
            "final_loss": float(np.asarray(mets["loss"])[-10:].mean()),
        }
        rows.append(row)
        print(f"  {kind:6s} skip q1={row['skip_rate_q1']:.2f} "
              f"q4={row['skip_rate_q4']:.2f} "
              f"LHS/RHS(q4)={row['lhs_over_rhs_q4']:.2e}")

    lag = {r["rule"]: r for r in rows}["lag"]
    cada = {r["rule"]: r for r in rows}["cada2"]
    print(f"[claim §2.1] LAG skip collapses "
          f"{lag['skip_rate_q1']:.2f} -> {lag['skip_rate_q4']:.2f} "
          f"(its LHS/RHS stays {lag['lhs_over_rhs_q4']:.1e}); "
          f"CADA2 sustains {cada['skip_rate_q1']:.2f} -> "
          f"{cada['skip_rate_q4']:.2f}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--iters", type=int, default=800)
    p.add_argument("--c", type=float, default=1.0)
    args = p.parse_args()
    rows = run(iters=args.iters, c=args.c)
    print(f"saved {save_rows('lag_ineffectiveness', rows)}")


if __name__ == "__main__":
    main()
