"""Roofline report: renders the dry-run JSONL rows (produced by
``python -m repro.launch.dryrun --all --out ...``) into the EXPERIMENTS.md
§Roofline table and flags the dominant term per (arch × shape × mesh).

This module does NOT lower anything itself (the dry-run needs 512 fake
devices; benches run with 1) — it is the analysis/reporting half.
"""
from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict

HW_NOTE = ("TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI")


def load(paths: list[str]) -> list[dict]:
    rows = []
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p) as f:
            for line in f:
                rows.append(json.loads(line))
    # keep the latest row per (arch, shape, mesh, step)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"], r.get("step"))] = r
    return list(dedup.values())


def _fmt(x: float) -> str:
    return f"{x:.2e}"


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | step | compute s | memory s | "
           "collective s | dominant | 6ND/HLO | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        note = r.get("rule", "") or ""
        if r.get("sliding_window"):
            note += f" window={r['sliding_window']}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} "
            f"| {_fmt(r['t_compute_s'])} | {_fmt(r['t_memory_s'])} "
            f"| {_fmt(r['t_collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def summary(rows: list[dict]) -> str:
    by_dom = defaultdict(list)
    for r in rows:
        by_dom[r["dominant"]].append(f"{r['arch']}×{r['shape']}")
    out = [f"{len(rows)} combos; {HW_NOTE}"]
    for dom, items in sorted(by_dom.items()):
        out.append(f"  dominant={dom}: {len(items)}")
    # the three §Perf candidates
    train = [r for r in rows if r["shape"] == "train_4k"
             and r["mesh"] == "16x16"]
    if train:
        worst = min(train, key=lambda r: r["useful_flops_ratio"])
        coll = max(train, key=lambda r: r["t_collective_s"]
                   / max(r["t_compute_s"] + r["t_memory_s"], 1e-12))
        out.append(f"  worst useful-flops ratio: {worst['arch']} "
                   f"({worst['useful_flops_ratio']:.2f})")
        out.append(f"  most collective-bound: {coll['arch']} "
                   f"(coll/compute+mem = "
                   f"{coll['t_collective_s'] / (coll['t_compute_s'] + coll['t_memory_s']):.2f})")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--in", dest="inputs", nargs="+",
                    default=["results/dryrun_single.jsonl",
                             "results/dryrun_multi.jsonl"])
    ap.add_argument("--md-out", default="results/roofline.md")
    args = ap.parse_args()
    rows = load(args.inputs)
    if not rows:
        print("no dry-run rows found — run: PYTHONPATH=src python -m "
              "repro.launch.dryrun --all --out results/dryrun_single.jsonl")
        return
    table = markdown_table(rows)
    with open(args.md_out, "w") as f:
        f.write(table + "\n")
    print(summary(rows))
    print(f"table -> {args.md_out}")


if __name__ == "__main__":
    main()
