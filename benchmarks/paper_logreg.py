"""Paper Figures 2-3: logistic regression on covtype-like / ijcnn1-like.

Reports loss vs iterations AND vs communication uploads AND vs gradient
evaluations for {Adam, CADA1, CADA2, stochastic LAG, local momentum,
FedAdam}, with the paper's hyper-parameters (Tables 1-2).

Claim validated: CADA1/2 reach the target loss with >=60% fewer uploads
than the best baseline (the paper reports >= one order of magnitude vs
Adam on logreg).
"""
from __future__ import annotations

import argparse
from functools import partial

import numpy as np

from benchmarks.common import (RunResult, run_engine_algo, save_rows,
                               uploads_to_target)
from repro.core.engine import make_sampler
from repro.data.partition import (pad_to_matrix, random_sizes_partition,
                                  uniform_partition)
from repro.data.synthetic import covtype_like, ijcnn1_like
from repro.models.small import logreg_init, logreg_loss

ALGOS = ("adam", "cada1", "cada2", "lag", "local_momentum", "fedadam")

SETUPS = {
    # paper: covtype 20 workers random unequal split, batch ratio 1e-3;
    # ijcnn1 10 workers uniform, batch ratio 1e-2; D=100, d_max=10.
    "covtype": dict(ds_fn=covtype_like, m=20, hetero=True, lr=0.005,
                    batch=32, h_period=20),
    "ijcnn1": dict(ds_fn=ijcnn1_like, m=10, hetero=False, lr=0.01,
                   batch=32, h_period=10),
}


C_GRID = (0.3, 1.0, 3.0, 10.0, 30.0, 100.0)   # paper §4: per-algo grid


def run(dataset: str, iters: int = 600, monte_carlo: int = 3,
        algos=ALGOS) -> list[dict]:
    su = SETUPS[dataset]
    ds = su["ds_fn"]()
    if su["hetero"]:
        shards = random_sizes_partition(ds.n, su["m"], seed=0)
    else:
        shards = uniform_partition(ds.n, su["m"], seed=0)
    mtx = pad_to_matrix(shards)
    sample = make_sampler(ds.x, ds.y, mtx, su["batch"])
    params = logreg_init(None, ds.x.shape[1], ds.n_classes)

    runner = partial(run_engine_algo, loss_fn=logreg_loss, params=params,
                     sample=sample, m=su["m"], iters=iters, lr=su["lr"],
                     d_max=10, max_delay=100, h_period=su["h_period"])

    # pass 1 — Adam fixes the loss target every algorithm must reach.
    adam_res = runner("adam", monte_carlo=monte_carlo)
    target = float(np.mean(adam_res.loss[-10:]) * 1.05)

    results: list[tuple[RunResult, float | None]] = [(adam_res, None)]
    for algo in algos:
        if algo == "adam":
            continue
        if algo in ("cada1", "cada2", "lag"):
            # the paper grid-searches each algorithm's threshold c.
            best, best_c = None, None
            for c in C_GRID:
                res = runner(algo, c=c, monte_carlo=1)
                u = uploads_to_target(res, target)
                if u is not None and (best is None
                                      or u < uploads_to_target(best,
                                                               target)):
                    best, best_c = res, c
            if best is None:  # never reaches target: report the run anyway
                best, best_c = runner(algo, c=C_GRID[0],
                                      monte_carlo=monte_carlo), C_GRID[0]
            elif monte_carlo > 1:
                best = runner(algo, c=best_c, monte_carlo=monte_carlo)
            results.append((best, best_c))
        else:
            results.append((runner(algo, monte_carlo=monte_carlo), None))

    rows = []
    for res, c in results:
        row = res.row()
        row["dataset"] = dataset
        row["c"] = c
        row["uploads_to_target"] = uploads_to_target(res, target)
        row["target_loss"] = target
        rows.append(row)
        print(f"  {dataset:8s} {row['algo']:15s} c={c} "
              f"final={row['final_loss']:.4f} "
              f"uploads@target={row['uploads_to_target']} "
              f"evals={row['total_grad_evals']}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--dataset", default="both",
                   choices=["covtype", "ijcnn1", "both"])
    p.add_argument("--iters", type=int, default=600)
    p.add_argument("--monte-carlo", type=int, default=3)
    args = p.parse_args()
    datasets = (["covtype", "ijcnn1"] if args.dataset == "both"
                else [args.dataset])
    rows = []
    for d in datasets:
        rows += run(d, iters=args.iters, monte_carlo=args.monte_carlo)
    path = save_rows("paper_logreg", rows)
    print(f"saved {path}")
    _assert_claims(rows)


def _assert_claims(rows) -> None:
    """The paper's headline: CADA cuts uploads >=60% vs baselines at equal
    loss (Figs 2-3)."""
    for dataset in {r["dataset"] for r in rows}:
        sub = {r["algo"]: r for r in rows if r["dataset"] == dataset}
        cada = min(x for a in ("cada1", "cada2")
                   if (x := sub[a]["uploads_to_target"]) is not None)
        base = min(x for a in ("adam", "local_momentum", "fedadam", "lag")
                   if a in sub
                   and (x := sub[a]["uploads_to_target"]) is not None)
        saving = 1.0 - cada / base
        print(f"[claim] {dataset}: CADA uploads-to-target {cada} vs best "
              f"baseline {base} -> saving {saving:.0%}")


if __name__ == "__main__":
    main()
