"""Paper Figures 4-5: neural-network training (mnist-like CNN / MLP).

The paper trains a two-conv+two-FC net on MNIST (Fig 4) and ResNet20 on
CIFAR10 (Fig 5) with D=50, d_max=10. This offline container uses the
statistically-similar mnist_like set and a reduced-width CNN (structure
preserved: conv-ELU-maxpool ×2 + FC ×2) — the measured quantity (uploads
saved at equal loss) is architecture-portable.
"""
from __future__ import annotations

import argparse
from functools import partial

import numpy as np

from benchmarks.common import (RunResult, run_engine_algo, save_rows,
                               uploads_to_target)
from repro.core.engine import make_sampler
from repro.data.partition import pad_to_matrix, uniform_partition
from repro.data.synthetic import mnist_like
from repro.models.small import cnn_init, cnn_loss, mlp_init, mlp_loss

import jax

ALGOS = ("adam", "cada1", "cada2", "lag", "local_momentum", "fedadam")
C_GRID = (0.3, 1.0, 3.0, 10.0, 30.0)


def run(model: str = "cnn", iters: int = 400, m: int = 10,
        monte_carlo: int = 1) -> list[dict]:
    ds = mnist_like(n=4096)
    mtx = pad_to_matrix(uniform_partition(ds.n, m, seed=0))
    x = ds.x if model == "cnn" else ds.x.reshape(ds.n, -1)
    sample = make_sampler(x, ds.y, mtx, 12)   # paper: minibatch 12
    if model == "cnn":
        params = cnn_init(jax.random.PRNGKey(0), n_classes=10)
        loss_fn = cnn_loss
    else:
        params = mlp_init(jax.random.PRNGKey(0), 28 * 28, 128, 10)
        loss_fn = mlp_loss

    runner = partial(run_engine_algo, loss_fn=loss_fn, params=params,
                     sample=sample, m=m, iters=iters, lr=5e-4,
                     d_max=10, max_delay=50, h_period=8, lag_lr=0.05)

    adam_res = runner("adam", monte_carlo=monte_carlo)
    target = float(np.mean(adam_res.loss[-10:]) * 1.1)
    rows = []

    def record(res: RunResult, c):
        row = res.row()
        row.update(model=model, c=c,
                   uploads_to_target=uploads_to_target(res, target),
                   target_loss=target)
        rows.append(row)
        print(f"  nn/{model} {row['algo']:15s} c={c} "
              f"final={row['final_loss']:.4f} "
              f"uploads@target={row['uploads_to_target']}")

    record(adam_res, None)
    for algo in ALGOS[1:]:
        if algo in ("cada1", "cada2", "lag"):
            best, best_c = None, None
            for c in C_GRID:
                res = runner(algo, c=c, monte_carlo=1)
                u = uploads_to_target(res, target)
                if u is not None and (
                        best is None
                        or u < uploads_to_target(best, target)):
                    best, best_c = res, c
            if best is None:
                best, best_c = runner(algo, c=C_GRID[0]), C_GRID[0]
            record(best, best_c)
        else:
            record(runner(algo, monte_carlo=monte_carlo), None)
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--model", default="cnn", choices=["cnn", "mlp"])
    p.add_argument("--iters", type=int, default=400)
    args = p.parse_args()
    rows = run(model=args.model, iters=args.iters)
    path = save_rows(f"paper_nn_{args.model}", rows)
    print(f"saved {path}")


if __name__ == "__main__":
    main()
