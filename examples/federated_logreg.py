"""Paper-faithful federated experiment (Figs 2-3 setting): heterogeneous
workers, all six algorithms, loss vs cumulative uploads.

    PYTHONPATH=src python examples/federated_logreg.py [--iters 600]

Prints an ASCII convergence table: the paper's 'communication complexity'
comparison — how many uploads each algorithm needs to reach the Adam
target loss.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.paper_logreg import run  # noqa: E402


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--dataset", default="covtype",
                   choices=["covtype", "ijcnn1"])
    p.add_argument("--iters", type=int, default=600)
    args = p.parse_args()
    rows = run(args.dataset, iters=args.iters, monte_carlo=1)

    print(f"\n{'algo':16s} {'c':>6s} {'final loss':>11s} "
          f"{'uploads@target':>15s}")
    for r in rows:
        u = r["uploads_to_target"]
        print(f"{r['algo']:16s} {str(r['c']):>6s} "
              f"{r['final_loss']:>11.4f} "
              f"{('-' if u is None else str(u)):>15s}")
    adam_u = next(r["uploads_to_target"] for r in rows
                  if r["algo"] == "adam")
    best_cada = min(r["uploads_to_target"] for r in rows
                    if r["algo"].startswith("cada")
                    and r["uploads_to_target"] is not None)
    print(f"\nCADA reaches Adam's loss with "
          f"{1 - best_cada / adam_u:.0%} fewer uploads.")


if __name__ == "__main__":
    main()
