"""Batched serving example: prefill a prompt batch, decode with the
ring-buffer KV / SSM state caches, compare an attention arch with an
attention-free SSM (falcon-mamba family: O(1) decode state).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import decode_step, init_params, prefill


def serve(arch: str, batch=4, prompt=48, new_tokens=24) -> None:
    cfg = C.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt), 0,
                              cfg.vocab)
    max_seq = prompt + new_tokens

    prefill_j = jax.jit(lambda p, t: prefill(cfg, p, tokens=t,
                                             max_seq=max_seq))
    decode_j = jax.jit(lambda p, c, t: decode_step(cfg, p, c, tokens=t))

    t0 = time.time()
    logits, cache = prefill_j(params, toks)
    t_prefill = time.time() - t0

    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves((cache.k, cache.v, cache.conv,
                                                cache.ssm))
                      if x is not None)
    nxt = jnp.argmax(logits, axis=-1)
    out = [nxt]
    t0 = time.time()
    for _ in range(new_tokens):
        logits, cache = decode_j(params, cache, nxt)
        nxt = jnp.argmax(logits, axis=-1)
        out.append(nxt)
    t_decode = time.time() - t0
    print(f"{arch:22s} prefill {t_prefill:5.2f}s | "
          f"{new_tokens} tokens in {t_decode:5.2f}s "
          f"({batch * new_tokens / t_decode:6.1f} tok/s) | "
          f"cache {cache_bytes / 1e6:.2f} MB")


if __name__ == "__main__":
    print("batched serving: GQA-attention vs attention-free SSM vs hybrid")
    for arch in ("internlm2-1.8b", "falcon-mamba-7b", "zamba2-2.7b"):
        serve(arch)
