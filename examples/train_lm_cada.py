"""End-to-end driver: train a ~100M-parameter decoder LM with hierarchical
CADA for a few hundred steps (deliverable b).

    PYTHONPATH=src python examples/train_lm_cada.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm_cada.py --tiny     # CI-sized

The model is a llama-family dense GQA decoder built from the same
ModelConfig the 10 assigned architectures use; the trainer is the same
distributed CADA2 step the multi-pod dry-run lowers. On this CPU container
the 100M default takes a while — --tiny exercises the identical path in
seconds.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.rules import CommRule
from repro.distributed.trainer import (TrainHParams, init_train_state,
                                       make_train_step, worker_split)
from repro.launch.train import make_token_batches
from repro.models.config import ModelConfig, param_count

LM_100M = ModelConfig(
    name="repro-lm-100m", arch_type="dense", block="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab=32000, mlp_act="swiglu", dtype="float32", remat=False,
    source="quickstart 100M config (llama-family)")

LM_TINY = LM_100M.with_(name="repro-lm-tiny", n_layers=2, d_model=256,
                        n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tiny", action="store_true")
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--workers", type=int, default=4)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seq", type=int, default=None)
    p.add_argument("--rule", default="cada2")
    args = p.parse_args()

    cfg = LM_TINY if args.tiny else LM_100M
    steps = args.steps or (30 if args.tiny else 200)
    batch = args.batch or (8 if args.tiny else 8)
    seq = args.seq or (64 if args.tiny else 256)
    m = args.workers
    print(f"model {cfg.name}: {param_count(cfg):,} params; "
          f"{steps} steps of {batch}x{seq} tokens on {m} workers")

    hp = TrainHParams(rule=CommRule(kind=args.rule, c=1.0, d_max=10,
                                    max_delay=50), lr=3e-4)
    step = jax.jit(make_train_step(cfg, hp, m))
    state = init_train_state(cfg, hp, m, jax.random.PRNGKey(0))
    tokens = make_token_batches(cfg, global_batch=batch, seq=seq,
                                steps=steps)

    t0, losses, uploads = time.time(), [], 0
    for i in range(steps):
        bt = worker_split({"tokens": tokens[i]}, m)
        state, mets = step(state, bt)
        losses.append(float(mets["loss"]))
        uploads += int(mets["uploads"])
        if i % 10 == 0 or i == steps - 1:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"skip={float(mets['skip_rate']):.2f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f}; uploads {uploads}/{steps * m}"
          f" ({1 - uploads / (steps * m):.0%} skipped)")
    assert last < first, "training must reduce the loss"


if __name__ == "__main__":
    main()
