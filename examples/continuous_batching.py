"""Serve a stream of variable-length requests through the fixed-slot
continuous-batching scheduler (distributed/scheduler.py).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

import repro.configs as C
from repro.distributed.scheduler import DecodeScheduler, Request
from repro.models.model import init_params

cfg = C.get_smoke_config("stablelm-1.6b")
params = init_params(cfg, jax.random.PRNGKey(0))
sched = DecodeScheduler(cfg, params, n_slots=4, max_seq=96)

rng = np.random.default_rng(0)
for uid in range(10):
    sched.submit(Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24),
                            dtype=np.int32),
        max_new=int(rng.integers(4, 16))))

t0 = time.time()
done = sched.run()
dt = time.time() - t0
tokens = sum(len(r.out) for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.1f}s "
      f"({tokens / dt:.1f} tok/s, slot utilization "
      f"{sched.utilization():.0%})")
for r in done[:3]:
    print(f"  req {r.uid}: prompt {len(r.prompt)} -> {r.out[:8]}...")
