"""Quickstart: communication-adaptive distributed Adam in ~40 lines.

Ten workers with heterogeneous (label-skewed) data collaboratively fit a
logistic regression. CADA2 skips the uninformative uploads; distributed
Adam uploads every worker every step. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.engine import CADAEngine, make_sampler
from repro.core.rules import CommRule
from repro.data.partition import dirichlet_partition, pad_to_matrix
from repro.data.synthetic import ijcnn1_like
from repro.models.small import logreg_init, logreg_loss
from repro.optim.adam import adam

M, ITERS = 10, 500

ds = ijcnn1_like(n=8000)
shards = pad_to_matrix(dirichlet_partition(ds.y, m=M, alpha=0.3, seed=0))
sample = make_sampler(ds.x, ds.y, shards, batch_size=32)
params = logreg_init(None, dim=ds.x.shape[1], n_classes=ds.n_classes)

for name, rule in [
    ("distributed Adam", CommRule(kind="always")),
    ("CADA2           ", CommRule(kind="cada2", c=0.6, d_max=10,
                                  max_delay=100)),
]:
    engine = CADAEngine(logreg_loss, adam(lr=0.01), rule, n_workers=M)
    state = engine.init(params)
    batches = jax.vmap(sample)(
        jax.random.split(jax.random.PRNGKey(1), ITERS))
    state, metrics = jax.jit(engine.run)(state, batches)
    loss = float(np.asarray(metrics["loss"])[-20:].mean())
    uploads = int(np.asarray(metrics["uploads"]).sum())
    print(f"{name}  final loss {loss:.4f}   worker uploads "
          f"{uploads:5d} / {ITERS * M}")
